# NOTE: no XLA_FLAGS here on purpose — tests run on the 1 real CPU device.
# Only launch/dryrun.py and analysis/run_roofline.py request 512 placeholder
# devices, in their own processes.
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
