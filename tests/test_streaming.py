"""Streaming subsystem: sources, tiler, and the deadline-scheduled pipeline.

The load-bearing invariants: clips replay deterministically, the pipeline
serves EXACTLY what the offline tiler computes, every frame is accounted
(in == served + dropped, never silently lost), bounded queues stay bounded
under a too-fast source, and the two fixed-point substrates produce
bit-identical detections on a frozen clip.
"""
import dataclasses
import time

import numpy as np
import pytest

from repro.core import smallnet
from repro.serving.router import ReplicaRouter
from repro.serving.vision_engine import VisionEngine
from repro.streaming.pipeline import StreamConfig, StreamingPipeline
from repro.streaming.sources import PacedPlayer, SyntheticVideoSource
from repro.streaming.tiler import Tiler, tile_positions


@pytest.fixture(scope="module")
def params():
    return smallnet.seeded_params()


@pytest.fixture(scope="module")
def clip():
    return SyntheticVideoSource(n_frames=8, seed=3)


@pytest.fixture(scope="module")
def tiler(params, clip):
    """Threshold at the 80th pct of first-frame 'fixed' confidences, so the
    frozen clip deterministically yields nonzero detections."""
    t0 = Tiler(stride=14)
    tiles, _ = t0.extract(clip.frames()[0])
    conf = t0._confidences(t0.score(params, tiles, backend="fixed")).max(-1)
    return Tiler(stride=14, threshold=float(np.quantile(conf, 0.8)))


# ---------------------------------------------------------------------------
# sources
# ---------------------------------------------------------------------------

def test_source_replays_identical_clip(clip):
    a, b = clip.frames(), clip.frames()
    assert len(a) == len(b) == 8
    for fa, fb in zip(a, b):
        np.testing.assert_array_equal(fa.pixels, fb.pixels)
        assert fa.truth == fb.truth


def test_source_tracks_stay_in_bounds_and_move(clip):
    H, W = clip.frame_shape
    frames = clip.frames()
    for f in frames:
        assert f.pixels.shape == (H, W, 1)
        assert f.pixels.min() >= 0.0 and f.pixels.max() <= 1.0
        for box in f.truth:
            assert 0 <= box.y and box.y + box.h <= H
            assert 0 <= box.x and box.x + box.w <= W
    # the objects drift: at least one box center changes across the clip
    c0 = [b.center for b in frames[0].truth]
    cN = [b.center for b in frames[-1].truth]
    assert c0 != cN


# ---------------------------------------------------------------------------
# tiler
# ---------------------------------------------------------------------------

def test_tile_positions_cover_frame():
    pos = tile_positions((112, 112), 28, 14)
    assert len(pos) == 49                        # 7x7 sweep
    covered = np.zeros((112, 112), bool)
    for y, x in pos:
        covered[y:y + 28, x:x + 28] = True
    assert covered.all()
    # non-dividing stride: last window clamps to the edge, still covers
    pos = tile_positions((100, 90), 28, 24)
    assert max(y for y, _ in pos) == 72 and max(x for _, x in pos) == 62
    covered = np.zeros((100, 90), bool)
    for y, x in pos:
        covered[y:y + 28, x:x + 28] = True
    assert covered.all()


def test_tiler_extract_matches_slicing(clip):
    frame = clip.frames()[0]
    t = Tiler(stride=28)
    tiles, pos = t.extract(frame)
    assert tiles.shape == (len(pos), 28, 28, 1) and tiles.dtype == np.float32
    for tile, (y, x) in zip(tiles, pos):
        np.testing.assert_array_equal(tile, frame.pixels[y:y + 28, x:x + 28])


def test_aggregate_thresholds_and_dedups():
    t = Tiler(stride=14, threshold=0.9, min_dist=14)
    pos = [(0, 0), (0, 14), (0, 70), (56, 56)]
    scores = np.full((4, 10), 0.1, np.float32)
    scores[0, 3] = 0.95           # hit
    scores[1, 3] = 0.97           # stronger hit 14px away -> wins, 0 suppressed
    scores[2, 7] = 0.93           # distinct object
    scores[3, 5] = 0.50           # below threshold
    dets = t.aggregate(scores, pos)
    assert [(d.label, d.y, d.x) for d in dets] == [(3, 0, 14), (7, 0, 70)]
    assert dets[0].score == pytest.approx(0.97)


def test_aggregate_min_mass_gates_empty_windows():
    t = Tiler(stride=14, threshold=0.9, min_mass=0.05)
    pos = [(0, 0), (0, 70)]
    scores = np.full((2, 10), 0.99, np.float32)       # both confident...
    tiles = np.zeros((2, 28, 28, 1), np.float32)
    tiles[1] += 0.2                                   # ...only one has pixels
    dets = t.aggregate(scores, pos, tiles)
    assert [(d.y, d.x) for d in dets] == [(0, 70)]
    # without tiles the gate is a no-op
    assert len(t.aggregate(scores, pos)) == 2


# ---------------------------------------------------------------------------
# pipeline
# ---------------------------------------------------------------------------

def test_pipeline_serves_exactly_the_offline_detections(params, clip, tiler):
    eng = VisionEngine(params, backend="ref", batch_size=64, warmup=False)
    pipe = StreamingPipeline(clip, eng, tiler)
    res = pipe.run()
    s = pipe.stats()
    assert s["mode"] == "throughput"
    assert s["frames_served"] == len(clip) and s["frames_dropped"] == 0
    assert s["accounted"]
    offline = [tiler.detect(params, f, backend="ref") for f in clip.frames()]
    assert [r.detections for r in res] == offline
    assert s["detections_total"] == sum(len(d) for d in offline) > 0
    assert 0.0 < s["batch_occupancy"] <= 1.0


def test_deadline_misses_are_counted_not_lost(params, clip, tiler):
    eng = VisionEngine(params, backend="ref", batch_size=64, warmup=False)
    pipe = StreamingPipeline(
        PacedPlayer(clip, fps=100), eng, tiler,
        config=StreamConfig(deadline_ms=1e-3, queue_size=4))
    res = pipe.run()
    s = pipe.stats()
    assert res == [] and s["frames_served"] == 0
    assert s["frames_dropped"] == s["frames_in"] == len(clip)
    assert s["drops_by_reason"] == {"deadline": len(clip)}
    assert s["accounted"]


@dataclasses.dataclass
class _FakeResult:
    scores: np.ndarray


class _SlowEngine:
    """Stub inference: fixed per-wave delay, constant scores."""

    def __init__(self, delay_s: float):
        self.delay_s = delay_s

    def serve(self, tiles):
        time.sleep(self.delay_s)
        return [_FakeResult(scores=np.zeros(10, np.float32)) for _ in tiles]


def test_backpressure_bounds_queue_depth_under_fast_source(tiler):
    clip = SyntheticVideoSource(n_frames=20, seed=1)
    pipe = StreamingPipeline(
        PacedPlayer(clip, fps=500), _SlowEngine(0.02), tiler,
        config=StreamConfig(queue_size=2))
    pipe.run()
    s = pipe.stats()
    assert s["mode"] == "realtime"
    assert max(s["queue_hwm"].values()) <= 2
    assert s["drops_by_reason"].get("queue_full", 0) > 0
    assert s["accounted"]
    assert s["frames_served"] + s["frames_dropped"] == 20


def test_drop_policy_oldest_keeps_the_freshest_frames(tiler):
    clip = SyntheticVideoSource(n_frames=20, seed=1)
    pipe = StreamingPipeline(
        PacedPlayer(clip, fps=500), _SlowEngine(0.02), tiler,
        config=StreamConfig(queue_size=2, drop_policy="oldest"))
    res = pipe.run()
    s = pipe.stats()
    assert s["accounted"] and s["drops_by_reason"].get("queue_full", 0) > 0
    # evicting the stalest queued frame means the clip's LAST frame is
    # always admitted and served
    assert res and res[-1].index == 19


def test_fixed_vs_fixed_pallas_detections_bit_identical(params, clip, tiler):
    """The frozen-clip contract: identical int32 score words -> identical
    detections (labels, coordinates, AND float scores) on both fixed
    substrates, through the full pipeline."""
    results = {}
    for backend in ("fixed", "fixed_pallas"):
        eng = VisionEngine(params, backend=backend, batch_size=64,
                           warmup=False)
        pipe = StreamingPipeline(clip, eng, tiler)
        pipe.run()
        assert pipe.stats()["accounted"]
        assert pipe.stats()["frames_served"] == len(clip)
        results[backend] = [r.detections for r in pipe.results]
    assert sum(len(d) for d in results["fixed"]) > 0
    assert results["fixed"] == results["fixed_pallas"]


def test_engine_batch_occupancy(params):
    eng = VisionEngine(params, backend="ref", batch_size=4, warmup=False)
    eng.serve([np.zeros((28, 28, 1), np.float32)] * 5)   # 2 steps, 3 padded
    s = eng.stats()
    assert s["batches"] == 2 and s["padded_slots"] == 3
    assert s["batch_occupancy"] == pytest.approx(5 / 8)


@pytest.mark.slow
def test_router_soak_reconciles_every_frame(params, tiler):
    """Several hundred frames through a 2-replica router: frames in ==
    served + dropped, and the fleet saw exactly one wave of tiles per
    served frame."""
    clip = SyntheticVideoSource(n_frames=300, seed=11)
    router = ReplicaRouter.from_backends(params, ["ref", "ref"],
                                        batch_size=64, warmup=False)
    pipe = StreamingPipeline(
        PacedPlayer(clip, fps=40), router, tiler,
        config=StreamConfig(deadline_ms=500, queue_size=4))
    pipe.run()
    s = pipe.stats()
    assert s["accounted"]
    assert s["frames_served"] + s["frames_dropped"] == 300
    n_tiles = len(tiler.positions(clip.frame_shape))
    assert s["engine"]["n"] == s["frames_served"] * n_tiles
    assert s["frames_served"] > 0
