"""The process-wide interpret switch (core/runtime.py).

Pure plumbing tests — no compiled-mode execution (CPU CI has no device to
compile Pallas for): the default resolves, explicit flags win, flipping the
switch fires the registered cache-reset hooks exactly once per real change,
and the registered backends defer to the process default (interpret=None)
rather than pinning their own.
"""
import pytest

from repro.core import backends as B
from repro.core import runtime


@pytest.fixture(autouse=True)
def _restore_interpret():
    before = runtime.interpret_default()
    yield
    runtime.set_interpret(before)


def test_resolve_explicit_wins_none_follows_default():
    assert runtime.resolve_interpret(None) == runtime.interpret_default()
    assert runtime.resolve_interpret(True) is True
    assert runtime.resolve_interpret(False) is False
    runtime.set_interpret(False)
    assert runtime.resolve_interpret(None) is False
    assert runtime.resolve_interpret(True) is True


def test_set_interpret_fires_hooks_only_on_change():
    calls = []
    hook = lambda: calls.append(1)
    runtime.register_reset_hook(hook)
    try:
        start = runtime.interpret_default()
        runtime.set_interpret(start)          # no-op: unchanged
        assert calls == []
        runtime.set_interpret(not start)
        assert calls == [1]
        runtime.set_interpret(not start)      # no-op again
        assert calls == [1]
    finally:
        runtime._RESET_HOOKS.remove(hook)


def test_registered_backends_follow_process_default():
    """No registered backend pins its own interpret mode — one switch moves
    the whole stack (the satellite contract this PR introduced)."""
    for name in B.list_backends():
        be = B.get_backend(name)
        flag = getattr(be, "interpret", None)
        assert flag is None, (
            f"backend {name!r} pins interpret={flag!r}; it must default to "
            f"None so backends.set_interpret governs it")
    assert B.set_interpret is runtime.set_interpret
    assert B.interpret_default is runtime.interpret_default
