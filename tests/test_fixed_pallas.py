"""fixed_conv / fixed_dense Pallas kernels vs the numpy int64 oracle and the
emulated jnp fixed path — randomized word-level parity that runs in tier-1
without hypothesis (the deeper property battery lives in
test_fixed_pallas_props.py and skips cleanly when hypothesis is absent)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backends as B
from repro.core import fixed_point as fxp
from repro.kernels.fixed_conv import (fixed_conv2d, fixed_conv2d_ref,
                                      fixed_dense_ref, fixed_maxpool2x2,
                                      fixed_maxpool2x2_ref, fixed_sigmoid,
                                      fixed_sigmoid_plan_ref)
from repro.kernels.fixed_conv.ref import random_words as _words
from repro.kernels.quant_matmul import fixed_dense

# one canonical format/mode matrix (core/fixed_point.py) drives every battery
CFGS = list(fxp.STANDARD_CONFIGS.values())
_IDS = list(fxp.STANDARD_CONFIGS)


def _i32(a):
    return jnp.asarray(np.asarray(a), jnp.int32)


@pytest.mark.parametrize("cfg", CFGS, ids=_IDS)
@pytest.mark.parametrize("activation,pool", [(None, False), ("plan", False),
                                             (None, True), ("plan", True)])
def test_fixed_conv_pipeline_vs_oracle_and_emulated(cfg, activation, pool, rng):
    x = _words(rng, (2, 8, 8), cfg)
    w4 = _words(rng, (4,), cfg, extremes=1)
    b = int(_words(rng, (1,), cfg, extremes=0)[0])
    got = fixed_conv2d(_i32(x), _i32(w4), jnp.int32(b), cfg=cfg,
                       activation=activation, pool=pool)
    want = fixed_conv2d_ref(x, w4, b, cfg, activation=activation, pool=pool)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)
    # and the emulated composition produces the same words
    emu = B.conv_fixed(_i32(x), _i32(w4), jnp.int32(b), cfg)
    if activation == "plan":
        emu = fxp.fixed_sigmoid_plan(emu, cfg)
    if pool:
        emu = B.maxpool_fixed(emu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(emu))


@pytest.mark.parametrize("cfg", CFGS, ids=_IDS)
def test_fixed_conv_stride2_vs_oracle(cfg, rng):
    """Mirror of the conv2d stride tests: stride realized by output
    decimation after the full stride-1 fused pipeline, still bit-exact."""
    x = _words(rng, (2, 12, 10), cfg)
    w4 = _words(rng, (4,), cfg, extremes=1)
    got = fixed_conv2d(_i32(x), _i32(w4), jnp.int32(7), cfg=cfg, stride=2)
    assert got.shape == (2, 6, 5)
    want = fixed_conv2d_ref(x, w4, 7, cfg, stride=2)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)


def test_fixed_conv_pool_and_stride_mutually_exclusive():
    x = jnp.zeros((1, 8, 8), jnp.int32)
    w4 = jnp.zeros((4,), jnp.int32)
    with pytest.raises(ValueError, match="pool and stride"):
        fixed_conv2d(x, w4, jnp.int32(0), pool=True, stride=2)


def test_fixed_conv_bad_activation_rejected():
    x = jnp.zeros((1, 8, 8), jnp.int32)
    with pytest.raises(ValueError, match="activation"):
        fixed_conv2d(x, jnp.zeros((4,), jnp.int32), jnp.int32(0),
                     activation="sigmoid")


def test_fixed_conv_vmem_guard():
    x = jnp.zeros((1, 1536, 1536), jnp.int32)
    with pytest.raises(ValueError, match="VMEM"):
        fixed_conv2d(x, jnp.zeros((4,), jnp.int32), jnp.int32(0))


@pytest.mark.parametrize("H,W", [(14, 14), (7, 7), (15, 9)])
def test_fixed_maxpool_odd_crop_vs_oracle(H, W, rng):
    x = _words(rng, (3, H, W), fxp.Q16_16)
    got = fixed_maxpool2x2(_i32(x))
    assert got.shape == (3, H // 2, W // 2)
    np.testing.assert_array_equal(np.asarray(got, np.int64),
                                  fixed_maxpool2x2_ref(x))


@pytest.mark.parametrize("cfg", CFGS, ids=_IDS)
@pytest.mark.parametrize("shape", [(10,), (6, 10), (2, 7, 7)])
def test_fixed_sigmoid_shapes_vs_oracle(cfg, shape, rng):
    x = _words(rng, shape, cfg)
    got = fixed_sigmoid(_i32(x), cfg=cfg)
    assert got.shape == shape and got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got, np.int64),
                                  fixed_sigmoid_plan_ref(x, cfg))


@pytest.mark.parametrize("cfg", CFGS, ids=_IDS)
@pytest.mark.parametrize("M,K,N", [(6, 49, 10), (1, 8, 5), (130, 16, 4)])
def test_fixed_dense_vs_oracle_and_emulated(cfg, M, K, N, rng):
    x = _words(rng, (M, K), cfg)
    w = _words(rng, (K, N), cfg)
    b = _words(rng, (N,), cfg, extremes=1)
    got = fixed_dense(_i32(x), _i32(w), _i32(b), cfg=cfg)
    np.testing.assert_array_equal(np.asarray(got, np.int64),
                                  fixed_dense_ref(x, w, b, cfg))
    emu = fxp.fixed_add(fxp.fixed_matmul(_i32(x), _i32(w), cfg),
                        _i32(b).reshape(1, -1), cfg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(emu))


def test_fixed_dense_default_bias_is_zero_words(rng):
    x = _words(rng, (3, 8), fxp.Q16_16)
    w = _words(rng, (8, 4), fxp.Q16_16)
    got = fixed_dense(_i32(x), _i32(w), cfg=fxp.Q16_16)
    want = fixed_dense_ref(x, w, np.zeros(4, np.int64), fxp.Q16_16)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)


# ---------------------------------------------------------------------------
# Rounding-semantics audit (the latent divergence fixed in this PR)
# ---------------------------------------------------------------------------

def test_plan_sigmoid_truncate_mode_is_pure_shift(rng):
    """Regression: in truncate mode the PLAN slopes must be the raw hardware
    shifter `ax >> k` — no rounding bit anywhere in the pipeline."""
    cfg = fxp.FixedPointConfig(32, 16, round_nearest=False)
    x = _words(rng, (512,), cfg)
    got = np.asarray(fxp.fixed_sigmoid_plan(_i32(x), cfg), np.int64)
    # int32 |x| wraps at INT32_MIN (|-2^31| stays -2^31), like jnp.abs
    ax = ((np.abs(x) + 2**31) % 2**32) - 2**31
    c = lambda v: int(np.asarray(fxp.to_fixed(v, cfg)))
    y = np.where(ax >= c(5.0), c(1.0),
                 np.where(ax >= c(2.375), (ax >> 5) + c(0.84375),
                          np.where(ax >= c(1.0), (ax >> 3) + c(0.625),
                                   (ax >> 2) + c(0.5))))
    want = np.where(x < 0, c(1.0) - y, y)
    np.testing.assert_array_equal(got, want)
    # and the Pallas kernel uses the identical shift semantics
    np.testing.assert_array_equal(
        np.asarray(fixed_sigmoid(_i32(x), cfg=cfg), np.int64), got)


def test_plan_sigmoid_round_nearest_adds_the_rounding_bit():
    """With round_nearest the slope shifts must round exactly like
    `fixed_mul` does (add bit k-1), so emulated and kernel paths share one
    shift rule.  2.5 in Q16.16: |x|>>3 has bit 2 set -> +1 word."""
    cfg_rn = fxp.Q16_16
    cfg_tr = fxp.FixedPointConfig(32, 16, round_nearest=False)
    x = jnp.asarray([int(fxp.to_fixed(1.0, cfg_rn)) + 4], jnp.int32)  # 65540
    rn = int(fxp.fixed_sigmoid_plan(x, cfg_rn)[0])
    tr = int(fxp.fixed_sigmoid_plan(x, cfg_tr)[0])
    assert rn == tr + 1        # 65540 >> 3 truncates; rounding bit adds one
    assert int(fixed_sigmoid(x, cfg=cfg_rn)[0]) == rn
    assert int(fixed_sigmoid(x, cfg=cfg_tr)[0]) == tr


def test_conv_and_sigmoid_share_shift_semantics_across_modes(rng):
    """The fused kernel and the emulated path agree word-for-word in BOTH
    rounding modes — the audit's acceptance condition."""
    for rnearest in (True, False):
        cfg = fxp.FixedPointConfig(32, 16, round_nearest=rnearest)
        x = _words(rng, (2, 6, 6), cfg)
        w4 = _words(rng, (4,), cfg, extremes=1)
        got = fixed_conv2d(_i32(x), _i32(w4), jnp.int32(3), cfg=cfg,
                           activation="plan")
        emu = fxp.fixed_sigmoid_plan(
            B.conv_fixed(_i32(x), _i32(w4), jnp.int32(3), cfg), cfg)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(emu))
