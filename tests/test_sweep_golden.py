"""Golden-vector regression for the FCN sweep trunk.

tests/golden/sweep_golden.json freezes the Q16.16 words of the full-frame
sweep over a deterministic 112x112 synthetic frame: all four pooled role
maps (interior / last_row / last_col / corner) and the stride-8 window
scores.  Both fixed substrates must reproduce every word — any drift in the
masked-weight edge maps, the decomposed accumulation, or the underlying
conv/PLAN/pool arithmetic fails here first, against vectors that cannot
silently regenerate themselves (the CI golden job diffs a fresh
generation).

Regenerate (only after an INTENTIONAL semantics change) with:
    PYTHONPATH=src python tests/golden/gen_sweep_golden.py
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core import smallnet
from repro.streaming.fcn_sweep import FcnSweep, sweep_feature_maps
from repro.streaming.sources import SyntheticVideoSource

_GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "sweep_golden.json").read_text())


@pytest.fixture(scope="module")
def params():
    return smallnet.seeded_params()


@pytest.fixture(scope="module")
def frame():
    f = SyntheticVideoSource(n_frames=1, seed=7).frames()[0]
    assert list(f.pixels.shape[:2]) == _GOLDEN["frame"]["shape"]
    return f


def _assert_words(got, want, what):
    np.testing.assert_array_equal(
        np.asarray(got, np.int64), np.asarray(want, np.int64),
        err_msg=f"{what}: sweep words drifted from golden vectors")


def test_golden_covers_all_role_maps():
    assert set(_GOLDEN["maps"]) == {"interior", "last_row", "last_col",
                                    "corner"}
    for m in _GOLDEN["maps"].values():
        assert np.asarray(m).shape == (28, 28)


@pytest.mark.parametrize("backend", ("fixed", "fixed_pallas"))
def test_role_maps_golden(params, frame, backend):
    maps = sweep_feature_maps(params, frame.pixels, backend=backend)
    for name, want in _GOLDEN["maps"].items():
        _assert_words(maps[name], want, f"{backend}/{name}")


@pytest.mark.parametrize("backend", ("fixed", "fixed_pallas"))
def test_window_scores_golden(params, frame, backend):
    sweep = FcnSweep(stride=_GOLDEN["stride"])
    fb, pos = sweep.extract(frame)
    assert [list(p) for p in pos] == _GOLDEN["positions"]
    got = sweep.score(params, fb, backend=backend)
    _assert_words(got, _GOLDEN["scores"], f"{backend}/scores")
