"""Word-exactness battery for the tiled trunk megakernel.

kernels/frame_trunk runs smallNet's entire conv->PLAN->pool trunk (with the
sweep's quad role maps) over a big frame in ONE Pallas launch.  Three
independent routes to the same int32 words get pinned pairwise:

  * the megakernel vs the untiled numpy int64 oracle (ref.py) on small
    random-word frames across tilings — interior, frame border, AND tile
    seams, in both Q16.16 and Q8.8;
  * the megakernel vs the composed per-stage FcnSweep cascade on the real
    112x112 streaming frame and on a 512x512 frame (where `choose_tile`
    splits 512x256 x2, so the seam path runs at acceptance scale) on both
    fixed substrates;
  * the end-to-end sweep scores (megakernel route vs composed route) and
    the `conv_trunk` fast path vs the plain per-stage trunk.

Launch topology is asserted too: the megakernel trunk must trace to exactly
ONE `pallas_call`, the composed fixed_pallas cascade to many.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.analysis.launches import count_pallas_launches
from repro.core import backends as B
from repro.core import fixed_point as fxp
from repro.core import smallnet
from repro.kernels.fixed_conv.ref import random_words
from repro.kernels.frame_trunk import choose_tile, frame_trunk_quad
from repro.kernels.frame_trunk import ops as ft_ops
from repro.kernels.frame_trunk.ref import frame_trunk_quad_ref
from repro.streaming import fcn_sweep as fs
from repro.streaming.fcn_sweep import FcnSweep, sweep_feature_maps
from repro.streaming.sources import SyntheticVideoSource

FIXED_BACKENDS = ("fixed", "fixed_pallas")
CFGS = {"q16_16": fxp.Q16_16, "q8_8": fxp.Q8_8}


@pytest.fixture(scope="module")
def params():
    return smallnet.seeded_params()


@pytest.fixture(scope="module")
def frame112():
    return SyntheticVideoSource(n_frames=1, seed=7).frames()[0]


def _rand_trunk_inputs(rng, shape, cfg):
    x = random_words(rng, shape, cfg)
    w1 = random_words(rng, (4,), cfg)
    b1 = random_words(rng, (1,), cfg)
    w2 = random_words(rng, (4,), cfg)
    b2 = random_words(rng, (1,), cfg)
    return x, w1, b1, w2, b2


def _assert_words(got, want, what):
    np.testing.assert_array_equal(
        np.asarray(got, np.int64), np.asarray(want, np.int64),
        err_msg=f"{what}: megakernel words drifted")


# ---------------------------------------------------------------------------
# megakernel vs the untiled numpy oracle, across tilings and formats
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", sorted(CFGS))
@pytest.mark.parametrize("shape", [(8, 8), (16, 12), (24, 16)])
def test_megakernel_matches_oracle(fmt, shape):
    cfg = CFGS[fmt]
    rng = np.random.default_rng(hash((fmt, shape)) % 2**32)
    x, w1, b1, w2, b2 = _rand_trunk_inputs(rng, shape, cfg)
    want = frame_trunk_quad_ref(x, w1, b1, w2, b2, cfg)
    H, W = shape
    # one tile, the minimal 4x4 tiling (max seams), and a column split —
    # the oracle is untiled, so matching every tiling pins halo/DMA/seam
    # bookkeeping, not just the arithmetic
    for tile in (None, (H, W), (4, 4), (H, 4)):
        got = frame_trunk_quad(jnp.asarray(x, jnp.int32), w1, b1, w2, b2,
                               cfg=cfg, tile=tile)
        _assert_words(got, want, f"{fmt}/{shape}/tile={tile}")


def test_megakernel_tile_invariance():
    """Every legal tiling of the same frame produces identical words —
    seam columns/rows are indistinguishable from interior ones."""
    cfg = fxp.Q16_16
    rng = np.random.default_rng(11)
    x, w1, b1, w2, b2 = _rand_trunk_inputs(rng, (24, 24), cfg)
    outs = {}
    for tile in ((24, 24), (12, 12), (8, 8), (4, 4), (24, 8), (4, 24)):
        outs[tile] = np.asarray(frame_trunk_quad(
            jnp.asarray(x, jnp.int32), w1, b1, w2, b2, cfg=cfg, tile=tile))
    base = outs[(24, 24)]
    for tile, got in outs.items():
        _assert_words(got, base, f"tile={tile}")


def test_megakernel_rejects_bad_geometry():
    cfg = fxp.Q16_16
    x = jnp.zeros((16, 16), jnp.int32)
    w = jnp.ones((4,), jnp.int32)
    b = jnp.zeros((1,), jnp.int32)
    for shape in ((15, 16), (16, 18), (2, 16), (16, 2)):
        with pytest.raises(ValueError, match="frame"):
            frame_trunk_quad(jnp.zeros(shape, jnp.int32), w, b, w, b, cfg=cfg)
    for tile in ((5, 4), (4, 6), (12, 4), (4, 12), (2, 2)):
        with pytest.raises(ValueError, match="tile"):
            frame_trunk_quad(x, w, b, w, b, cfg=cfg, tile=tile)
    sat = fxp.FixedPointConfig(cfg.total_bits, cfg.frac_bits, saturate=True)
    with pytest.raises(NotImplementedError, match="wraparound"):
        frame_trunk_quad(x, w, b, w, b, cfg=sat)


# ---------------------------------------------------------------------------
# megakernel vs the composed FcnSweep cascade (the deployed pairing)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", FIXED_BACKENDS)
def test_sweep_maps_megakernel_vs_composed_112(params, frame112, backend):
    mega = sweep_feature_maps(params, frame112.pixels, backend=backend,
                              megakernel=True)
    comp = sweep_feature_maps(params, frame112.pixels, backend=backend,
                              megakernel=False)
    for name in ("interior", "last_row", "last_col", "corner"):
        _assert_words(mega[name], comp[name], f"{backend}/112/{name}")


@pytest.mark.parametrize("backend", FIXED_BACKENDS)
def test_sweep_scores_megakernel_vs_composed_112(params, frame112, backend):
    fb, pos = FcnSweep().extract(frame112)
    got = FcnSweep(megakernel=True).score(params, fb, backend=backend)
    want = FcnSweep(megakernel=False).score(params, fb, backend=backend)
    _assert_words(got, want, f"{backend}/scores")


@pytest.mark.parametrize("backend", FIXED_BACKENDS)
def test_sweep_maps_megakernel_vs_composed_512(params, backend):
    """Acceptance-bar scale: choose_tile splits 512x512 into 512x256 x2, so
    the megakernel words cross a real tile seam (and the frame border)."""
    assert choose_tile(512, 512) != (512, 512)  # must genuinely tile
    rng = np.random.default_rng(512)
    frame = rng.random((512, 512), np.float32)
    mega = sweep_feature_maps(params, frame, backend=backend,
                              megakernel=True)
    comp = sweep_feature_maps(params, frame, backend=backend,
                              megakernel=False)
    for name in ("interior", "last_row", "last_col", "corner"):
        _assert_words(mega[name], comp[name], f"{backend}/512/{name}")


def test_sweep_megakernel_through_forced_small_tiles(params, frame112,
                                                     monkeypatch):
    """The backend-hook route with choose_tile forced to 28x28: sixteen
    tiles, fifteen seams, still word-identical end-to-end scores.  A
    fresh backend NAME dodges the `_sweep_fn` lru_cache (frozen-dataclass
    equality would otherwise reuse the unforced program)."""
    monkeypatch.setattr(ft_ops, "choose_tile", lambda H, W, **kw: (28, 28))
    be = B.FixedBackend(name="fixed_seamtest")
    fb, pos = FcnSweep().extract(frame112)
    got = FcnSweep(megakernel=True).score(params, fb, backend=be)
    want = FcnSweep(megakernel=False).score(params, fb, backend="fixed")
    _assert_words(got, want, "forced-28x28-tiles/scores")


def test_megakernel_required_raises_on_ref(params, frame112):
    fb, pos = FcnSweep().extract(frame112)
    with pytest.raises(NotImplementedError, match="frame_trunk"):
        FcnSweep(megakernel=True).score(params, fb, backend="ref")


# ---------------------------------------------------------------------------
# conv_trunk fast path + launch topology
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", FIXED_BACKENDS)
def test_conv_trunk_fast_path_matches_composed(params, frame112, backend):
    """smallnet.conv_trunk routes single big frames through the megakernel
    hook; its output must be word-identical to the composed per-stage
    trunk (the quad's interior map IS the plain trunk)."""
    x = frame112.pixels[None].astype(np.float32)   # pixels are (H, W, 1)
    got = smallnet.conv_trunk(params, x, backend=backend)
    be = B.get_backend(backend)
    want = smallnet._conv_stages(be, be.prepare_params(params), x)
    _assert_words(got, want, f"{backend}/conv_trunk")


def test_trunk_launch_topology(params, frame112):
    """The whole point of the PR: ONE pallas_call per frame on the
    megakernel route; the composed fixed_pallas cascade stays many."""
    be = B.get_backend("fixed_pallas")
    p = be.prepare_params(params)
    frame = jnp.asarray(frame112.pixels[None], jnp.float32)
    n_mega = count_pallas_launches(
        lambda f: fs._trunk_quad(be, p, f, True), frame)
    n_comp = count_pallas_launches(
        lambda f: fs._trunk_quad(be, p, f, False), frame)
    assert n_mega == 1, f"megakernel trunk traced {n_mega} pallas_calls"
    assert n_comp > 10, f"composed cascade traced only {n_comp}"
    # the emulated backend megakernel route is also exactly one launch
    bef = B.get_backend("fixed")
    pf = bef.prepare_params(params)
    assert count_pallas_launches(
        lambda f: fs._trunk_quad(bef, pf, f, True), frame) == 1
