"""Hypothesis property battery: fixed_pallas kernels vs the numpy int64
oracle over adversarial word distributions.

The strategies deliberately mix uniform int32 words with max_int/min_int
and near-boundary values so two's-complement wraparound (and the saturate
decision) is exercised on every run — smooth-range inputs never hit the
wrap paths that distinguish a correct limb decomposition from a lucky one.

Tier-1 runs the bounded versions (`max_examples` small); the `slow`-marked
deep battery multiplies the example budget for local soak runs:

    pytest tests/test_fixed_pallas_props.py -m slow   # deep
    pytest -m "not slow"                              # bounded (CI)
"""
import pytest

hp = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
st = pytest.importorskip("hypothesis.strategies")
import jax.numpy as jnp
import numpy as np

from repro.core import fixed_point as fxp
from repro.kernels.fixed_conv import (fixed_conv2d, fixed_conv2d_ref,
                                      fixed_dense_ref, fixed_sigmoid,
                                      fixed_sigmoid_plan_ref)
from repro.kernels.quant_matmul import fixed_dense

# one canonical format/mode matrix (core/fixed_point.py) drives every battery
CFGS = list(fxp.STANDARD_CONFIGS.values())
_IDS = list(fxp.STANDARD_CONFIGS)


def _word_st(cfg):
    """Words biased toward the dangerous edges of the format."""
    edges = st.sampled_from([cfg.max_int, cfg.min_int, cfg.max_int - 1,
                             cfg.min_int + 1, -1, 0, 1])
    return st.one_of(st.integers(cfg.min_int, cfg.max_int), edges)


def _grid(cfg, h, w, b=1):
    return st.lists(st.lists(st.lists(_word_st(cfg), min_size=w, max_size=w),
                             min_size=h, max_size=h),
                    min_size=b, max_size=b)


def _i32(a):
    return jnp.asarray(np.asarray(a, np.int64), jnp.int32)


@pytest.mark.parametrize("cfg", CFGS, ids=_IDS)
@hp.given(data=st.data())
@hp.settings(max_examples=25, deadline=None)
def test_conv_pipeline_words_match_oracle(cfg, data):
    h = data.draw(st.integers(2, 6), label="H")
    w = data.draw(st.integers(2, 6), label="W")
    x = np.asarray(data.draw(_grid(cfg, h, w)), np.int64)
    w4 = np.asarray(data.draw(st.lists(_word_st(cfg), min_size=4, max_size=4)),
                    np.int64)
    b = data.draw(_word_st(cfg), label="bias")
    act = data.draw(st.sampled_from([None, "plan"]), label="act")
    got = fixed_conv2d(_i32(x), _i32(w4), jnp.int32(b), cfg=cfg,
                       activation=act)
    want = fixed_conv2d_ref(x, w4, b, cfg, activation=act)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)


@pytest.mark.parametrize("cfg", CFGS, ids=_IDS)
@hp.given(data=st.data())
@hp.settings(max_examples=25, deadline=None)
def test_sigmoid_words_match_oracle(cfg, data):
    x = np.asarray(
        data.draw(st.lists(_word_st(cfg), min_size=1, max_size=64)), np.int64)
    got = fixed_sigmoid(_i32(x), cfg=cfg)
    np.testing.assert_array_equal(np.asarray(got, np.int64),
                                  fixed_sigmoid_plan_ref(x, cfg))


@pytest.mark.parametrize("cfg", CFGS, ids=_IDS)
@hp.given(data=st.data())
@hp.settings(max_examples=25, deadline=None)
def test_dense_words_match_oracle(cfg, data):
    m = data.draw(st.integers(1, 5), label="M")
    k = data.draw(st.integers(1, 8), label="K")
    n = data.draw(st.integers(1, 6), label="N")
    flat = st.lists(_word_st(cfg), min_size=m * k + k * n + n,
                    max_size=m * k + k * n + n)
    v = np.asarray(data.draw(flat), np.int64)
    x, wgt, b = (v[:m * k].reshape(m, k), v[m * k:m * k + k * n].reshape(k, n),
                 v[m * k + k * n:])
    got = fixed_dense(_i32(x), _i32(wgt), _i32(b), cfg=cfg)
    np.testing.assert_array_equal(np.asarray(got, np.int64),
                                  fixed_dense_ref(x, wgt, b, cfg))


@hp.given(data=st.data())
@hp.settings(max_examples=50, deadline=None)
def test_emulated_and_pallas_agree_even_if_oracle_wrong(data):
    """Independent cross-check: the two jnp substrates agree with EACH OTHER
    on fresh random words (so a shared-oracle mistake can't mask a split)."""
    cfg = data.draw(st.sampled_from(CFGS), label="cfg")
    from repro.core import backends as B
    x = np.asarray(data.draw(_grid(cfg, 4, 4, b=2)), np.int64)
    w4 = np.asarray(data.draw(st.lists(_word_st(cfg), min_size=4, max_size=4)),
                    np.int64)
    b = data.draw(_word_st(cfg), label="bias")
    got = fixed_conv2d(_i32(x), _i32(w4), jnp.int32(b), cfg=cfg,
                       activation="plan", pool=True)
    emu = B.maxpool_fixed(fxp.fixed_sigmoid_plan(
        B.conv_fixed(_i32(x), _i32(w4), jnp.int32(b), cfg), cfg))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(emu))


@pytest.mark.slow
@pytest.mark.parametrize("cfg", CFGS, ids=_IDS)
@hp.given(data=st.data())
@hp.settings(max_examples=400, deadline=None)
def test_deep_conv_battery(cfg, data):
    """The soak version: same property, 16x the example budget."""
    h = data.draw(st.integers(2, 10), label="H")
    w = data.draw(st.integers(2, 10), label="W")
    x = np.asarray(data.draw(_grid(cfg, h, w, b=2)), np.int64)
    w4 = np.asarray(data.draw(st.lists(_word_st(cfg), min_size=4, max_size=4)),
                    np.int64)
    b = data.draw(_word_st(cfg), label="bias")
    act = data.draw(st.sampled_from([None, "plan"]), label="act")
    pool = data.draw(st.booleans(), label="pool")
    got = fixed_conv2d(_i32(x), _i32(w4), jnp.int32(b), cfg=cfg,
                       activation=act, pool=pool)
    want = fixed_conv2d_ref(x, w4, b, cfg, activation=act, pool=pool)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)
