"""End-to-end behaviour tests for the paper's system.

The paper's pipeline at system level: train float (Keras analogue) ->
extract + quantize -> deploy on the accelerator path -> validate accuracy
and latency; plus the framework-level training loop with checkpointing.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import deploy, smallnet
from repro.runtime import fault
from repro.runtime.trainer import Trainer, TrainerConfig


@pytest.mark.slow
def test_paper_pipeline_end_to_end():
    """train -> extract -> fixed-point bake -> classify: the full smallNet
    deployment flow of the paper, in one go."""
    res = deploy.train_smallnet(n_train=4000, n_test=600, epochs=10, seed=1)
    assert res.test_acc > 0.70
    qfix = smallnet.quantize_params_fixed(res.params)
    baked = deploy.bake(
        lambda q, x: smallnet.forward_fixed(q, x), qfix)
    from repro.data import synth_mnist
    x, y = synth_mnist.make_dataset(200, seed=9)
    pred = smallnet.predict(baked(jnp.asarray(x)))
    acc = float(jnp.mean(pred == jnp.asarray(y)))
    assert acc > 0.65                          # fixed-point deployed accuracy
    lat = deploy.measure_latency(smallnet.forward, res.params, batch=1, iters=5)
    assert lat < 1.0                            # sanity: sub-second inference


@pytest.mark.slow
def test_lm_training_loss_decreases():
    cfg = get_config("granite-3-2b").smoke()
    t = Trainer(cfg, TrainerConfig(total_steps=150, seq_len=64, global_batch=8,
                                   lr=1e-2, warmup_steps=10, log_every=100))
    state, history = t.run()
    first = np.mean(history[:5])
    last = np.mean(history[-5:])
    assert last < first - 1.0, (first, last)   # structured data is learnable


def test_watchdog_fires():
    import time
    with pytest.raises(fault.StepTimeout):
        with fault.StepWatchdog(timeout_s=0.2):
            time.sleep(1.0)


def test_straggler_detection():
    st = fault.StepStats(window=20, slo_factor=2.0)
    for _ in range(10):
        assert st.record(0.1) is False
    assert st.record(0.5) is True


def test_run_with_restarts_recovers(tmp_path):
    """Inject a crash mid-run; the loop must resume from the checkpoint and
    finish with the same step count."""
    from repro.checkpoint.ckpt import CheckpointManager
    mgr = CheckpointManager(tmp_path)
    calls = {"n": 0, "crashed": False}

    def make_state():
        return {"x": jnp.zeros(())}

    def train_one(state, step):
        calls["n"] += 1
        if step == 3 and not calls["crashed"]:
            calls["crashed"] = True
            raise RuntimeError("injected node failure")
        return {"x": state["x"] + 1.0}

    state, restarts = fault.run_with_restarts(
        make_state, train_one, mgr, total_steps=6, timeout_s=30.0)
    assert restarts == 1
    assert float(state["x"]) == 6.0            # all 6 steps applied exactly once
