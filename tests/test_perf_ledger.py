"""perf_ledger gates: `validate` (committed schema + MFU invariants),
`check` (symmetric row presence, launch topology, FPS band), ledger
discovery, and the cross-PR MFU delta report — all on synthetic ledgers,
no measurement."""
import copy
import json

from benchmarks.perf_ledger import (FPS_BAND, MFU_KEYS, ROW_KEYS,
                                    SCHEMA_VERSION, check, ledger_paths,
                                    mfu_deltas, newest_ledger, validate)


def _row(**kw):
    row = {"sustained_fps": 100.0, "latency_p50_ms": 5.0,
           "latency_p99_ms": 9.0, "drop_rate": 0.0,
           "trunk_launches_per_frame": 1, "program_launches_per_frame": 3,
           "model_flops_per_frame": 531848, "bytes_per_frame": 101468,
           "device_ms_per_frame": 2.0, "achieved_flops": 2.6e8,
           "achieved_bw": 5.0e7, "mfu": 0.4, "mfu_basis": "roofline_model"}
    row.update(kw)
    return row


def _ledger():
    composed = _row(trunk_launches_per_frame=33,
                    program_launches_per_frame=35,
                    model_flops_per_frame=523712,
                    bytes_per_frame=2231224, mfu=0.02)
    return {
        "config": {"schema_version": SCHEMA_VERSION, "frames": 16,
                   "seed": 7},
        "context": {"device": "cpu", "interpret": True},
        "rows": {
            "fixed": {"sweep_composed": copy.deepcopy(composed),
                      "sweep_megakernel": _row()},
            "ref": {"sweep_composed": copy.deepcopy(composed)},
        },
    }


def test_check_passes_on_identical():
    assert check(_ledger(), copy.deepcopy(_ledger())) == []


def test_validate_passes_on_wellformed():
    assert validate(_ledger()) == []


def test_validate_flags_schema_version_and_missing_columns():
    led = _ledger()
    led["config"]["schema_version"] = 1
    assert any("schema_version" in f for f in validate(led))
    led = _ledger()
    del led["rows"]["fixed"]["sweep_megakernel"]["mfu"]
    del led["rows"]["fixed"]["sweep_megakernel"]["bytes_per_frame"]
    fails = validate(led)
    assert any("missing columns" in f and "mfu" in f for f in fails)


def test_validate_flags_mfu_out_of_range():
    for bad in (0.0, -0.1, 1.5):
        led = _ledger()
        led["rows"]["ref"]["sweep_composed"]["mfu"] = bad
        assert any("outside (0, 1]" in f for f in validate(led)), bad
    led = _ledger()
    led["rows"]["ref"]["sweep_composed"]["mfu"] = 1.0   # inclusive top
    assert validate(led) == []


def test_validate_flags_megakernel_mfu_not_above_composed():
    led = _ledger()
    led["rows"]["fixed"]["sweep_megakernel"]["mfu"] = 0.01   # < composed
    assert any("worse-utilized" in f for f in validate(led))
    led["rows"]["fixed"]["sweep_megakernel"]["mfu"] = 0.02   # tie fails too
    assert any("worse-utilized" in f for f in validate(led))


def test_validate_flags_bad_basis_and_nonpositive_counts():
    led = _ledger()
    led["rows"]["ref"]["sweep_composed"]["mfu_basis"] = "vibes"
    assert any("unknown mfu_basis" in f for f in validate(led))
    led = _ledger()
    led["rows"]["ref"]["sweep_composed"]["bytes_per_frame"] = 0
    assert any("must be positive" in f for f in validate(led))


def test_check_flags_fresh_row_missing_from_ledger():
    committed, fresh = _ledger(), _ledger()
    del committed["rows"]["fixed"]["sweep_megakernel"]
    fails = check(committed, fresh)
    assert any("misses row fixed/sweep_megakernel" in f for f in fails)


def test_check_flags_committed_row_vanished_from_fresh():
    """Regression (one-sided check): a backend/route silently dropped from
    the measurement sweep used to pass --check."""
    committed, fresh = _ledger(), _ledger()
    del fresh["rows"]["fixed"]["sweep_megakernel"]
    fails = check(committed, fresh)
    assert any("fixed/sweep_megakernel vanished" in f for f in fails)
    # a whole backend vanishing is flagged too
    committed2, fresh2 = _ledger(), _ledger()
    del fresh2["rows"]["ref"]
    assert any("ref/sweep_composed vanished" in f
               for f in check(committed2, fresh2))


def test_check_flags_launch_topology_drift_and_fps_band():
    committed, fresh = _ledger(), _ledger()
    fresh["rows"]["fixed"]["sweep_megakernel"]["trunk_launches_per_frame"] = 2
    fails = check(committed, fresh)
    assert any("trunk_launches_per_frame changed 1 -> 2" in f for f in fails)
    assert any("megakernel trunk is 2 launches" in f for f in fails)
    committed, fresh = _ledger(), _ledger()
    fresh["rows"]["fixed"]["sweep_megakernel"]["sustained_fps"] = (
        FPS_BAND * 100.0 * 0.9)
    assert any("regressed past" in f for f in check(committed, fresh))


def test_check_flags_fresh_mfu_out_of_range():
    committed, fresh = _ledger(), _ledger()
    fresh["rows"]["ref"]["sweep_composed"]["mfu"] = 1.2
    assert any("freshly measured mfu" in f for f in check(committed, fresh))


def test_check_config_drift_short_circuits():
    committed, fresh = _ledger(), _ledger()
    committed["config"]["frames"] = 8
    fails = check(committed, fresh)
    assert len(fails) == 1 and "config drifted" in fails[0]


def test_ledger_discovery_and_committed_ledger_roundtrip():
    """The repo's own committed ledgers: discovery orders them by PR, the
    newest passes the full schema gate, and a write -> validate round-trip
    through JSON is idempotent."""
    paths = ledger_paths()
    assert [p.name for p in paths] == sorted(
        (p.name for p in paths),
        key=lambda n: int(n.split("_")[1].split(".")[0]))
    newest = newest_ledger()
    assert newest is not None and newest == paths[-1]
    led = json.loads(newest.read_text())
    assert validate(led) == []
    assert validate(json.loads(json.dumps(led))) == []
    for routes in led["rows"].values():
        for row in routes.values():
            assert all(k in row for k in ROW_KEYS + MFU_KEYS)


def test_mfu_deltas_report():
    prev, cur = _ledger(), _ledger()
    cur["rows"]["fixed"]["sweep_megakernel"]["mfu"] = 0.5
    lines = mfu_deltas(prev, cur)
    assert any("fixed/sweep_megakernel" in ln and "+25.0%" in ln
               for ln in lines)
    # a previous ledger without mfu columns degrades to "(no previous)"
    for routes in prev["rows"].values():
        for row in routes.values():
            del row["mfu"]
    assert all("no previous" in ln for ln in mfu_deltas(prev, cur))
    assert all("no previous" in ln for ln in mfu_deltas(None, cur))
