"""perf_ledger.check(): the gate must be symmetric — a row missing from
either side (committed ledger or fresh measurement) is a failure."""
import copy

from benchmarks.perf_ledger import FPS_BAND, check


def _ledger():
    row = {"sustained_fps": 100.0, "latency_p50_ms": 5.0,
           "latency_p99_ms": 9.0, "drop_rate": 0.0,
           "trunk_launches_per_frame": 1, "program_launches_per_frame": 3}
    composed = dict(row, trunk_launches_per_frame=33,
                    program_launches_per_frame=35)
    return {
        "config": {"frames": 16, "seed": 7},
        "rows": {
            "fixed": {"sweep_composed": copy.deepcopy(composed),
                      "sweep_megakernel": copy.deepcopy(row)},
            "ref": {"sweep_composed": copy.deepcopy(composed)},
        },
    }


def test_check_passes_on_identical():
    assert check(_ledger(), copy.deepcopy(_ledger())) == []


def test_check_flags_fresh_row_missing_from_ledger():
    committed, fresh = _ledger(), _ledger()
    del committed["rows"]["fixed"]["sweep_megakernel"]
    fails = check(committed, fresh)
    assert any("misses row fixed/sweep_megakernel" in f for f in fails)


def test_check_flags_committed_row_vanished_from_fresh():
    """Regression (one-sided check): a backend/route silently dropped from
    the measurement sweep used to pass --check."""
    committed, fresh = _ledger(), _ledger()
    del fresh["rows"]["fixed"]["sweep_megakernel"]
    fails = check(committed, fresh)
    assert any("fixed/sweep_megakernel vanished" in f for f in fails)
    # a whole backend vanishing is flagged too
    committed2, fresh2 = _ledger(), _ledger()
    del fresh2["rows"]["ref"]
    assert any("ref/sweep_composed vanished" in f
               for f in check(committed2, fresh2))


def test_check_flags_launch_topology_drift_and_fps_band():
    committed, fresh = _ledger(), _ledger()
    fresh["rows"]["fixed"]["sweep_megakernel"]["trunk_launches_per_frame"] = 2
    fails = check(committed, fresh)
    assert any("trunk_launches_per_frame changed 1 -> 2" in f for f in fails)
    assert any("megakernel trunk is 2 launches" in f for f in fails)
    committed, fresh = _ledger(), _ledger()
    fresh["rows"]["fixed"]["sweep_megakernel"]["sustained_fps"] = (
        FPS_BAND * 100.0 * 0.9)
    assert any("regressed past" in f for f in check(committed, fresh))


def test_check_config_drift_short_circuits():
    committed, fresh = _ledger(), _ledger()
    committed["config"]["frames"] = 8
    fails = check(committed, fresh)
    assert len(fails) == 1 and "config drifted" in fails[0]
