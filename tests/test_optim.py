"""Hand-rolled Adam: convergence, clipping-fold semantics, schedules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (AdamConfig, adam_init, adam_update,
                         clip_by_global_norm, cosine_schedule)


def test_adam_converges_quadratic():
    cfg = AdamConfig(lr=0.1, clip_norm=None)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = adam_init(params, cfg)
    loss = lambda p: jnp.sum(jnp.square(p["x"]))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adam_update(g, state, params, cfg)
    assert float(loss(params)) < 1e-3


def test_clip_fold_matches_explicit_clip():
    """Folded clip scale must equal clipping grads then updating."""
    params = {"w": jnp.asarray([1.0, 2.0, 3.0])}
    grads = {"w": jnp.asarray([10.0, -20.0, 5.0])}
    cfg = AdamConfig(lr=0.01, clip_norm=1.0)
    p1, s1, m1 = adam_update(grads, adam_init(params, cfg), params, cfg)

    clipped, gn = clip_by_global_norm(grads, 1.0)
    cfg2 = AdamConfig(lr=0.01, clip_norm=None)
    p2, s2, m2 = adam_update(clipped, adam_init(params, cfg2), params, cfg2)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]), rtol=1e-6)
    np.testing.assert_allclose(float(m1["grad_norm"]), float(gn), rtol=1e-6)


def test_layer_chunked_update_matches_unchunked():
    k = jax.random.key(0)
    params = {"stack": jax.random.normal(k, (6, 8, 4))}
    grads = {"stack": jax.random.normal(jax.random.fold_in(k, 1), (6, 8, 4))}
    c1 = AdamConfig(lr=0.1, layer_chunked=False)
    c2 = AdamConfig(lr=0.1, layer_chunked=True)
    p1, s1, _ = adam_update(grads, adam_init(params, c1), params, c1)
    p2, s2, _ = adam_update(grads, adam_init(params, c2), params, c2)
    np.testing.assert_allclose(np.asarray(p1["stack"]), np.asarray(p2["stack"]),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s1.mu["stack"]), np.asarray(s2.mu["stack"]),
                               rtol=1e-6)


def test_moment_dtype_bf16():
    cfg = AdamConfig(moment_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = adam_init(params, cfg)
    assert state.mu["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full((4,), 0.5, jnp.bfloat16)}
    p, s, _ = adam_update(g, state, params, cfg)
    assert p["w"].dtype == jnp.bfloat16
    assert s.nu["w"].dtype == jnp.bfloat16


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup_steps=10, total_steps=100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1e-3) < 1e-9
    assert float(lr(100)) < 1e-5
    # monotone decay after warmup
    vals = [float(lr(s)) for s in range(10, 101, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))
