"""int8 gradient compression: error bounds, error feedback, wire math."""
import json
import subprocess
import sys
import textwrap

import pytest

hp = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
st = pytest.importorskip("hypothesis.strategies")
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import compression as C


@hp.given(st.integers(0, 2**31 - 1), st.floats(0.01, 100.0))
@hp.settings(max_examples=50, deadline=None)
def test_block_quant_error_bound(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, scale, (4, C.BLOCK)), jnp.float32)
    q, s = C._quantize_block(x)
    deq = q.astype(jnp.float32) * s
    bound = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 / 2 + 1e-5
    assert bool(jnp.all(jnp.abs(deq - x) <= bound + 1e-6))


def test_error_feedback_reduces_bias(rng):
    g = {"w": jnp.asarray(rng.normal(0, 1, (512,)), jnp.float32)}
    # two rounds with feedback: total transmitted ~ g1+g2 with residual carry
    sent1, res = C.compression_error_feedback(g, None)
    sent2, res2 = C.compression_error_feedback(g, res)
    # the residual is exactly what quantization dropped
    for leaf, r in zip(jax.tree_util.tree_leaves(sent1),
                       jax.tree_util.tree_leaves(res)):
        assert float(jnp.max(jnp.abs(r))) <= float(jnp.max(jnp.abs(leaf))) / 127.0 + 1e-6


_PSUM = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.distributed.compression import make_compressed_allreduce

    mesh = jax.make_mesh((4,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 1, (1024,)), jnp.float32)
    ar = make_compressed_allreduce(mesh, axis="pod")
    out = ar({"g": g})["g"]          # every peer holds the same g -> mean = g
    err = float(jnp.max(jnp.abs(out - g)))
    print(json.dumps({"err": err}))
""")


def test_compressed_allreduce_subprocess():
    r = subprocess.run([sys.executable, "-c", _PSUM], capture_output=True,
                       text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"})
    assert r.returncode == 0, r.stderr[-2000:]
    err = json.loads(r.stdout.strip().splitlines()[-1])["err"]
    # quantize->sum->dequant of identical replicas: error <= one quant step
    assert err <= 4.0 / 127.0


def test_wire_bytes_ratio(rng):
    """Compression claim: int8 payload is ~4x smaller than f32."""
    x = jnp.asarray(rng.normal(0, 1, (4096,)), jnp.float32)
    q, s = C._quantize_block(x.reshape(-1, C.BLOCK))
    f32_bytes = x.size * 4
    wire = q.size * 1 + s.size * 4
    assert wire < f32_bytes / 3.5
