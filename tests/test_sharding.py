"""Sharding policy properties + subprocess multi-device compile/elastic tests.

The subprocess tests set XLA_FLAGS themselves (8 virtual devices) so the
rest of the suite keeps the 1-device default.
"""
import json
import subprocess
import sys
import textwrap

import pytest

hp = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.distributed import sharding as shd


def test_rules_divisibility_all_cells():
    """Every (arch x shape) cell must produce mesh-divisible specs for the
    dims the policy shards (the invariant the dry-run relies on)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for s in SHAPES.values():
            for axes in (("data", "model"), ("pod", "data", "model")):
                rules = shd.make_rules(
                    mesh_axes=axes, global_batch=s.global_batch,
                    n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                    decode=(s.kind == "decode"), seq_len=s.seq_len)
                if rules["batch"] == ("pod", "data"):
                    assert s.global_batch % 32 == 0
                elif rules["batch"] == ("data",):
                    assert s.global_batch % 16 == 0
                if rules["heads"] == "model":
                    assert cfg.n_heads % 16 == 0
                if rules["res_seq"] == "model":
                    assert s.seq_len % 16 == 0
                # dims the policy always shards over "model"
                assert cfg.d_model % 16 == 0
                assert cfg.head_dim % 16 == 0 or rules["cache_head_dim"] != "model" \
                    or cfg.head_dim in (64, 128)
                assert cfg.vocab_padded % 256 == 0


@hp.given(st.integers(1, 4096), st.integers(1, 256), st.integers(1, 256))
@hp.settings(max_examples=100, deadline=None)
def test_rules_batch_never_uneven(batch, heads, kv):
    rules = shd.make_rules(mesh_axes=("data", "model"), global_batch=batch,
                           n_heads=heads, n_kv_heads=kv, seq_len=64)
    if rules["batch"] is not None:
        assert batch % 16 == 0
    if rules["heads"] == "model":
        assert heads % 16 == 0


def test_constrain_noop_without_rules():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    y = shd.constrain(x, "batch", None)
    assert y is x


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, dataclasses
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.configs.base import get_config, ShapeSpec
    from repro.launch.lowering import lower_cell
    from repro.configs import base as cbase

    # shrink the production mesh to 2x4 for the smoke-scale compile
    import repro.launch.mesh as mesh_mod
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

    import repro.distributed.sharding as shd
    cfg = dataclasses.replace(get_config("granite-3-2b").smoke(),
                              d_model=64, micro_batch=4)
    shape = ShapeSpec("t", 64, 8, "train")
    cbase.SHAPES["t"] = shape
    def rules_for(cfg, shape, mesh):
        return {"batch": ("data",), "res_seq": "model", "seq": None,
                "heads": "model", "kv_heads": None, "head_dim": None,
                "qkv": "model", "ffn": "model", "vocab": "model",
                "experts": "model", "expert_group": ("data",),
                "cache_batch": ("data",), "cache_head_dim": "model",
                "fsdp": ("data",), "w_model": "model", "layers": None,
                "embed": None}
    import repro.launch.lowering as L
    L.rules_for = rules_for
    art = L.lower_cell("granite-3-2b", "t", mesh, cfg_override=cfg)
    ma = art.compiled.memory_analysis()
    print(json.dumps({"ok": True, "arg_bytes": int(ma.argument_size_in_bytes)}))
""")


def test_multi_device_compile_subprocess():
    r = subprocess.run([sys.executable, "-c", _SUBPROC], capture_output=True,
                       text=True, timeout=560,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"})
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"]


_ELASTIC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, tempfile
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.checkpoint.ckpt import save_checkpoint, restore_checkpoint

    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    d = tempfile.mkdtemp()
    save_checkpoint(d, 1, tree)              # saved unsharded ("mesh A")

    # "mesh B": restore sharded over 8 devices (elastic re-shard)
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    r = restore_checkpoint(d, tree, shardings=sh)
    ok = (r["w"].sharding == sh["w"]
          and bool(jnp.all(r["w"] == tree["w"])))
    print(json.dumps({"ok": bool(ok)}))
""")


def test_elastic_restore_subprocess():
    r = subprocess.run([sys.executable, "-c", _ELASTIC], capture_output=True,
                       text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"})
    assert r.returncode == 0, r.stderr[-2000:]
    assert json.loads(r.stdout.strip().splitlines()[-1])["ok"]


_INT8_LOWER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, dataclasses
    import jax
    from repro.configs.base import get_config, ShapeSpec
    from repro.configs import base as cbase
    import repro.launch.lowering as L

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = dataclasses.replace(get_config("granite-3-2b").smoke(), d_model=64)
    cbase.SHAPES["d"] = ShapeSpec("d", 64, 8, "decode")
    rules = {"batch": ("data",), "res_seq": None, "seq": None, "heads": "model",
             "kv_heads": None, "kv_seq": None, "head_dim": None, "qkv": "model",
             "ffn": "model", "vocab": "model", "experts": "model",
             "expert_group": ("data",), "cache_batch": ("data",),
             "cache_head_dim": "model", "cache_seq": "model",
             "fsdp": ("data",), "w_model": "model", "layers": None, "embed": None}
    L.rules_for = lambda cfg, shape, mesh: rules
    art = L.lower_cell("granite-3-2b", "d", mesh, cfg_override=cfg,
                       int8_serving=True)
    ma = art.compiled.memory_analysis()
    print(json.dumps({"ok": True, "args": int(ma.argument_size_in_bytes)}))
""")


def test_int8_serving_lowering_subprocess():
    """The paper's baked-int8 deployment compiles on a sharded mesh."""
    r = subprocess.run([sys.executable, "-c", _INT8_LOWER], capture_output=True,
                       text=True, timeout=560,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"})
    assert r.returncode == 0, r.stderr[-2000:]
    assert json.loads(r.stdout.strip().splitlines()[-1])["ok"]
