"""Deterministic host-sharded data pipeline (straggler/fault substrate)."""
import pytest

hp = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
st = pytest.importorskip("hypothesis.strategies")
import numpy as np

from repro.data import lm_data, synth_mnist


@hp.given(st.integers(0, 1000), st.integers(0, 50))
@hp.settings(max_examples=25, deadline=None)
def test_host_batch_deterministic(seed, step):
    cfg = lm_data.DataConfig(vocab=128, seq_len=16, global_batch=4, seed=seed)
    a = lm_data.host_batch(cfg, step)
    b = lm_data.host_batch(cfg, step)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_labels_are_shifted_tokens():
    cfg = lm_data.DataConfig(vocab=64, seq_len=8, global_batch=2)
    b = lm_data.host_batch(cfg, 0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_shard_replacement_property():
    """A replacement host regenerates exactly the failed host's shard."""
    full = lm_data.DataConfig(vocab=64, seq_len=8, global_batch=8, n_hosts=4,
                              host_index=2)
    original = lm_data.host_batch(full, step=17)
    replacement = lm_data.host_batch(
        lm_data.DataConfig(vocab=64, seq_len=8, global_batch=8, n_hosts=4,
                           host_index=2), step=17)
    np.testing.assert_array_equal(original["tokens"], replacement["tokens"])
    # a different host's shard differs
    other = lm_data.host_batch(
        lm_data.DataConfig(vocab=64, seq_len=8, global_batch=8, n_hosts=4,
                           host_index=3), step=17)
    assert not np.array_equal(original["tokens"], other["tokens"])


def test_tokens_in_vocab_range():
    cfg = lm_data.DataConfig(vocab=97, seq_len=32, global_batch=4)
    b = lm_data.host_batch(cfg, 3)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 97


def test_mnist_proxy_class_balance():
    _, labels = synth_mnist.make_dataset(2000, seed=0)
    counts = np.bincount(labels, minlength=10)
    assert counts.min() > 120        # roughly balanced
