"""Paper reproduction: smallNet architecture, training, and the accuracy
ladder float -> PLAN -> fixed-point -> int8 (paper §IV-C)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import deploy, smallnet
from repro.data import synth_mnist


@pytest.fixture(scope="module")
def trained():
    # small but real training run (module-scoped: shared across tests)
    return deploy.train_smallnet(n_train=6000, n_test=1200, epochs=14, seed=0)


def test_param_count_matches_paper():
    params = smallnet.init_params(jax.random.key(0))
    assert smallnet.param_count(params) == 510     # paper: "no more than 510"


def test_forward_shapes():
    params = smallnet.init_params(jax.random.key(0))
    x = jnp.zeros((5, 28, 28, 1), jnp.float32)
    scores = smallnet.forward(params, x)
    assert scores.shape == (5, 10)
    assert smallnet.predict(scores).shape == (5,)


@pytest.mark.slow
def test_training_reaches_deployable_accuracy(trained):
    # paper hardware threshold: 81 %; our MNIST-proxy target: comfortably above
    assert trained.test_acc >= 0.80, trained.test_acc


@pytest.mark.slow
def test_accuracy_ladder(trained):
    accs = deploy.evaluate_all_paths(trained.params, n_test=800)
    # fixed-point and int8 paths must stay within a few points of float —
    # the paper's float->fixed drop was 5.4 points at 32-bit
    assert accs["fixed_q16_16"] >= accs["float32"] - 0.06
    assert accs["int8_ptq"] >= accs["float32"] - 0.06
    # the paper's own exact->PLAN drop was 5.44 points (93.47 -> 88.03), so
    # a 4-point bound was stricter than the source hardware; allow the same
    # few-points envelope as the other quantized paths
    assert accs["float32_plan_sigmoid"] >= accs["float32"] - 0.06


@pytest.mark.slow
def test_fixed_path_is_integer_only(trained):
    qp = smallnet.quantize_params_fixed(trained.params)
    for leaf in jax.tree_util.tree_leaves(qp):
        assert leaf.dtype == jnp.int32
    x, _ = synth_mnist.make_dataset(4, seed=3)
    out = smallnet.forward_fixed(qp, jnp.asarray(x))
    assert out.dtype == jnp.int32


@pytest.mark.slow
def test_bake_constant_folds(trained):
    baked = deploy.bake(smallnet.forward, trained.params)
    x, _ = synth_mnist.make_dataset(4, seed=3)
    np.testing.assert_allclose(
        np.asarray(baked(jnp.asarray(x))),
        np.asarray(smallnet.forward(trained.params, jnp.asarray(x))),
        rtol=1e-6)


def test_dataset_determinism():
    a1, l1 = synth_mnist.make_dataset(32, seed=7)
    a2, l2 = synth_mnist.make_dataset(32, seed=7)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(l1, l2)
    assert a1.shape == (32, 28, 28, 1) and a1.min() >= 0 and a1.max() <= 1
