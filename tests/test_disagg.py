"""Disaggregated trunk/head serving (`repro/serving/disagg.py`).

Three layers of contract:

  * `FeatureMapCache` — bounded LRU + TTL with single-flight dedup: exact
    hit/miss/coalesced accounting (each call counts exactly one), capacity
    and TTL evictions by reason, one trunk pass per thundering herd, and
    leader-failure re-election (a crashed leader never wedges a key).
  * `DisaggServer` correctness — window scores word-exact vs the
    monolithic `FcnSweep` on both fixed substrates (same ints, same
    dtype), detection parity, the fleet ledger invariant
    `submitted == served + shed + pending`, trunk failover onto a healthy
    sibling, all-faulted and deadline/queue_depth shed paths, and the
    `StreamingPipeline` seam (the server slots in where the sweep runs).
  * The slow soak — concurrent streams against a started fleet with a
    mid-run trunk fault and a cache sized BELOW the distinct-frame pool
    (constant churn): every ledger reconciles, cache memory stays bounded
    by construction, and the flight recorder holds the whole run.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import smallnet
from repro.serving.disagg import (DisaggServer, DisaggShedError,
                                  FeatureMapCache, FeatureMapKey,
                                  feature_key, frame_digest)
from repro.streaming.fcn_sweep import FcnSweep
from repro.streaming.sources import RepeatedClipSource, SyntheticVideoSource

BACKENDS = ("fixed", "fixed_pallas")


@pytest.fixture(scope="module")
def params():
    return smallnet.seeded_params()


@pytest.fixture(scope="module")
def clip():
    return SyntheticVideoSource(n_frames=6, seed=7).frames()


def _key(i: int) -> FeatureMapKey:
    return FeatureMapKey(digest=f"k{i}", backend="fixed", cfg="q16.16",
                         megakernel=None, interpret=True)


def _quad(i: int):
    return tuple(np.full((2, 2), i + j, np.int32) for j in range(4))


# ---------------------------------------------------------------------------
# FeatureMapCache
# ---------------------------------------------------------------------------

class TestFeatureMapCache:
    def test_lru_capacity_eviction(self):
        c = FeatureMapCache(capacity=2)
        c.put(_key(0), _quad(0))
        c.put(_key(1), _quad(1))
        assert c.get(_key(0)) is not None       # 0 now most-recent
        c.put(_key(2), _quad(2))                # evicts 1 (LRU), not 0
        assert c.get(_key(1)) is None
        assert c.get(_key(0)) is not None
        assert len(c) == 2
        assert c.stats()["evictions"]["capacity"] == 1

    def test_ttl_expiry_is_lazy_and_counted(self):
        c = FeatureMapCache(capacity=4, ttl_s=0.02)
        c.put(_key(0), _quad(0))
        assert c.get(_key(0)) is not None
        time.sleep(0.03)
        assert c.get(_key(0)) is None
        s = c.stats()
        assert s["evictions"]["ttl"] == 1
        assert s["entries"] == 0

    def test_each_call_counts_exactly_one_outcome(self):
        c = FeatureMapCache(capacity=4)
        calls = []
        for _ in range(5):
            c.get_or_compute(_key(0), lambda: calls.append(1) or _quad(0))
        s = c.stats()
        assert len(calls) == 1
        assert s["misses"] == 1 and s["hits"] == 4 and s["coalesced"] == 0
        assert s["hit_rate"] == pytest.approx(0.8)

    def test_single_flight_one_trunk_pass_per_herd(self):
        c = FeatureMapCache(capacity=4)
        computes, gate = [], threading.Event()

        def compute():
            gate.wait(timeout=5.0)
            computes.append(1)
            return _quad(0)

        results = []
        threads = [threading.Thread(
            target=lambda: results.append(c.get_or_compute(_key(0), compute)))
            for _ in range(8)]
        for t in threads:
            t.start()
        time.sleep(0.05)          # let every follower park on the leader
        gate.set()
        for t in threads:
            t.join(timeout=10.0)
        s = c.stats()
        assert len(computes) == 1
        assert len(results) == 8
        assert s["misses"] == 1
        assert s["hits"] + s["coalesced"] == 7

    def test_leader_failure_wakes_followers_to_reelect(self):
        c = FeatureMapCache(capacity=4)
        attempts = []

        def flaky():
            attempts.append(1)
            if len(attempts) == 1:
                raise RuntimeError("leader died")
            return _quad(0)

        errors, values = [], []

        def call():
            try:
                values.append(c.get_or_compute(_key(0), flaky))
            except RuntimeError as e:
                errors.append(e)

        threads = [threading.Thread(target=call) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=10.0)
        # exactly one caller saw the crash; the rest re-elected and served
        assert len(errors) == 1
        assert len(values) == 3
        assert len(attempts) == 2

    def test_follower_timeout_raises(self):
        c = FeatureMapCache(capacity=4)
        started = threading.Event()

        def slow():
            started.set()
            time.sleep(0.5)
            return _quad(0)

        leader = threading.Thread(
            target=lambda: c.get_or_compute(_key(0), slow))
        leader.start()
        assert started.wait(timeout=5.0)
        with pytest.raises(TimeoutError):
            c.get_or_compute(_key(0), slow, timeout=0.02)
        leader.join(timeout=10.0)

    def test_bytes_gauge_tracks_resident_quads(self):
        c = FeatureMapCache(capacity=2)
        c.put(_key(0), _quad(0))
        per_entry = c.stats()["resident_bytes"]
        assert per_entry == sum(m.nbytes for m in _quad(0))
        c.put(_key(1), _quad(1))
        c.put(_key(2), _quad(2))      # capacity eviction keeps bytes flat
        s = c.stats()
        assert s["resident_bytes"] == 2 * per_entry
        assert s["resident_bytes_hwm"] <= 2 * per_entry


# ---------------------------------------------------------------------------
# Cache keying
# ---------------------------------------------------------------------------

def test_feature_key_separates_every_word_axis(clip):
    from repro.core import backends as B
    px = clip[0].pixels[None]
    fixed, ref = B.get_backend("fixed"), B.get_backend("ref")
    k = feature_key(px, fixed, None)
    assert k != feature_key(px, ref, None)             # backend axis
    assert k != feature_key(px, fixed, True)           # megakernel route
    assert k != feature_key(clip[1].pixels[None], fixed, None)  # pixels
    assert k == feature_key(np.array(px), fixed, None)  # content, not id


def test_frame_digest_covers_shape_and_dtype():
    a = np.zeros((1, 8, 8, 1), np.float32)
    assert frame_digest(a) == frame_digest(a.copy())
    assert frame_digest(a) != frame_digest(a.reshape(1, 4, 16, 1))
    assert frame_digest(a) != frame_digest(a.astype(np.float64))


# ---------------------------------------------------------------------------
# DisaggServer: word-exactness + ledger
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_scores_word_exact_vs_monolithic_sweep(params, clip, backend):
    import jax
    sweep = FcnSweep(stride=8)
    srv = DisaggServer(params, backend=backend, stride=8, cache_capacity=8)
    for f in clip[:3]:
        mono = np.asarray(jax.device_get(
            sweep.score(params, f.pixels[None], backend=backend)))
        dis = np.asarray(srv.score_frame(f.pixels[None]))
        assert dis.dtype == mono.dtype
        assert np.array_equal(dis, mono)
        assert sweep.aggregate(mono, list(srv.positions)) \
            == srv.detect(f, tiler=sweep)


def test_repeat_queries_hit_the_cache_and_ledger_reconciles(params, clip):
    srv = DisaggServer(params, backend="fixed", stride=8, cache_capacity=8)
    first = srv.score_frame(clip[0].pixels[None])
    again = srv.score_frame(clip[0].pixels[None])
    assert np.array_equal(first, again)
    s = srv.stats()
    assert s["accounted"] and s["n"] == 2 and s["shed"] == 0
    assert s["cache"]["hits"] == 1 and s["cache"]["misses"] == 1
    # the hit ran NO trunk pass: only one stage request reached the pool
    trunk_served = sum(s["per_stage"][e.name]["n"] for e in srv.trunks)
    assert trunk_served == 1


def test_trunk_failover_to_healthy_sibling(params, clip):
    srv = DisaggServer(params, backend="fixed", stride=8, n_trunk=2)
    boom = RuntimeError("injected trunk fault")
    srv.trunks[0]._compute = lambda payload: (_ for _ in ()).throw(boom)
    for f in clip[:4]:
        scores = srv.score_frame(f.pixels[None])
        assert scores.shape[0] == len(srv.positions)
    s = srv.stats()
    assert s["accounted"] and s["n"] == 4 and s["shed"] == 0
    faults = sum(st["shed_by_reason"].get("fault", 0)
                 for st in s["per_stage"].values())
    assert faults >= 1, "the faulty trunk was never exercised"


def test_all_trunks_faulted_sheds_with_reason(params, clip):
    srv = DisaggServer(params, backend="fixed", stride=8, n_trunk=2)
    for eng in srv.trunks:
        eng._compute = lambda payload: (_ for _ in ()).throw(
            RuntimeError("boom"))
    with pytest.raises(DisaggShedError) as ei:
        srv.score_frame(clip[0].pixels[None])
    assert ei.value.reason == "fault"
    s = srv.stats()
    assert s["accounted"] and s["shed_by_reason"] == {"fault": 1}


def test_deadline_shed_under_trunk_backpressure(params, clip):
    srv = DisaggServer(params, backend="fixed", stride=8, n_trunk=1,
                       trunk_floor_s=0.2)
    with pytest.raises(DisaggShedError) as ei:
        srv.score_frame(clip[0].pixels[None], deadline_ms=1.0)
    assert ei.value.reason == "deadline"
    assert srv.stats()["shed_by_reason"] == {"deadline": 1}


def test_open_loop_intake_bound_sheds_queue_depth(params, clip):
    srv = DisaggServer(params, backend="fixed", stride=8, max_queue=2)
    uids = [srv.submit(clip[i % len(clip)].pixels) for i in range(5)]
    shed = srv.pop_shed(uids)
    assert list(shed.values()) == ["queue_depth"] * 3
    srv.start()
    try:
        srv.wait([u for u in uids if u not in shed], timeout=30.0)
    finally:
        srv.stop(drain=True)
    s = srv.stats()
    assert s["accounted"] and s["n"] == 2


def test_open_loop_matches_sync_scores(params, clip):
    srv = DisaggServer(params, backend="fixed", stride=8)
    srv.start()
    try:
        uids = [srv.submit(f.pixels) for f in clip[:3]]
        srv.wait(uids, timeout=30.0)
        res = srv.pop_results(uids)
    finally:
        srv.stop(drain=True)
    ref = DisaggServer(params, backend="fixed", stride=8)
    for uid, f in zip(uids, clip[:3]):
        assert np.array_equal(res[uid].scores, ref.score_frame(f.pixels[None]))


def test_streaming_pipeline_drives_the_disagg_server(params):
    from repro.streaming.pipeline import StreamingPipeline
    base = SyntheticVideoSource(n_frames=4, seed=7)
    source = RepeatedClipSource(base, repeats=3)
    sweep = FcnSweep(stride=8, threshold=0.5)
    srv = DisaggServer(params, backend="fixed", stride=8, cache_capacity=8)
    pipe = StreamingPipeline(source, srv, sweep)
    results = pipe.run()
    ps, ss = pipe.stats(), srv.stats()
    assert len(results) == len(source)
    assert ps["accounted"] and ps["frames_served"] == len(source)
    assert ss["accounted"] and ss["n"] == len(source)
    assert ss["cache"]["hit_rate"] == pytest.approx(2 / 3)
    # repeated frames must produce identical detections
    by_px = {}
    for f, r in zip(source, results):
        key = frame_digest(f.pixels)
        by_px.setdefault(key, r.detections)
        assert by_px[key] == r.detections


# ---------------------------------------------------------------------------
# The soak: streams + failover + cache churn, every ledger tight
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_disagg_soak_streams_failover_and_cache_churn(params):
    from repro.obs import trace as T
    n_streams, per_stream = 4, 120
    pool = [f.pixels[None]
            for f in SyntheticVideoSource(n_frames=12, seed=7).frames()]
    capacity = 6            # BELOW the distinct pool: constant churn
    tr = T.enable(capacity=1 << 16)
    try:
        srv = DisaggServer(params, backend="fixed", stride=8,
                           n_trunk=2, n_head=2, cache_capacity=capacity)
        per_entry = srv.cache._nbytes(srv._run_trunk(pool[0]))
        for eng in srv.trunks + srv.heads:
            eng.start()
        client_shed = [0] * n_streams

        def stream(sid: int):
            rng = np.random.default_rng(sid)
            for i in range(per_stream):
                px = pool[int(rng.integers(0, len(pool)))]
                try:
                    srv.score_frame(px)
                except DisaggShedError:
                    client_shed[sid] += 1
                if sid == 0 and i == per_stream // 3:
                    # mid-run fault: one trunk replica dies; the fleet
                    # fails over and keeps serving
                    srv.trunks[0]._compute = lambda p: (_ for _ in ()).throw(
                        RuntimeError("injected soak fault"))

        threads = [threading.Thread(target=stream, args=(i,))
                   for i in range(n_streams)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300.0)
        assert not any(t.is_alive() for t in threads)
        srv.stop(drain=True)

        s = srv.stats()
        # the fleet ledger reconciles exactly, client-side view included
        assert s["accounted"]
        assert s["submitted"] == n_streams * per_stream
        assert s["n"] + s["shed"] == s["submitted"] and s["pending"] == 0
        assert s["shed"] == sum(client_shed)
        assert s["n"] >= 0.9 * s["submitted"], s["shed_by_reason"]
        for name, st in s["per_stage"].items():
            assert st["accounted"], (name, st)
        # cache memory bounded by construction, with real churn observed
        cs = s["cache"]
        assert cs["entries"] <= capacity
        assert cs["resident_bytes_hwm"] <= capacity * per_entry
        assert cs["evictions"]["capacity"] > 0
        assert cs["hits"] > 0
        # the flight recorder held the whole run
        assert tr.recorder.evicted == 0
    finally:
        T.disable()
