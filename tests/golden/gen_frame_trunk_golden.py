"""Generator for tests/golden/frame_trunk_golden.json — run once, commit.

    PYTHONPATH=src python tests/golden/gen_frame_trunk_golden.py

Freezes the megakernel trunk's level-2 role-map quad (interior / last_row /
last_col / corner, 28x28 int32 words each) over the deterministic 112x112
synthetic frame (SyntheticVideoSource seed 7, frame 0) with the seeded
benchmark params, in BOTH deployed formats: Q16.16 and Q8.8.  Generation
cross-checks four independent routes per format and fails loudly on any
disagreement:

  * the one-launch megakernel on the emulated "fixed" backend vs on
    "fixed_pallas" (same kernel, both substrate plumbings);
  * the megakernel vs the composed per-stage FcnSweep cascade
    (megakernel=False — the decomposition the frozen sweep_golden.json
    already pins);
  * the megakernel vs the untiled numpy int64 oracle
    (kernels/frame_trunk/ref.py), which knows nothing about tiles, halos,
    or DMA offsets.

So the frozen vectors pin the megakernel's tiling/halo bookkeeping against
vectors that cannot silently regenerate themselves — the CI golden job
rebuilds this file and diffs it, exactly like sweep_golden.json.
"""
from __future__ import annotations

import json
import pathlib

import numpy as np

from repro.core import backends as B
from repro.core import fixed_point as fxp
from repro.core import smallnet
from repro.kernels.frame_trunk.ref import frame_trunk_quad_ref
from repro.streaming.fcn_sweep import sweep_feature_maps
from repro.streaming.sources import SyntheticVideoSource

MAPS = ("interior", "last_row", "last_col", "corner")
FORMATS = {"q16_16": fxp.Q16_16, "q8_8": fxp.Q8_8}


def _check_equal(name, a, b):
    if not np.array_equal(np.asarray(a, np.int64), np.asarray(b, np.int64)):
        raise SystemExit(f"substrate drift while generating {name!r}")
    return np.asarray(a, np.int64)


def main() -> None:
    params = smallnet.seeded_params()
    frame = SyntheticVideoSource(n_frames=1, seed=7).frames()[0]

    out = {
        "frame": {"source": "SyntheticVideoSource(n_frames=1, seed=7)",
                  "index": 0, "shape": [112, 112]},
        "maps": {},
    }
    for fmt, cfg in FORMATS.items():
        be = B.FixedBackend(name=f"fixed_{fmt}", cfg=cfg)
        bp = B.FixedPallasBackend(name=f"fixed_pallas_{fmt}", cfg=cfg)
        mega = sweep_feature_maps(params, frame.pixels, backend=be,
                                  megakernel=True)
        mega_p = sweep_feature_maps(params, frame.pixels, backend=bp,
                                    megakernel=True)
        comp = sweep_feature_maps(params, frame.pixels, backend=be,
                                  megakernel=False)

        p = be.prepare_params(params)
        x = np.asarray(be.ingest(np.asarray(frame.pixels, np.float32)[None]))
        oracle = frame_trunk_quad_ref(x[0], np.asarray(p["conv1"]["w"]),
                                      np.asarray(p["conv1"]["b"]),
                                      np.asarray(p["conv2"]["w"]),
                                      np.asarray(p["conv2"]["b"]), cfg)

        out["maps"][fmt] = {}
        for k, name in enumerate(MAPS):
            words = _check_equal(f"{fmt}/{name} (fixed vs fixed_pallas)",
                                 mega[name], mega_p[name])
            _check_equal(f"{fmt}/{name} (megakernel vs composed)",
                         words, comp[name])
            _check_equal(f"{fmt}/{name} (megakernel vs numpy oracle)",
                         words, oracle[k])
            out["maps"][fmt][name] = words.tolist()

    path = pathlib.Path(__file__).parent / "frame_trunk_golden.json"
    path.write_text(json.dumps(out, indent=1) + "\n")
    print(f"wrote {path} ({path.stat().st_size} bytes)")


if __name__ == "__main__":
    main()
