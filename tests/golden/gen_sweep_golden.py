"""Generator for tests/golden/sweep_golden.json — run once, commit the JSON.

    PYTHONPATH=src python tests/golden/gen_sweep_golden.py

Freezes the Q16.16 words of the FCN sweep trunk over one deterministic
112x112 synthetic frame (SyntheticVideoSource seed 7, frame 0) with the
seeded benchmark params: all four pooled role maps (interior / last_row /
last_col / corner, 28x28 int32 each) plus the (144, 10) window-score words
of the stride-8 sweep.  Generation cross-checks three substrates and fails
loudly on any disagreement:

  * the emulated "fixed" sweep vs the "fixed_pallas" kernel sweep
    (word-for-word on every map and score), and
  * the sweep scores vs the host Tiler's patch-extract-and-score path on
    the same window lattice — the independent patch-wise semantics that
    the quad cascade must reproduce.

So the frozen vectors pin the sweep's padding/edge arithmetic itself, not
just one implementation of it.  The CI golden job regenerates this file
and diffs it, exactly like fixed_golden.json.
"""
from __future__ import annotations

import json
import pathlib

import numpy as np

from repro.core import smallnet
from repro.streaming.fcn_sweep import FcnSweep, sweep_feature_maps
from repro.streaming.sources import SyntheticVideoSource
from repro.streaming.tiler import Tiler

STRIDE = 8
MAPS = ("interior", "last_row", "last_col", "corner")


def _check_equal(name, a, b):
    if not np.array_equal(np.asarray(a, np.int64), np.asarray(b, np.int64)):
        raise SystemExit(f"substrate drift while generating {name!r}")
    return np.asarray(a, np.int64)


def main() -> None:
    params = smallnet.seeded_params()
    frame = SyntheticVideoSource(n_frames=1, seed=7).frames()[0]

    maps = {}
    # megakernel=False pins the COMPOSED per-stage decomposition itself —
    # the one-launch frame_trunk route has its own frozen vectors
    # (frame_trunk_golden.json), so each route is pinned independently
    by_backend = {b: sweep_feature_maps(params, frame.pixels, backend=b,
                                        megakernel=False)
                  for b in ("fixed", "fixed_pallas")}
    for name in MAPS:
        maps[name] = _check_equal(f"map/{name}",
                                  by_backend["fixed"][name],
                                  by_backend["fixed_pallas"][name]).tolist()

    sweep = FcnSweep(stride=STRIDE, megakernel=False)
    fb, pos = sweep.extract(frame)
    scores = _check_equal("scores",
                          sweep.score(params, fb, backend="fixed"),
                          sweep.score(params, fb, backend="fixed_pallas"))
    tiler = Tiler(stride=STRIDE)
    tiles, pos_t = tiler.extract(frame)
    assert pos == pos_t
    patch_scores = tiler.score(params, tiles, backend="fixed")
    _check_equal("scores vs host tiler", scores, patch_scores)

    out = {
        "frame": {"source": "SyntheticVideoSource(n_frames=1, seed=7)",
                  "index": 0, "shape": [112, 112]},
        "format": "q16_16", "stride": STRIDE,
        "positions": [list(p) for p in pos],
        "maps": maps,
        "scores": scores.tolist(),
    }
    path = pathlib.Path(__file__).parent / "sweep_golden.json"
    path.write_text(json.dumps(out, indent=1) + "\n")
    print(f"wrote {path} ({path.stat().st_size} bytes)")


if __name__ == "__main__":
    main()
