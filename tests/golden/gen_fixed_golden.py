"""Generator for tests/golden/fixed_golden.json — run once, commit the JSON.

    PYTHONPATH=src python tests/golden/gen_fixed_golden.py

The frozen vectors are produced by the NUMPY INT64 ORACLE
(`kernels/fixed_conv/ref.py`), not by the jnp implementations under test,
and cross-checked at generation time against both the emulated "fixed"
path and the fixed_pallas kernels — a generation run fails loudly if any
substrate disagrees.  Inputs are deterministic (seeded) with max_int /
min_int words injected so the frozen outputs actually pin wraparound (and
the saturation decision), not just smooth-range arithmetic.
"""
from __future__ import annotations

import json
import pathlib
import zlib

import numpy as np

import jax.numpy as jnp

from repro.core import backends as B
from repro.core import fixed_point as fxp
from repro.kernels.fixed_conv import (fixed_conv2d, fixed_conv2d_ref,
                                      fixed_dense_ref, fixed_maxpool2x2,
                                      fixed_maxpool2x2_ref, fixed_sigmoid,
                                      fixed_sigmoid_plan_ref)
from repro.kernels.fixed_conv.ref import random_words
from repro.kernels.quant_matmul import fixed_dense

CONFIGS = fxp.STANDARD_CONFIGS


def _words(rng, shape, cfg, extremes=4):
    # extremes=4 kept (not random_words' default) so regeneration stays
    # byte-identical to the frozen vectors
    return random_words(rng, shape, cfg, extremes)


def _check(name, *arrays):
    first = np.asarray(arrays[0], np.int64)
    for a in arrays[1:]:
        if not np.array_equal(first, np.asarray(a, np.int64)):
            raise SystemExit(f"substrate drift while generating {name!r}")
    return first


def make_case(cfg: fxp.FixedPointConfig, rng) -> dict:
    j32 = lambda a: jnp.asarray(np.asarray(a), jnp.int32)
    case = {}

    # --- conv (pre-activation) and the fully fused conv+PLAN+pool stage ---
    x = _words(rng, (2, 6, 6), cfg)
    w4 = _words(rng, (4,), cfg, extremes=1)
    b = int(_words(rng, (1,), cfg, extremes=0)[0])
    conv_out = _check(
        "conv",
        fixed_conv2d_ref(x, w4, b, cfg),
        B.conv_fixed(j32(x), j32(w4), jnp.int32(b), cfg),
        fixed_conv2d(j32(x), j32(w4), jnp.int32(b), cfg=cfg))
    fused_out = _check(
        "fused_conv_plan_pool",
        fixed_conv2d_ref(x, w4, b, cfg, activation="plan", pool=True),
        B.maxpool_fixed(fxp.fixed_sigmoid_plan(
            B.conv_fixed(j32(x), j32(w4), jnp.int32(b), cfg), cfg)),
        fixed_conv2d(j32(x), j32(w4), jnp.int32(b), cfg=cfg,
                     activation="plan", pool=True))
    case["conv"] = {"x": x.tolist(), "w4": w4.tolist(), "b": b,
                    "out": conv_out.tolist(),
                    "out_fused_plan_pool": fused_out.tolist()}

    # --- standalone maxpool ---
    xp = _words(rng, (2, 4, 4), cfg)
    pool_out = _check(
        "pool",
        fixed_maxpool2x2_ref(xp),
        B.maxpool_fixed(j32(xp)),
        fixed_maxpool2x2(j32(xp)))
    case["pool"] = {"x": xp.tolist(), "out": pool_out.tolist()}

    # --- PLAN sigmoid: all four segments, both signs, extremes ---
    seg = np.asarray([0.0, 0.5, -0.5, 1.0, -1.0, 1.7, -1.7, 2.375, -2.375,
                      3.3, -3.3, 5.0, -5.0, 9.9, -9.9], np.float32)
    xs = np.concatenate([np.asarray(fxp.to_fixed(seg, cfg), np.int64),
                         _words(rng, (9,), cfg)]).reshape(4, 6)
    sig_out = _check(
        "sigmoid",
        fixed_sigmoid_plan_ref(xs, cfg),
        fxp.fixed_sigmoid_plan(j32(xs), cfg),
        fixed_sigmoid(j32(xs), cfg=cfg))
    case["sigmoid"] = {"x": xs.tolist(), "out": sig_out.tolist()}

    # --- dense MAC array ---
    xd = _words(rng, (3, 8), cfg)
    wd = _words(rng, (8, 5), cfg)
    bd = _words(rng, (5,), cfg, extremes=1)
    dense_out = _check(
        "dense",
        fixed_dense_ref(xd, wd, bd, cfg),
        fxp.fixed_add(fxp.fixed_matmul(j32(xd), j32(wd), cfg),
                      j32(bd).reshape(1, -1), cfg),
        fixed_dense(j32(xd), j32(wd), j32(bd), cfg=cfg))
    case["dense"] = {"x": xd.tolist(), "w": wd.tolist(), "b": bd.tolist(),
                     "out": dense_out.tolist()}
    return case


def main() -> None:
    out = {"configs": {}, "cases": {}}
    for name, cfg in CONFIGS.items():
        out["configs"][name] = {
            "total_bits": cfg.total_bits, "frac_bits": cfg.frac_bits,
            "saturate": cfg.saturate, "round_nearest": cfg.round_nearest}
        # independent but deterministic stream per config (crc32, not
        # Python's randomized str hash)
        out["cases"][name] = make_case(
            cfg, np.random.default_rng(zlib.crc32(name.encode())))
    path = pathlib.Path(__file__).parent / "fixed_golden.json"
    path.write_text(json.dumps(out, indent=1) + "\n")
    print(f"wrote {path} ({path.stat().st_size} bytes)")


if __name__ == "__main__":
    main()
