"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conv2d import conv2d, conv2d_ref
from repro.kernels.maxpool2d import maxpool2d, maxpool2d_ref
from repro.kernels.quant_matmul import quant_matmul, quant_matmul_ref
from repro.kernels.sigmoid_pla import sigmoid_pla, sigmoid_pla_ref


@pytest.mark.parametrize("B,H,W,ci,co,kh,kw,pad,sig,stride", [
    (2, 28, 28, 1, 1, 2, 2, "SAME", True, 1),     # smallNet conv1
    (2, 14, 14, 1, 1, 2, 2, "SAME", True, 1),     # smallNet conv2
    (1, 16, 16, 3, 8, 3, 3, "SAME", False, 1),
    (3, 16, 12, 4, 4, 2, 2, "VALID", False, 1),
    (1, 32, 32, 2, 6, 5, 5, "SAME", False, 2),
    (2, 8, 8, 8, 16, 1, 1, "VALID", False, 1),
])
def test_conv2d_vs_ref(B, H, W, ci, co, kh, kw, pad, sig, stride, rng):
    x = jnp.asarray(rng.normal(size=(B, H, W, ci)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(kh, kw, ci, co)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(co,)), jnp.float32)
    got = conv2d(x, w, b, padding=pad, apply_sigmoid=sig, stride=stride)
    want = conv2d_ref(x, w, b, padding=pad, apply_sigmoid=sig, stride=stride)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("M,K,N", [
    (64, 49, 10),        # smallNet dense
    (256, 512, 256),     # aligned
    (100, 300, 70),      # unaligned -> wrapper pads
    (8, 128, 8),
    (513, 257, 129),
])
def test_quant_matmul_vs_ref(M, K, N, rng):
    xq = jnp.asarray(rng.integers(-127, 128, (M, K)), jnp.int8)
    wq = jnp.asarray(rng.integers(-127, 128, (K, N)), jnp.int8)
    sx = jnp.asarray(rng.uniform(0.01, 0.1, (M,)), jnp.float32)
    sw = jnp.asarray(rng.uniform(0.01, 0.1, (N,)), jnp.float32)
    got = quant_matmul(xq, wq, sx, sw)
    want = quant_matmul_ref(xq, wq, sx, sw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_quant_matmul_int32_exactness(rng):
    # accumulation must be exact int32 (no float roundoff): compare against
    # numpy int64 accumulation
    xq = rng.integers(-127, 128, (32, 1024)).astype(np.int8)
    wq = rng.integers(-127, 128, (1024, 16)).astype(np.int8)
    got = np.asarray(quant_matmul(jnp.asarray(xq), jnp.asarray(wq), 1.0, 1.0))
    want = (xq.astype(np.int64) @ wq.astype(np.int64)).astype(np.float64)
    np.testing.assert_array_equal(got.astype(np.int64), want.astype(np.int64))


@pytest.mark.parametrize("shape", [(7,), (33, 5), (2, 3, 4, 5), (1000,), (256, 128)])
@pytest.mark.parametrize("scale", [0.1, 4.0, 20.0])
def test_sigmoid_pla_vs_ref(shape, scale, rng):
    x = jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)
    np.testing.assert_allclose(np.asarray(sigmoid_pla(x)),
                               np.asarray(sigmoid_pla_ref(x)),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("B,H,W,C", [(2, 28, 28, 1), (1, 14, 14, 1),
                                     (2, 15, 9, 2), (3, 8, 8, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_maxpool2d_vs_ref(B, H, W, C, dtype, rng):
    x = jnp.asarray(rng.normal(size=(B, H, W, C)), dtype)
    got = maxpool2d(x)
    want = maxpool2d_ref(x)
    assert got.dtype == want.dtype
    np.testing.assert_array_equal(np.asarray(got.astype(jnp.float32)),
                                  np.asarray(want.astype(jnp.float32)))


def test_conv2d_vmem_guard():
    x = jnp.zeros((1, 1024, 1024, 8), jnp.float32)
    w = jnp.zeros((3, 3, 8, 8), jnp.float32)
    with pytest.raises(ValueError, match="VMEM"):
        conv2d(x, w)


@pytest.mark.parametrize("activation", [None, "sigmoid", "plan"])
def test_conv2d_fused_activation_epilogue(activation, rng):
    # smallNet conv1 shape with each fused epilogue vs the composed oracle
    x = jnp.asarray(rng.normal(size=(2, 28, 28, 1)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(2, 2, 1, 1)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(1,)), jnp.float32)
    got = conv2d(x, w, b, activation=activation)
    want = conv2d_ref(x, w, b, activation=activation)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_conv2d_bad_activation_rejected():
    x = jnp.zeros((1, 8, 8, 1), jnp.float32)
    w = jnp.zeros((2, 2, 1, 1), jnp.float32)
    with pytest.raises(ValueError, match="activation"):
        conv2d(x, w, activation="relu")


def test_conv2d_stride2_vmem_budgets_strided_output():
    """Strides are realized NATIVELY (only kept rows/columns are MAC'd), so
    the VMEM budget covers just the strided output: a shape whose stride-1
    output would blow the budget fits comfortably at stride 2."""
    x = jnp.zeros((1, 512, 512, 1), jnp.float32)
    w = jnp.zeros((2, 2, 1, 16), jnp.float32)
    # stride-1 output 512*512*16*4 B ~= 16.8 MB > 14 MB budget...
    with pytest.raises(ValueError, match="strided output"):
        conv2d(x, w, stride=1)
    # ...but the stride-2 output is only ~4.2 MB, so the SAME image now runs
    y = conv2d(x, w, stride=2)
    assert y.shape == (1, 256, 256, 16)


def test_conv2d_stride2_large_frame_matches_ref(rng):
    """The natively-strided kernel on a streaming-tiler-sized frame agrees
    with the decimate-a-stride-1-output oracle."""
    x = jnp.asarray(rng.normal(size=(1, 112, 112, 1)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(2, 2, 1, 4)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(4,)), jnp.float32)
    for stride in (2, 3, 4):
        got = conv2d(x, w, b, stride=stride)
        want = conv2d_ref(x, w, b, stride=stride)
        assert got.shape == want.shape
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_conv2d_stride2_small_shape_still_exact(rng):
    x = jnp.asarray(rng.normal(size=(2, 12, 10, 3)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(2, 2, 3, 4)), jnp.float32)
    got = conv2d(x, w, stride=2)
    want = conv2d_ref(x, w, stride=2)
    assert got.shape == (2, 6, 5, 4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# frame-extent generalization (the FCN sweep runs whole frames, not 28x28)
# ---------------------------------------------------------------------------

def test_conv2d_frame_extent_fused_stage(rng):
    """The smallNet conv stage (2x2 SAME + fused sigmoid) at streaming
    frame size — the sweep's per-frame launch shape — matches the oracle
    and fits the VMEM budget with room to spare."""
    x = jnp.asarray(rng.normal(size=(1, 112, 112, 1)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(2, 2, 1, 1)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(1,)), jnp.float32)
    got = conv2d(x, w, b, activation="sigmoid")
    want = conv2d_ref(x, w, b, activation="sigmoid")
    assert got.shape == (1, 112, 112, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_fixed_conv_frame_extent_and_budget(rng):
    """kernels/fixed_conv at frame extents: the fused conv+PLAN+pool launch
    on a 112x112 word map matches the emulated backend word-for-word, odd
    extents pool like the emulated path, and the budget check trips on
    frames that genuinely exceed VMEM (with the limb temporaries counted)."""
    from repro.core import backends as B
    from repro.core import fixed_point as fxp
    from repro.kernels.fixed_conv import fixed_conv2d

    cfg = fxp.Q16_16
    x = jnp.asarray(rng.integers(-2 ** 20, 2 ** 20, (1, 112, 112)), jnp.int32)
    w4 = jnp.asarray(rng.integers(-2 ** 14, 2 ** 14, (4,)), jnp.int32)
    b = jnp.int32(rng.integers(-2 ** 14, 2 ** 14))
    got = fixed_conv2d(x, w4, b, cfg=cfg, activation="plan", pool=True)
    want = B.maxpool_fixed(fxp.fixed_sigmoid_plan(
        B.conv_fixed(x, w4, b, cfg), cfg))
    assert got.shape == (1, 56, 56)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # odd extent: even-crop before pooling, exactly like maxpool_fixed
    odd = fixed_conv2d(x[:, :29, :29], w4, b, cfg=cfg, activation="plan",
                       pool=True)
    assert odd.shape == (1, 14, 14)
    # a frame past ~670x670 exceeds input + limb-temporary VMEM
    with pytest.raises(ValueError, match="VMEM"):
        fixed_conv2d(jnp.zeros((1, 700, 700), jnp.int32), w4, b, cfg=cfg)
