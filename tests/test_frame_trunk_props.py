"""Hypothesis property battery for the megakernel's VMEM/tile chooser.

`choose_tile` is static arithmetic (no tracing, no device), so the
properties range widely over frame geometries, halo widths, and budgets:
the chosen tile must always divide the frame on the pooled lattice and fit
the budget; frames that cannot fit any tile — or that break the
multiple-of-4 contract, including odd and 112..512-range non-multiples —
must raise loudly rather than launch a kernel that oversubscribes VMEM.

The one model-evaluating property is the degenerate single-tile case: when
the whole frame is one tile there is no halo, no seam, and no DMA offset
arithmetic left, so the megakernel's interior map must equal the plain
composition of two fused `fixed_conv2d(activation="plan", pool=True)`
launches word-for-word.
"""
import numpy as np
import pytest

hp = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
st = pytest.importorskip("hypothesis.strategies")

import jax.numpy as jnp

from repro.core import fixed_point as fxp
from repro.kernels.fixed_conv.ops import fixed_conv2d
from repro.kernels.fixed_conv.ref import random_words
from repro.kernels.frame_trunk import (choose_tile, frame_trunk_quad,
                                       frame_trunk_vmem_bytes)
from repro.kernels.frame_trunk.ops import _VMEM_BUDGET, check_frame_geometry

# frames on the pooled lattice, spanning the ISSUE's 112..512 deployment
# range and the tiny end where tile == frame
_side = st.integers(1, 128).map(lambda k: 4 * k)          # 4..512
_halo = st.integers(1, 8)
_budget = st.integers(frame_trunk_vmem_bytes(4, 4, halo=8),
                      4 * _VMEM_BUDGET)


@hp.given(H=_side, W=_side, halo=_halo, budget=_budget)
@hp.settings(max_examples=150, deadline=None)
def test_choose_tile_respects_budget_and_lattice(H, W, halo, budget):
    th, tw = choose_tile(H, W, halo=halo, budget=budget)
    assert th % 4 == 0 and tw % 4 == 0 and th >= 4 and tw >= 4
    assert H % th == 0 and W % tw == 0
    assert frame_trunk_vmem_bytes(th, tw, halo=halo) <= budget


@hp.given(H=_side, W=_side, halo=_halo)
@hp.settings(max_examples=60, deadline=None)
def test_choose_tile_is_maximal(H, W, halo):
    """No legal tile with a larger area fits the budget — the chooser
    never leaves VMEM on the table."""
    th, tw = choose_tile(H, W, halo=halo)
    for a in range(4, H + 1, 4):
        if H % a:
            continue
        for b in range(4, W + 1, 4):
            if W % b or a * b <= th * tw:
                continue
            assert frame_trunk_vmem_bytes(a, b, halo=halo) > _VMEM_BUDGET


@hp.given(H=st.integers(4, 512), W=st.integers(4, 512))
@hp.settings(max_examples=100, deadline=None)
def test_off_lattice_frames_rejected(H, W):
    """Odd and non-multiple-of-4 extents anywhere in the deployment range
    raise; lattice-aligned ones pass the geometry check."""
    if H % 4 == 0 and W % 4 == 0:
        check_frame_geometry(H, W)
    else:
        with pytest.raises(ValueError, match="lattice"):
            check_frame_geometry(H, W)


@hp.given(n=st.integers(0, 3), m=st.integers(0, 3))
@hp.settings(max_examples=20, deadline=None)
def test_too_small_frames_rejected(n, m):
    with pytest.raises(ValueError, match="small"):
        check_frame_geometry(n, m)


@hp.given(H=_side, W=_side, halo=_halo)
@hp.settings(max_examples=40, deadline=None)
def test_impossible_budget_rejected_loudly(H, W, halo):
    floor = frame_trunk_vmem_bytes(4, 4, halo=halo)
    with pytest.raises(ValueError, match="VMEM budget"):
        choose_tile(H, W, halo=halo, budget=floor - 1)


@hp.given(halo=_halo)
@hp.settings(max_examples=20, deadline=None)
def test_vmem_model_monotone(halo):
    """Bigger tiles and wider halos never claim less VMEM — the budget
    check cannot be gamed by the chooser's scan order."""
    for th, tw in ((4, 4), (8, 8), (16, 8), (64, 64), (256, 128)):
        assert (frame_trunk_vmem_bytes(th, tw, halo=halo)
                <= frame_trunk_vmem_bytes(2 * th, tw, halo=halo))
        assert (frame_trunk_vmem_bytes(th, tw, halo=halo)
                <= frame_trunk_vmem_bytes(th, 2 * tw, halo=halo))
        assert (frame_trunk_vmem_bytes(th, tw, halo=halo)
                <= frame_trunk_vmem_bytes(th, tw, halo=halo + 1))


@hp.given(H=st.sampled_from([4, 8, 12, 16]), W=st.sampled_from([4, 8, 12, 16]),
          fmt=st.sampled_from(["q16_16", "q8_8"]), seed=st.integers(0, 2**16))
@hp.settings(max_examples=25, deadline=None)
def test_single_tile_degenerate_matches_fixed_conv2d(H, W, fmt, seed):
    """tile == frame: no halo/seam/DMA arithmetic in play, so the interior
    map must be exactly two composed fused fixed_conv2d stages."""
    cfg = fxp.Q16_16 if fmt == "q16_16" else fxp.Q8_8
    rng = np.random.default_rng(seed)
    x = random_words(rng, (H, W), cfg)
    w1, b1 = random_words(rng, (4,), cfg), random_words(rng, (1,), cfg)
    w2, b2 = random_words(rng, (4,), cfg), random_words(rng, (1,), cfg)
    quad = frame_trunk_quad(jnp.asarray(x, jnp.int32), w1, b1, w2, b2,
                            cfg=cfg, tile=(H, W))
    s1 = fixed_conv2d(jnp.asarray(x, jnp.int32)[None], jnp.asarray(w1),
                      jnp.asarray(b1), cfg=cfg, activation="plan", pool=True)
    s2 = fixed_conv2d(s1, jnp.asarray(w2), jnp.asarray(b2), cfg=cfg,
                      activation="plan", pool=True)
    np.testing.assert_array_equal(
        np.asarray(quad[0], np.int64), np.asarray(s2[0], np.int64),
        err_msg=f"{fmt}/{H}x{W}: single-tile interior drifted from the "
                f"per-stage fixed_conv2d composition")
