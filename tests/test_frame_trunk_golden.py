"""Golden-vector regression for the trunk megakernel.

tests/golden/frame_trunk_golden.json freezes the megakernel's level-2 quad
words over the deterministic 112x112 synthetic frame in BOTH deployed
formats (Q16.16 and Q8.8).  Both fixed substrates must reproduce every word
through the one-launch route — any drift in the tile chooser, the halo DMA,
the in-kernel edge masking, or the underlying arithmetic fails here first,
against vectors that cannot silently regenerate themselves (the CI golden
job diffs a fresh generation).

Regenerate (only after an INTENTIONAL semantics change) with:
    PYTHONPATH=src python tests/golden/gen_frame_trunk_golden.py
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core import backends as B
from repro.core import fixed_point as fxp
from repro.core import smallnet
from repro.streaming.fcn_sweep import sweep_feature_maps
from repro.streaming.sources import SyntheticVideoSource

_GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden"
     / "frame_trunk_golden.json").read_text())

_FORMATS = {"q16_16": fxp.Q16_16, "q8_8": fxp.Q8_8}
_MAPS = ("interior", "last_row", "last_col", "corner")


@pytest.fixture(scope="module")
def params():
    return smallnet.seeded_params()


@pytest.fixture(scope="module")
def frame():
    f = SyntheticVideoSource(n_frames=1, seed=7).frames()[0]
    assert list(f.pixels.shape[:2]) == _GOLDEN["frame"]["shape"]
    return f


def test_golden_covers_both_formats_and_all_maps():
    assert set(_GOLDEN["maps"]) == set(_FORMATS)
    for fmt in _FORMATS:
        assert set(_GOLDEN["maps"][fmt]) == set(_MAPS)
        for m in _GOLDEN["maps"][fmt].values():
            assert np.asarray(m).shape == (28, 28)


@pytest.mark.parametrize("fmt", sorted(_FORMATS))
@pytest.mark.parametrize("kind", ("fixed", "fixed_pallas"))
def test_megakernel_maps_golden(params, frame, fmt, kind):
    cls = B.FixedBackend if kind == "fixed" else B.FixedPallasBackend
    be = cls(name=f"{kind}_{fmt}_golden", cfg=_FORMATS[fmt])
    maps = sweep_feature_maps(params, frame.pixels, backend=be,
                              megakernel=True)
    for name in _MAPS:
        np.testing.assert_array_equal(
            np.asarray(maps[name], np.int64),
            np.asarray(_GOLDEN["maps"][fmt][name], np.int64),
            err_msg=f"{kind}/{fmt}/{name}: megakernel words drifted from "
                    f"golden vectors")
