"""Backend dispatch: registry coverage + cross-backend parity on the one
network graph (paper: one datapath, many substrates)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backends as B
from repro.core import fixed_point as fxp
from repro.core import smallnet

REQUIRED = {"ref", "plan", "pallas", "fixed", "fixed_pallas", "int8"}


@pytest.fixture(scope="module")
def setup(rng):
    params = smallnet.init_params(jax.random.key(1))
    x = jnp.asarray(rng.uniform(0.0, 1.0, (6, 28, 28, 1)), jnp.float32)
    return params, x


def test_list_backends_covers_all_required():
    assert REQUIRED <= set(B.list_backends())


def test_get_backend_roundtrip_and_unknown():
    be = B.get_backend("pallas")
    assert be.name == "pallas"
    assert B.get_backend(be) is be                 # instance passthrough
    with pytest.raises(KeyError, match="registered"):
        B.get_backend("verilog")


def test_register_backend_decorator():
    @B.register_backend("_test_tmp")
    @dataclasses.dataclass(frozen=True)
    class Tmp(B.Backend):
        name: str = "_test_tmp"
    try:
        assert isinstance(B.get_backend("_test_tmp"), Tmp)
    finally:
        B._REGISTRY.pop("_test_tmp", None)


def test_apply_works_for_all_registered_backends_from_float_params(setup):
    params, x = setup
    for name in B.list_backends():
        scores = smallnet.apply(params, x, backend=name)
        assert scores.shape == (6, 10), name
        assert smallnet.predict(scores).shape == (6,), name


def test_ref_backend_is_forward(setup):
    params, x = setup
    np.testing.assert_array_equal(
        np.asarray(smallnet.apply(params, x, backend="ref")),
        np.asarray(smallnet.forward(params, x)))


def test_pallas_matches_ref_allclose(setup):
    params, x = setup
    got = smallnet.apply(params, x, backend="pallas")     # interpret mode
    want = smallnet.apply(params, x, backend="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_pallas_plan_matches_plan_allclose(setup):
    params, x = setup
    got = smallnet.apply(params, x, backend="pallas_plan")
    want = smallnet.apply(params, x, backend="plan")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fixed_matches_plan_within_qmn_tolerance(setup):
    """The fixed path IS the plan path in Qm.n words: dequantized scores must
    sit within a few quantization steps of the float PLAN scores."""
    params, x = setup
    fix = smallnet.apply(params, x, backend="fixed")
    assert fix.dtype == jnp.int32
    deq = fxp.from_fixed(fix, fxp.Q16_16)
    plan = smallnet.apply(params, x, backend="plan")
    # Q16.16 resolution is 2^-16; the 49-tap dense MAC accumulates ~50 steps
    np.testing.assert_allclose(np.asarray(deq), np.asarray(plan), atol=2e-3)


def test_fixed_wrapper_equals_backend_and_is_idempotent(setup):
    params, x = setup
    qfix = smallnet.quantize_params_fixed(params)
    via_wrapper = smallnet.forward_fixed(qfix, x)            # native params
    via_apply = smallnet.apply(params, x, backend="fixed")   # float params
    np.testing.assert_array_equal(np.asarray(via_wrapper), np.asarray(via_apply))
    # prepare_params must not double-quantize native int32 params
    be = B.get_backend("fixed")
    leaves = jax.tree_util.tree_leaves(be.prepare_params(qfix))
    np.testing.assert_array_equal(np.asarray(leaves[0]),
                                  np.asarray(jax.tree_util.tree_leaves(qfix)[0]))


def test_fixed_pallas_bit_exact_with_fixed(setup):
    """The contract of the fused kernel path: int32 WORD EQUALITY with the
    emulated fixed substrate — not closeness, identity."""
    params, x = setup
    got = smallnet.apply(params, x, backend="fixed_pallas")
    want = smallnet.apply(params, x, backend="fixed")
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fixed_pallas_bit_exact_in_saturate_and_trunc_modes(setup):
    params, x = setup
    for kw in ({"saturate": True}, {"round_nearest": False}):
        cfg = dataclasses.replace(fxp.Q16_16, **kw)
        got = smallnet.apply(params, x, backend=B.FixedPallasBackend(cfg=cfg))
        want = smallnet.apply(params, x, backend=B.FixedBackend(cfg=cfg))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=str(kw))


def test_fixed_pallas_matches_plan_within_qmn_tolerance(setup):
    """Same tolerance-based closeness to the float PLAN path as "fixed"."""
    params, x = setup
    deq = fxp.from_fixed(smallnet.apply(params, x, backend="fixed_pallas"),
                         fxp.Q16_16)
    plan = smallnet.apply(params, x, backend="plan")
    np.testing.assert_allclose(np.asarray(deq), np.asarray(plan), atol=2e-3)


def test_fixed_pallas_native_params_passthrough(setup):
    params, x = setup
    qfix = smallnet.quantize_params_fixed(params)
    np.testing.assert_array_equal(
        np.asarray(smallnet.apply(qfix, x, backend="fixed_pallas")),
        np.asarray(smallnet.apply(params, x, backend="fixed_pallas")))


def test_fused_conv_act_pool_hook_matches_composition(setup):
    """The new graph hook must equal maxpool(fused_conv_act(.)) for every
    backend — for fixed_pallas that means the single fused launch equals
    the three-launch composition, word for word."""
    params, x = setup
    for name in B.list_backends():
        be = B.get_backend(name)
        p = be.prepare_params(params)
        xi = be.ingest(x)
        fused = be.fused_conv_act_pool(xi, p["conv1"]["w"], p["conv1"]["b"])
        composed = be.maxpool2x2(
            be.fused_conv_act(xi, p["conv1"]["w"], p["conv1"]["b"]))
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(composed),
                                      err_msg=name)


def test_int8_matches_ref_within_ptq_tolerance(setup):
    params, x = setup
    got = smallnet.apply(params, x, backend="int8")
    want = smallnet.apply(params, x, backend="ref")
    # int8 PTQ + PLAN sigmoid: scores move a little, ranking mostly survives
    assert float(jnp.abs(got - want).max()) < 0.08
    agree = float(jnp.mean(smallnet.predict(got) == smallnet.predict(want)))
    assert agree >= 0.5


def test_int8_dense_uses_quant_matmul_kernel(setup):
    """The int8 dense layer must route through the Pallas quant_matmul
    wrapper, not the jnp oracle: same math, so compare against it."""
    params, x = setup
    from repro.core import ptq
    be = B.get_backend("int8")
    qp = be.quantize_params(params)
    feats = jnp.asarray(np.random.default_rng(2).uniform(0, 1, (6, 49)),
                        jnp.float32)
    got = be.dense(feats, qp["dense"]["w"], qp["dense"]["b"])
    xq = ptq.quantize(feats, ptq.QuantConfig(per_channel=False))
    wq = qp["dense"]["w"]
    want = ptq.quantized_matmul_ref(
        xq, ptq.QuantTensor(wq.q, wq.scale.reshape(-1))) + qp["dense"]["b"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_forward_plan_wrapper(setup):
    params, x = setup
    np.testing.assert_array_equal(
        np.asarray(smallnet.forward_plan(params, x)),
        np.asarray(smallnet.apply(params, x, backend="plan")))


def test_apply_jits_per_backend(setup):
    params, x = setup
    fn = jax.jit(lambda p, xx: smallnet.apply(p, xx, backend="pallas_plan"))
    np.testing.assert_allclose(
        np.asarray(fn(params, x)),
        np.asarray(smallnet.apply(params, x, backend="pallas_plan")),
        rtol=1e-6, atol=1e-6)
