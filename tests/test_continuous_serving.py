"""Overload behavior: admission control, SLO routing, elastic scaling, and
the serving-accounting regressions (idle-window qps, bounded retention,
degenerate latency_stats)."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import smallnet
from repro.serving.router import ReplicaRouter
from repro.serving.vision_engine import (EngineFaultError, VisionEngine,
                                         latency_stats)
from repro.streaming.loadgen import LoadGen


@pytest.fixture(scope="module")
def vision_setup(rng):
    params = smallnet.init_params(jax.random.key(0))
    images = rng.uniform(0.0, 1.0, (104, 28, 28, 1)).astype(np.float32)
    return params, images


def _slow_step(batch_size: int, delay_s: float):
    """Deterministic-capacity stand-in for the jitted step: the service
    rate is exactly batch_size/delay_s, independent of the host."""
    def f(params, x):
        time.sleep(delay_s)
        return jnp.zeros((batch_size, 10), jnp.float32)
    return f


# ---------------------------------------------------------------------------
# Bugfix regressions
# ---------------------------------------------------------------------------


def test_latency_stats_zero_window_is_zero_qps():
    """Regression: a zero-length serving window used to report inf qps."""
    s = latency_stats([0.001, 0.002], 0.0)
    assert s["throughput_qps"] == 0.0
    assert np.isfinite(s["throughput_qps"])
    assert s["latency_p99_ms"] >= s["latency_p50_ms"] > 0


def test_latency_stats_empty_raises():
    """Regression: an empty latency set used to nan every percentile."""
    with pytest.raises(ValueError, match="empty latency set"):
        latency_stats([], 1.0)


def test_throughput_over_busy_time_not_idle_gaps(vision_setup):
    """Regression (idle-window qps): an engine serving two bursts separated
    by a sleep must report its service rate over BUSY time — the old
    wall = t_last_done - t_first_submit accounting deflated qps by the
    inter-burst idle gap."""
    params, images = vision_setup
    eng = VisionEngine(params, backend="ref", batch_size=8)
    eng.serve(list(images[:16]))
    time.sleep(0.5)                                  # the idle gap
    eng.serve(list(images[16:32]))
    s = eng.stats()
    assert s["wall_s"] >= 0.5                        # gap is inside the wall
    assert s["busy_s"] < s["wall_s"] - 0.4           # ...but not inside busy
    wall_qps = s["n"] / s["wall_s"]
    assert s["throughput_qps"] == pytest.approx(s["n"] / s["busy_s"])
    assert s["throughput_qps"] > 3 * wall_qps        # the deflation is gone


def test_engine_resident_results_stay_bounded(vision_setup):
    """Regression (unbounded result growth): a pipeline-style per-wave
    serve() over a 300-frame run keeps the engine's resident result set
    O(batch), not O(stream)."""
    params, images = vision_setup
    eng = VisionEngine(params, backend="ref", batch_size=8)
    for i in range(300):
        res = eng.serve([images[i % 100], images[(i + 1) % 100]])
        assert len(res) == 2
        assert len(eng._results) == 0                # popped by serve()
        assert len(eng._shed) == 0
    s = eng.stats()
    assert s["n"] == 600 and s["submitted"] == 600 and s["accounted"]


def test_router_resident_results_stay_bounded(vision_setup):
    params, images = vision_setup
    router = ReplicaRouter.from_backends(params, ["ref", "ref"],
                                         batch_size=8, warmup=False)
    for i in range(100):
        res = router.serve([images[i % 100], images[(i + 1) % 100]])
        assert len(res) == 2
        assert len(router._results) == 0
        assert len(router._assignment) == 0
        assert len(router._shed) == 0
    assert router.stats()["n"] == 200


# ---------------------------------------------------------------------------
# Admission control under open-loop overload
# ---------------------------------------------------------------------------


def test_admission_shed_accounting_under_2x_poisson(vision_setup):
    """2x-capacity Poisson load against a bounded queue: the engine sheds
    (reason queue_depth), never exceeds the bound, and the ledger
    reconciles exactly: submitted == served + shed."""
    params, _ = vision_setup
    eng = VisionEngine(params, backend="ref", batch_size=8, warmup=False,
                       max_queue=16)
    eng._step_fn = _slow_step(8, 0.010)              # capacity: 800 qps
    gen = LoadGen(process="poisson", rate_qps=1600, n_requests=300,
                  n_streams=4, seed=7)
    img = np.zeros((28, 28, 1), np.float32)
    eng.start()
    try:
        gen.replay(lambda a, t: eng.submit(img, t_submit=t))
    finally:
        eng.stop(drain=True)
    s = eng.stats()
    assert s["submitted"] == len(gen)                # every arrival admitted
    assert s["shed"] > 0
    assert s["shed_by_reason"].get("queue_depth", 0) == s["shed"]
    assert s["pending"] == 0
    assert s["n"] + s["shed"] == len(gen) and s["accounted"]
    assert s["queue_hwm"] <= 16


def test_deadline_and_age_sheds_at_batch_forming(vision_setup):
    params, images = vision_setup
    eng = VisionEngine(params, backend="ref", batch_size=4, warmup=False)
    uids = [eng.submit(img, deadline_ms=0.01) for img in images[:3]]
    time.sleep(0.01)
    assert eng.run() == 0                            # all expired unserved
    assert eng.pop_shed(uids) == {u: "deadline" for u in uids}
    assert eng.stats()["goodput"] == 0.0             # nothing made its SLO
    aged = VisionEngine(params, backend="ref", batch_size=4, warmup=False,
                        max_age_ms=0.01)
    aged.submit_many(list(images[:2]))
    time.sleep(0.01)
    assert aged.run() == 0
    assert set(aged.pop_shed().values()) == {"age"}
    assert aged.stats()["accounted"]


def test_serve_returns_none_gaps_for_shed(vision_setup):
    params, images = vision_setup
    eng = VisionEngine(params, backend="ref", batch_size=4, warmup=False,
                       max_queue=2)
    res = eng.serve(list(images[:5]))                # 2 queued, 3 shed
    assert len(res) == 5
    assert sum(r is None for r in res) == 3
    assert {r.uid for r in res if r is not None} == {0, 1}


def test_faulted_serving_thread_sheds_and_reports(vision_setup):
    """A dying jitted step must not strand requests: the batch and queue
    shed as "fault", the fault is exposed, later submits shed at the door,
    and accounting still reconciles."""
    params, images = vision_setup
    eng = VisionEngine(params, backend="ref", batch_size=4, warmup=False)
    eng._step_fn = lambda p, x: (_ for _ in ()).throw(
        RuntimeError("hardware fault"))
    eng.start()
    uids = eng.submit_many(list(images[:6]))
    eng.wait(uids, timeout=30)                       # resolves via sheds
    assert isinstance(eng.fault, RuntimeError)
    assert set(eng.pop_shed(uids).values()) == {"fault"}
    late = eng.submit(images[0])                     # faulted engine: at-door
    assert eng.pop_shed([late]) == {late: "fault"}
    assert eng.stats()["accounted"]
    with pytest.raises(EngineFaultError):            # unknown uids never resolve
        eng.wait([10 ** 9], timeout=5)
    eng.stop()


# ---------------------------------------------------------------------------
# SLO-aware routing
# ---------------------------------------------------------------------------


def test_slo_router_sheds_instead_of_blowing_p99(vision_setup):
    """Same 100-request burst against the same deterministic 800 qps
    replica: the least-loaded policy queues everything and its p99 eats the
    full backlog; the SLO policy sheds at the door and holds p99 near the
    deadline."""
    params, _ = vision_setup
    img = np.zeros((28, 28, 1), np.float32)

    def mk(policy, **kw):
        r = ReplicaRouter.from_backends(params, ["ref"], batch_size=8,
                                        warmup=False, policy=policy, **kw)
        r.replicas[0]._step_fn = _slow_step(8, 0.010)
        # establish the observed service rate with ONE batch: a cold slo
        # fleet with no rate evidence door-sheds anything beyond a full
        # batch of backlog (the cold-fleet SLO fix in router._projected_
        # waits_from), so a 2-batch warmup would itself shed
        r.serve([img] * 8)
        return r

    ll = mk("least_loaded")
    ll.serve([img] * 100)
    slo = mk("slo", slo_ms=25.0)
    res = slo.serve([img] * 100)
    s_ll, s_slo = ll.stats(), slo.stats()
    assert s_ll["shed"] == 0                         # queues it all...
    assert s_slo["shed_by_reason"]["slo_wait"] >= 30  # ...SLO sheds instead
    assert s_slo["latency_p99_ms"] < s_ll["latency_p99_ms"]
    assert s_slo["latency_p99_ms"] < 100.0           # ~deadline + one batch
    assert s_slo["accounted"] and s_slo["goodput"] > 0.0
    assert sum(r is None for r in res) == s_slo["shed"]


def test_slo_dispatch_prefers_faster_replica(vision_setup):
    """Projected-wait dispatch: a fast replica with the same queue depth
    must win over a slow one — depth-only dispatch can't see that."""
    params, _ = vision_setup
    img = np.zeros((28, 28, 1), np.float32)
    router = ReplicaRouter.from_backends(params, ["ref", "ref"],
                                         batch_size=8, warmup=False,
                                         policy="slo")
    router.replicas[0]._step_fn = _slow_step(8, 0.050)   # 160 qps
    router.replicas[1]._step_fn = _slow_step(8, 0.005)   # 1600 qps
    router.serve([img] * 32)                         # learn both rates
    with router._lock:
        router._pending[0] = []                      # equalize depths
        router._pending[1] = []
    assigned = [router._assignment[router.submit(img)] for _ in range(6)]
    assert assigned.count(1) > assigned.count(0)


def test_router_fleet_ledger_reconciles_with_engine_sheds(vision_setup):
    """Engine-level admission sheds surface as fleet sheds (not failover):
    submitted == served + shed at BOTH levels."""
    params, images = vision_setup
    router = ReplicaRouter.from_backends(
        params, ["ref"], batch_size=4, warmup=False,
        engine_kw={"max_queue": 4})
    uids = router.submit_many(list(images[:12]))
    router.run()
    router.wait(uids)
    s = router.stats()
    assert s["submitted"] == 12
    assert s["accounted"]
    assert s["n"] + s["shed"] == 12
    if s["shed"]:
        assert set(s["shed_by_reason"]) <= {"queue_depth"}


# ---------------------------------------------------------------------------
# Elastic scaling
# ---------------------------------------------------------------------------


def test_autoscale_spawns_under_backlog_and_retires_idle(vision_setup):
    params, images = vision_setup
    spawned = []

    def spawn():
        eng = VisionEngine(params, backend="ref", batch_size=4, warmup=False)
        spawned.append(eng)
        return eng

    router = ReplicaRouter.from_backends(
        params, ["ref"], batch_size=4, warmup=False, spawn=spawn,
        min_replicas=1, max_replicas=3, scale_up_depth=2.0,
        scale_down_idle=2)
    router.submit_many(list(images[:20]))            # 20 > 2.0 * 4 capacity
    assert router.autoscale() == "spawn:1"
    assert len(router.replicas) == 2 and len(spawned) == 1
    uids = list(router._assignment)
    router.submit_many(list(images[20:24]))          # lands on the new replica
    assert any(i == 1 for i in router._assignment.values())
    router.run()
    router.wait(uids)
    assert router.stats()["healthy"] == 2
    # drained fleet: two consecutive idle checks retire one replica...
    assert router.autoscale() is None                # idle tick 1
    retire = router.autoscale()                      # idle tick 2
    assert retire is not None and retire.startswith("retire:")
    s = router.stats()
    assert s["healthy"] == 1 and len(s["retired"]) == 1
    # ...but never below min_replicas
    assert router.autoscale() is None
    assert router.autoscale() is None
    assert router.stats()["healthy"] == 1
    # and dispatch routes around the retiree
    retired = int(retire.split(":")[1])
    live = [router._assignment[router.submit(images[0])] for _ in range(4)]
    assert retired not in live
    assert router.stats()["accounted"]


# ---------------------------------------------------------------------------
# Continuous batching == wave serving, word for word
# ---------------------------------------------------------------------------


def test_continuous_thread_word_exact_vs_sync_on_fixed(vision_setup):
    """The serving DISCIPLINE must not change the arithmetic: a threaded
    continuous-batching engine on the fused fixed-point kernels returns the
    same int32 score words as a synchronous drain on the emulated fixed
    backend, whatever batch boundaries the thread happened to form."""
    params, images = vision_setup
    sync = VisionEngine(params, backend="fixed", batch_size=8)
    want = sync.serve(list(images[:24]))
    eng = VisionEngine(params, backend="fixed_pallas", batch_size=8)
    eng.start()
    try:
        uids = []
        for i in range(0, 24, 3):                    # dribble: ragged batches
            uids += eng.submit_many(list(images[i:i + 3]))
            time.sleep(0.002)
        eng.wait(uids, timeout=60)
    finally:
        eng.stop()
    got = eng.pop_results(uids)
    assert sorted(got) == uids
    np.testing.assert_array_equal(
        np.stack([got[u].scores for u in uids]),
        np.stack([r.scores for r in want]))
    assert [got[u].pred for u in uids] == [r.pred for r in want]
    assert eng.stats()["accounted"] and eng.stats()["shed"] == 0
