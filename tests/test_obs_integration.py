"""Observability integration: spans vs. the real serving-stack ledgers.

Runs the actual pipeline/engine with tracing enabled and asserts the
contract the CI trace smoke gates on: every submitted frame (and every
engine request) ends in exactly one terminal span state that reconciles
with the component's own accounting, span clocks are monotonic and
nested, the flight recorder trips on SLO violations, and the registry's
Prometheus exposition round-trips after a live run.
"""
import numpy as np
import pytest

from repro.core import smallnet
from repro.obs import metrics as M
from repro.obs import recorder as R
from repro.obs import trace as T
from repro.serving.vision_engine import VisionEngine
from repro.streaming.pipeline import StreamConfig, StreamingPipeline
from repro.streaming.sources import PacedPlayer, SyntheticVideoSource
from repro.streaming.tiler import Tiler


@pytest.fixture(scope="module")
def params():
    return smallnet.seeded_params()


@pytest.fixture(scope="module")
def clip():
    return SyntheticVideoSource(n_frames=6, seed=3)


@pytest.fixture(scope="module")
def tiler(params, clip):
    t0 = Tiler(stride=14)
    tiles, _ = t0.extract(clip.frames()[0])
    conf = t0._confidences(t0.score(params, tiles, backend="ref")).max(-1)
    return Tiler(stride=14, threshold=float(np.quantile(conf, 0.8)))


@pytest.fixture()
def tracer(tmp_path):
    tr = T.enable(capacity=1 << 15, dump_dir=str(tmp_path))
    yield tr
    T.disable()


def _run_pipeline(params, clip, tiler, **cfg):
    engine = VisionEngine(params, backend="ref", batch_size=64)
    pipe = StreamingPipeline(clip, engine, tiler,
                             config=StreamConfig(**cfg))
    pipe.run()
    return pipe


# -- the headline contract: spans reconcile with both ledgers -----------------

class TestTracedPipelineReconciles:
    def test_frame_and_request_ledgers(self, params, clip, tiler, tracer):
        pipe = _run_pipeline(params, clip, tiler)
        s = pipe.stats()
        spans = tracer.recorder.spans()
        assert tracer.recorder.evicted == 0

        # every submitted frame ends in exactly one terminal frame span
        # matching the pipeline ledger
        assert R.reconcile(spans, frames_served=s["frames_served"],
                           frames_dropped=s["frames_dropped"]) == []
        # and every engine request reconciles against the engine ledger
        es = s["engine"]
        assert es["accounted"]
        assert R.reconcile(spans, served=es["n"], shed=es["shed"],
                           root_name="request") == []

    def test_span_taxonomy_present(self, params, clip, tiler, tracer):
        _run_pipeline(params, clip, tiler)
        names = {sp.name for sp in tracer.recorder.spans()}
        for expected in ("frame", "tile", "infer", "aggregate",
                         "request", "queue_wait", "batch_form",
                         "device_step"):
            assert expected in names, f"missing {expected!r} spans"

    def test_one_frame_root_per_ingested_frame(self, params, clip, tiler,
                                               tracer):
        pipe = _run_pipeline(params, clip, tiler)
        roots = [sp for sp in tracer.recorder.spans()
                 if sp.name == "frame" and sp.parent_id is None]
        assert len(roots) == pipe.stats()["frames_in"]
        assert all(r.terminal for r in roots)

    def test_stage_spans_nest_inside_their_frame(self, params, clip, tiler,
                                                 tracer):
        _run_pipeline(params, clip, tiler)
        spans = tracer.recorder.spans()
        by_id = {sp.span_id: sp for sp in spans}
        checked = 0
        for sp in spans:
            if sp.name not in ("tile", "infer", "aggregate"):
                continue
            parent = by_id[sp.parent_id]
            assert parent.name == "frame"
            assert sp.t_start >= parent.t_start - 1e-6
            assert sp.t_end <= parent.t_end + 1e-6
            checked += 1
        assert checked > 0


class TestDroppedFrames:
    def test_deadline_drops_reconcile_and_trip(self, params, clip, tiler,
                                               tracer, tmp_path):
        # an impossible deadline: every frame is dropped, none served
        pipe = _run_pipeline(params, clip, tiler, deadline_ms=1e-3)
        s = pipe.stats()
        assert s["frames_served"] == 0
        assert s["frames_dropped"] == s["frames_in"] > 0
        spans = tracer.recorder.spans()
        assert R.reconcile(spans, frames_served=0,
                           frames_dropped=s["frames_dropped"]) == []
        roots = [sp for sp in spans
                 if sp.name == "frame" and sp.parent_id is None]
        assert all(r.status.startswith("dropped:") for r in roots)
        # deadline misses tripped the flight recorder (rate-limited)
        assert tracer.recorder.trip_counts().get("slo_violation", 0) > 0
        dumped = list(tmp_path.glob("flight_slo_violation_*.jsonl"))
        assert 1 <= len(dumped) <= tracer.recorder.trip_limit
        header, dumped_spans = R.load_jsonl(str(dumped[0]))
        assert header["reason"] == "slo_violation"
        assert len(dumped_spans) == header["n_spans"]


class TestTracedEngineStandalone:
    def test_door_sheds_and_serves_reconcile(self, params, tracer):
        engine = VisionEngine(params, backend="ref", batch_size=4,
                              max_queue=3)
        rng = np.random.default_rng(0)
        imgs = rng.random((8, 28, 28, 1), dtype=np.float32)
        for img in imgs:
            engine.submit(img)           # queue bound 3: 5 shed at the door
        engine.run()
        es = engine.stats()
        assert es["submitted"] == 8
        assert es["n"] == 3 and es["shed"] == 5
        assert es["accounted"]
        spans = tracer.recorder.spans()
        assert R.reconcile(spans, served=es["n"], shed=es["shed"],
                           root_name="request") == []
        sheds = [sp for sp in spans if sp.name == "request"
                 and sp.status == "shed:queue_depth"]
        assert len(sheds) == 5
        # served requests carry a queue_wait child inside their window
        served = [sp for sp in spans if sp.name == "request"
                  and sp.status == "served"]
        qw_parents = {sp.parent_id for sp in spans
                      if sp.name == "queue_wait"}
        assert {sp.span_id for sp in served} <= qw_parents


# -- satellite 1: bounded memory in the pipeline's stage timings --------------

class TestBoundedRetention:
    def test_stage_histograms_are_bounded(self, params, clip, tiler):
        pipe = _run_pipeline(params, clip, tiler)
        for hist in list(pipe._stage_hist.values()) + [pipe._lat_hist]:
            assert hist._samples.maxlen == M.RESERVOIR
            assert len(hist._samples) <= hist._samples.maxlen
            # exact accumulators live outside the reservoir
            assert hist.count >= len(hist._samples)

    def test_retention_is_constant_past_the_reservoir(self):
        h = M.Histogram("stage", {}, buckets=(0.01,), reservoir=32)
        for i in range(10 * 32):
            h.observe(i * 1e-4)
        assert len(h.samples()) == 32
        assert h.count == 320
        # summary still reports the exact stream count, not the window
        assert h.summary_ms()["n"] == 320

    def test_no_unbounded_stat_lists_on_pipeline(self, params, clip, tiler):
        # the pre-registry ad-hoc lists must not come back
        pipe = _run_pipeline(params, clip, tiler)
        for attr in ("_stage_s", "_latencies", "_lat_s"):
            assert not hasattr(pipe, attr)


# -- live-registry export after a real run ------------------------------------

class TestLiveRegistryExport:
    def test_prometheus_round_trips_after_run(self, params, clip, tiler):
        pipe = _run_pipeline(params, clip, tiler)
        s = pipe.stats()
        parsed = M.parse_prometheus(M.REGISTRY.to_prometheus())
        pid = pipe._id
        assert parsed[f'stream_frames_in_total{{pipe="{pid}"}}'] == \
            s["frames_in"]
        assert parsed[f'stream_frames_served_total{{pipe="{pid}"}}'] == \
            s["frames_served"]
        key = f'stream_frame_latency_seconds_count{{pipe="{pid}"}}'
        assert parsed[key] == s["frames_served"]

    def test_realtime_pipeline_reconciles_too(self, params, clip, tiler,
                                              tracer):
        engine = VisionEngine(params, backend="ref", batch_size=64)
        pipe = StreamingPipeline(
            PacedPlayer(clip, fps=30.0), engine, tiler,
            config=StreamConfig(deadline_ms=2000.0, queue_size=4))
        pipe.run()
        s = pipe.stats()
        assert s["accounted"]
        assert R.reconcile(tracer.recorder.spans(),
                           frames_served=s["frames_served"],
                           frames_dropped=s["frames_dropped"]) == []
