"""FCN frame sweep: the sweep-vs-tiler equivalence battery.

The contract under test (streaming/fcn_sweep.py): scoring a 28x28 window
from the full-frame sweep trunk is EQUAL to `Tiler.extract`+`score` on the
host-extracted patch — word-exact int32 for the fixed substrates (interior
AND border windows, thanks to the masked-weight edge maps), float-tight
(~1 ulp of XLA conv accumulation order) for the float backends — and
therefore frozen-clip detections are identical between the two paths, both
offline and through the streaming pipeline.  The geometry/edge contract
(positions on the stride-4 pooled lattice, wraparound-only fixed configs)
must fail loudly, never approximately.
"""
import numpy as np
import pytest

from repro.core import backends as B
from repro.core import fixed_point as fxp
from repro.core import smallnet
from repro.serving.vision_engine import VisionEngine
from repro.streaming.fcn_sweep import FcnSweep, sweep_feature_maps
from repro.streaming.pipeline import StreamingPipeline
from repro.streaming.sources import SyntheticVideoSource
from repro.streaming.tiler import Tiler, tile_positions

FIXED_BACKENDS = ("fixed", "fixed_pallas")
PARITY_BACKENDS = ("ref", "fixed", "fixed_pallas")


@pytest.fixture(scope="module")
def params():
    return smallnet.seeded_params()


@pytest.fixture(scope="module")
def clip():
    return SyntheticVideoSource(n_frames=3, seed=7)


@pytest.fixture(scope="module")
def frame112(clip):
    return clip.frames()[0]


@pytest.fixture(scope="module")
def small_frame():
    """36x36: 3x3 = 9 windows at stride 4 — cheap enough for the Pallas
    interpreter backends."""
    rng = np.random.default_rng(5)
    return rng.random((36, 36, 1)).astype(np.float32)


@pytest.fixture(scope="module")
def calibrated(params, frame112):
    """Shared (tiler, sweep) pair at stride 8 with the threshold pinned to
    the 80th pct of first-frame 'fixed' confidences (deterministic nonzero
    detections on the frozen clip)."""
    t0 = Tiler(stride=8)
    tiles, _ = t0.extract(frame112)
    conf = t0._confidences(t0.score(params, tiles, backend="fixed")).max(-1)
    thr = float(np.quantile(conf, 0.8))
    return Tiler(stride=8, threshold=thr), FcnSweep(stride=8, threshold=thr)


# ---------------------------------------------------------------------------
# geometry
# ---------------------------------------------------------------------------

def test_sweep_positions_match_tiler_lattice():
    for stride in (4, 8, 12, 28):
        assert FcnSweep(stride=stride).positions((112, 112)) == \
            tile_positions((112, 112), 28, stride)


def test_edge_contract_fails_loudly():
    with pytest.raises(ValueError, match="multiple of 4"):
        FcnSweep(stride=14)                       # off-lattice stride
    with pytest.raises(ValueError, match="multiple of 4"):
        FcnSweep(patch=30)                        # off-lattice patch
    with pytest.raises(ValueError, match="edge contract"):
        FcnSweep(stride=8).positions((110, 112))  # clamped window off-lattice
    with pytest.raises(ValueError, match="one frame per call"):
        FcnSweep().score({}, np.zeros((2, 112, 112, 1), np.float32))


def test_saturating_config_rejected(params, small_frame):
    sat = B.FixedBackend(cfg=fxp.FixedPointConfig(32, 16, saturate=True))
    with pytest.raises(NotImplementedError, match="wraparound"):
        FcnSweep(stride=4).score(params, small_frame[None], backend=sat)


# ---------------------------------------------------------------------------
# per-window score equality vs the host tiler
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", sorted(B.list_backends()))
def test_per_window_scores_match_tiler_every_backend(params, small_frame,
                                                     backend):
    """Every registered backend: sweep score == patch score per window —
    exact int32 words for integer-scored backends, allclose (the float
    convs' accumulation-order latitude) for float ones."""
    t, s = Tiler(stride=4), FcnSweep(stride=4)
    tiles, pos_t = t.extract(small_frame)
    fb, pos_s = s.extract(small_frame)
    assert pos_t == pos_s
    want = t.score(params, tiles, backend=backend)
    got = s.score(params, fb, backend=backend)
    assert got.shape == want.shape == (len(pos_t), 10)
    if np.issubdtype(want.dtype, np.integer):
        np.testing.assert_array_equal(got, want)
    else:
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("backend", FIXED_BACKENDS)
def test_full_frame_word_exact_including_border_windows(params, frame112,
                                                        backend):
    """112x112 at stride 4 (484 windows): every window's int32 score words
    — interior AND the edge-clamped border rows/cols — equal the host
    tiler's, which is the acceptance bar for detection parity."""
    t, s = Tiler(stride=4), FcnSweep(stride=4)
    tiles, pos = t.extract(frame112)
    want = t.score(params, tiles, backend=backend)
    got = s.score(params, s.extract(frame112)[0], backend=backend)
    np.testing.assert_array_equal(got, want)
    border = [i for i, (y, x) in enumerate(pos) if y == 84 or x == 84]
    assert border, "the clamped border windows must be part of the sweep"
    np.testing.assert_array_equal(got[border], want[border])


def test_fixed_vs_fixed_pallas_bitexact_through_sweep_trunk(params, frame112):
    """The two fixed substrates must agree word-for-word on all four
    role maps of the sweep trunk AND on the final window scores."""
    maps = {b: sweep_feature_maps(params, frame112.pixels, backend=b)
            for b in FIXED_BACKENDS}
    for name in ("interior", "last_row", "last_col", "corner"):
        a, b = maps["fixed"][name], maps["fixed_pallas"][name]
        assert a.dtype == b.dtype == np.int32
        assert a.shape == b.shape == (28, 28)
        np.testing.assert_array_equal(a, b, err_msg=f"map {name!r} drifted")
    s = FcnSweep(stride=4)
    fb, _ = s.extract(frame112)
    np.testing.assert_array_equal(
        s.score(params, fb, backend="fixed"),
        s.score(params, fb, backend="fixed_pallas"))


# ---------------------------------------------------------------------------
# detections: offline parity + the pipeline path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", PARITY_BACKENDS)
def test_frozen_clip_detection_parity(params, clip, calibrated, backend):
    """Detections identical sweep-vs-tiler: strictly (float scores
    included) on the word-exact fixed substrates; labels/positions exact
    with 1e-5-tolerant scores on 'ref', whose conv summation order has
    ~1-ulp latitude between the two paths."""
    tiler, sweep = calibrated
    dt = [tiler.detect(params, f, backend=backend) for f in clip.frames()]
    ds = [sweep.detect(params, f, backend=backend) for f in clip.frames()]
    assert sum(len(d) for d in dt) > 0
    if backend in FIXED_BACKENDS:
        assert dt == ds
    else:
        for a, b in zip(dt, ds):
            assert [(d.label, d.y, d.x, d.size) for d in a] == \
                [(d.label, d.y, d.x, d.size) for d in b]
            np.testing.assert_allclose([d.score for d in a],
                                       [d.score for d in b], atol=1e-5)


def test_min_mass_gate_matches_tiler(params, frame112, calibrated):
    thr = calibrated[0].threshold
    t = Tiler(stride=8, threshold=thr, min_mass=0.04)
    s = FcnSweep(stride=8, threshold=thr, min_mass=0.04)
    dt = t.detect(params, frame112, backend="fixed")
    ds = s.detect(params, frame112, backend="fixed")
    assert dt == ds
    # the gate actually bit: fewer (or equal) detections than ungated
    assert len(ds) <= len(calibrated[1].detect(params, frame112,
                                               backend="fixed"))


def test_confidence_grid_matches_tiler_on_sweep_lattice(params, frame112,
                                                        calibrated):
    tiler, sweep = calibrated
    tiles, pos = tiler.extract(frame112)
    fb, _ = sweep.extract(frame112)
    gt = tiler.confidence_grid(tiler.score(params, tiles, backend="fixed"), pos)
    gs = sweep.confidence_grid(sweep.score(params, fb, backend="fixed"), pos)
    assert gt.shape == gs.shape == (12, 12)      # range(0,84,8)+[84] per axis
    np.testing.assert_array_equal(gs, gt)


def test_pipeline_sweep_serves_offline_sweep_detections(params, clip,
                                                        calibrated):
    _, sweep = calibrated
    eng = VisionEngine(params, backend="fixed", batch_size=64, warmup=False)
    pipe = StreamingPipeline(clip, eng, sweep)
    res = pipe.run()
    s = pipe.stats()
    assert s["accounted"] and s["frames_served"] == len(clip)
    offline = [sweep.detect(params, f, backend="fixed") for f in clip.frames()]
    assert [r.detections for r in res] == offline
    assert s["detections_total"] == sum(len(d) for d in offline) > 0


def test_pipeline_sweep_rejects_engines_without_model(calibrated):
    class NoModel:
        def serve(self, tiles):
            return []
    with pytest.raises(TypeError, match="params/backend"):
        StreamingPipeline(SyntheticVideoSource(n_frames=1), NoModel(),
                          calibrated[1])


# ---------------------------------------------------------------------------
# conv_trunk / dense_head split (the smallnet refactor the sweep rides on)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ("ref", "fixed", "fixed_pallas", "int8"))
def test_apply_equals_trunk_plus_head(params, backend):
    rng = np.random.default_rng(2)
    imgs = rng.random((4, 28, 28, 1)).astype(np.float32)
    whole = np.asarray(smallnet.apply(params, imgs, backend=backend))
    feats = smallnet.conv_trunk(params, imgs, backend=backend)
    split = np.asarray(smallnet.dense_head(params, feats, backend=backend))
    if np.issubdtype(whole.dtype, np.integer):
        np.testing.assert_array_equal(split, whole)
    else:
        np.testing.assert_array_equal(split, whole)  # same ops, same order


def test_conv_trunk_shapes(params):
    imgs = np.zeros((2, 28, 28, 1), np.float32)
    assert smallnet.conv_trunk(params, imgs, backend="ref").shape == (2, 7, 7, 1)
    assert smallnet.conv_trunk(params, imgs, backend="fixed").shape == (2, 7, 7)


# ---------------------------------------------------------------------------
# Tiler.confidence_grid regression (satellite): non-product position lists
# ---------------------------------------------------------------------------

def test_confidence_grid_rejects_non_product_positions():
    t = Tiler()
    scores = np.full((3, 10), 0.5, np.float32)
    with pytest.raises(ValueError, match="rectangular"):
        t.confidence_grid(scores, [(0, 0), (0, 14), (14, 7)])


def test_confidence_grid_derives_cols_from_positions():
    t = Tiler()
    pos = [(y, x) for y in (0, 14) for x in (0, 14, 28)]
    grid = t.confidence_grid(np.tile(np.linspace(0, 1, 10, dtype=np.float32),
                                     (6, 1)), pos)
    assert grid.shape == (2, 3)
