"""Unit tests for the observability subsystem (`repro/obs`).

Pins the contracts the serving stack and the CI trace smoke rely on:
nearest-rank percentile semantics on tiny samples, bounded-memory
instruments, the Prometheus exposition round-trip, span lifecycle and
terminal-status rules, and the flight recorder's ring/trip behavior.
"""
import json
import math

import pytest

from repro.obs import metrics as M
from repro.obs import recorder as R
from repro.obs import trace as T


@pytest.fixture(autouse=True)
def _tracing_off():
    """Never leak a process-wide tracer into other tests."""
    yield
    T.disable()


# -- percentile: nearest-rank, pinned on tiny samples -------------------------

class TestPercentile:
    def test_single_sample_every_q(self):
        for q in (0, 1, 50, 99, 100):
            assert M.percentile([10.0], q) == 10.0

    def test_four_samples_pinned(self):
        xs = [4.0, 1.0, 3.0, 2.0]          # unsorted on purpose
        # nearest-rank: k = max(1, ceil(q/100 * 4)), 1-indexed into sorted
        assert M.percentile(xs, 0) == 1.0
        assert M.percentile(xs, 25) == 1.0
        assert M.percentile(xs, 50) == 2.0
        assert M.percentile(xs, 75) == 3.0
        assert M.percentile(xs, 76) == 4.0
        assert M.percentile(xs, 99) == 4.0
        assert M.percentile(xs, 100) == 4.0

    def test_two_samples(self):
        assert M.percentile([5.0, 9.0], 50) == 5.0
        assert M.percentile([5.0, 9.0], 51) == 9.0

    def test_is_always_an_observed_value(self):
        xs = [0.1, 0.9]
        # nearest-rank never interpolates (np.percentile would give 0.5)
        assert M.percentile(xs, 50) in xs

    def test_empty_raises(self):
        with pytest.raises(ValueError):
            M.percentile([], 50)

    def test_out_of_range_q_raises(self):
        with pytest.raises(ValueError):
            M.percentile([1.0], -1)
        with pytest.raises(ValueError):
            M.percentile([1.0], 101)


# -- instruments --------------------------------------------------------------

class TestCounter:
    def test_inc(self):
        c = M.Counter("hits", {})
        c.inc()
        c.inc(4)
        assert c.value == 5

    def test_negative_rejected(self):
        c = M.Counter("hits", {})
        with pytest.raises(ValueError):
            c.inc(-1)


class TestGauge:
    def test_set_and_hwm(self):
        g = M.Gauge("depth", {})
        g.set(3)
        g.set(7)
        g.set(2)
        assert g.value == 2
        assert g.hwm == 7
        g.reset_hwm()
        assert g.hwm == 2


class TestHistogram:
    def test_counts_and_moments_exact(self):
        h = M.Histogram("lat", {}, buckets=(1.0, 10.0))
        for v in (0.5, 2.0, 3.0, 100.0):
            h.observe(v)
        assert h.count == 4
        assert h.sum == pytest.approx(105.5)
        assert h.min == 0.5
        assert h.max == 100.0

    def test_bucket_assignment(self):
        h = M.Histogram("lat", {}, buckets=(1.0, 10.0))
        for v in (0.5, 1.0, 2.0, 3.0, 100.0):
            h.observe(v)
        # per-bucket counts, `le` semantics, +inf last: boundary value
        # 1.0 lands in the le=1.0 bucket
        assert h.bucket_counts == [2, 2, 1]

    def test_bounded_memory(self):
        h = M.Histogram("lat", {}, buckets=(1.0,), reservoir=16)
        for i in range(1000):
            h.observe(float(i))
        assert len(h.samples()) == 16          # the bound
        assert h.count == 1000                 # exact counters unaffected
        assert h.max == 999.0

    def test_percentile_from_reservoir(self):
        h = M.Histogram("lat", {}, buckets=(1.0,))
        for v in (1.0, 2.0, 3.0, 4.0):
            h.observe(v)
        assert h.percentile(50) == 2.0

    def test_summary_ms(self):
        h = M.Histogram("lat", {}, buckets=(1.0,))
        h.observe(0.010)
        s = h.summary_ms()
        assert s["n"] == 1
        assert s["p50_ms"] == pytest.approx(10.0)
        assert M.Histogram("lat", {}, buckets=(1.0,)).summary_ms() == {"n": 0}

    def test_non_increasing_buckets_raise(self):
        with pytest.raises(ValueError):
            M.Histogram("lat", {}, buckets=(2.0, 1.0))
        with pytest.raises(ValueError):
            M.Histogram("lat", {}, buckets=(1.0, 1.0))


class TestRegistry:
    def test_get_or_create_same_instance(self):
        reg = M.Registry()
        a = reg.counter("hits", route="x")
        b = reg.counter("hits", route="x")
        assert a is b
        assert reg.counter("hits", route="y") is not a

    def test_type_mismatch_raises(self):
        reg = M.Registry()
        reg.counter("thing")
        with pytest.raises(TypeError):
            reg.gauge("thing")

    def test_prometheus_round_trip(self):
        reg = M.Registry()
        reg.counter("requests", route="a").inc(3)
        reg.gauge("depth", q="main").set(5)
        h = reg.histogram("lat_seconds", buckets=(0.1, 1.0))
        h.observe(0.05)
        h.observe(0.5)
        h.observe(2.0)
        parsed = M.parse_prometheus(reg.to_prometheus())
        assert parsed['requests_total{route="a"}'] == 3
        assert parsed['depth{q="main"}'] == 5
        assert parsed['lat_seconds_bucket{le="0.1"}'] == 1
        assert parsed['lat_seconds_bucket{le="1.0"}'] == 2
        assert parsed['lat_seconds_bucket{le="+Inf"}'] == 3
        assert parsed["lat_seconds_count"] == 3
        assert parsed["lat_seconds_sum"] == pytest.approx(2.55)

    def test_instance_labels_unique(self):
        assert M.instance_label("eng") != M.instance_label("eng")


class TestSummarizeLatency:
    def test_values(self):
        out = M.summarize_latency([0.010, 0.020], window_s=2.0)
        assert out["latency_p50_ms"] == pytest.approx(10.0)
        assert out["latency_max_ms"] == pytest.approx(20.0)
        assert out["throughput_qps"] == pytest.approx(1.0)

    def test_zero_window(self):
        assert M.summarize_latency([0.01], window_s=0.0)[
            "throughput_qps"] == 0.0

    def test_empty_raises(self):
        with pytest.raises(ValueError):
            M.summarize_latency([], window_s=1.0)


# -- spans --------------------------------------------------------------------

class TestTracer:
    def test_start_end_lifecycle(self):
        tr = T.Tracer(R.FlightRecorder(capacity=64))
        root = tr.start("frame", "f-0", index=0)
        child = tr.start("tile", "f-0", parent=root)
        tr.end(child)
        tr.end(root, "served")
        assert child.parent_id == root.span_id
        assert root.status == "served" and root.terminal
        assert child.status == "ok" and not child.terminal
        assert root.t_end >= root.t_start
        assert child.t_start >= root.t_start

    def test_double_end_raises(self):
        tr = T.Tracer(R.FlightRecorder(capacity=8))
        s = tr.start("x", "t")
        tr.end(s)
        with pytest.raises(RuntimeError):
            tr.end(s)

    def test_span_ids_unique_and_increasing(self):
        tr = T.Tracer(R.FlightRecorder(capacity=8))
        a = tr.start("a", "t")
        b = tr.start("b", "t")
        assert b.span_id > a.span_id

    def test_point_is_instantaneous(self):
        tr = T.Tracer(R.FlightRecorder(capacity=8))
        p = tr.point("dispatch", "t", "shed:door", replica=1)
        assert 0.0 <= p.duration_s < 0.01     # two adjacent clock reads
        assert p.terminal
        assert p.tags == {"replica": 1}

    def test_context_manager_marks_errors(self):
        tr = T.Tracer(R.FlightRecorder(capacity=8))
        with pytest.raises(RuntimeError):
            with tr.span("work", "t"):
                raise RuntimeError("boom")
        (s,) = tr.recorder.spans()
        assert s.status == "error"

    def test_end_at_uses_given_clock(self):
        tr = T.Tracer(R.FlightRecorder(capacity=8))
        s = tr.start("x", "t")
        tr.end_at(s, s.t_start + 1.5, "served")
        assert s.duration_s == pytest.approx(1.5)

    def test_emit_materializes_finished_span(self):
        tr = T.Tracer(R.FlightRecorder(capacity=8))
        root = tr.emit("request", "t", 1.0, 3.0, "served", uid=7)
        child = tr.emit("queue_wait", "t", 1.0, 2.0, parent=root)
        assert root.terminal and root.tags == {"uid": 7}
        assert child.parent_id == root.span_id
        assert len(tr.recorder) == 2

    def test_enable_disable(self):
        assert T.get() is None
        tr = T.enable(capacity=16)
        assert T.get() is tr
        T.disable()
        assert T.get() is None

    def test_span_dict_round_trip(self):
        tr = T.Tracer(R.FlightRecorder(capacity=8))
        s = tr.emit("request", "t", 1.0, 2.0, "shed:deadline", uid=3)
        assert T.Span.from_dict(s.to_dict()) == s


# -- flight recorder ----------------------------------------------------------

class TestFlightRecorder:
    def _span(self, tr, i):
        return tr.emit("frame", f"f-{i}", float(i), float(i) + 1.0, "served")

    def test_ring_is_bounded(self):
        rec = R.FlightRecorder(capacity=4)
        tr = T.Tracer(rec)
        for i in range(10):
            self._span(tr, i)
        assert len(rec) == 4
        assert rec.evicted == 6
        assert [s.trace_id for s in rec.spans()] == [
            "f-6", "f-7", "f-8", "f-9"]

    def test_dump_and_load_round_trip(self, tmp_path):
        rec = R.FlightRecorder(capacity=16)
        tr = T.Tracer(rec)
        for i in range(3):
            self._span(tr, i)
        path = rec.dump_jsonl(str(tmp_path / "t.jsonl"),
                              reason="manual", detail="x")
        header, spans = R.load_jsonl(path)
        assert header["reason"] == "manual"
        assert header["n_spans"] == 3
        assert [s.trace_id for s in spans] == ["f-0", "f-1", "f-2"]
        assert spans == rec.spans()

    def test_load_rejects_headerless_file(self, tmp_path):
        p = tmp_path / "bad.jsonl"
        p.write_text(json.dumps({"name": "frame"}) + "\n")
        with pytest.raises(ValueError):
            R.load_jsonl(str(p))

    def test_trip_rate_limited(self, tmp_path):
        rec = R.FlightRecorder(capacity=8, dump_dir=str(tmp_path),
                               trip_limit=2)
        tr = T.Tracer(rec)
        self._span(tr, 0)
        paths = [rec.trip("slo_violation", f"n{i}") for i in range(5)]
        assert sum(p is not None for p in paths) == 2
        assert rec.trip_counts() == {"slo_violation": 5}
        assert len(list(tmp_path.glob("flight_slo_violation_*.jsonl"))) == 2

    def test_dump_prometheus(self, tmp_path):
        reg = M.Registry()
        reg.counter("ticks").inc(2)
        path = R.dump_prometheus(str(tmp_path / "m.prom"), registry=reg)
        parsed = M.parse_prometheus(open(path).read())
        assert parsed["ticks_total"] == 2


# -- reconciliation -----------------------------------------------------------

def _mk(tr, name, tid, t0, t1, status="ok", parent=None):
    return tr.emit(name, tid, t0, t1, status, parent=parent)


class TestReconcile:
    def test_clean_set_reconciles(self):
        tr = T.Tracer(R.FlightRecorder(capacity=64))
        for i in range(3):
            root = _mk(tr, "frame", f"f-{i}", 0.0, 10.0, "served")
            _mk(tr, "tile", f"f-{i}", 1.0, 2.0, parent=root)
        _mk(tr, "frame", "f-3", 0.0, 10.0, "dropped:infer/deadline")
        fails = R.reconcile(tr.recorder.spans(),
                            frames_served=3, frames_dropped=1)
        assert fails == []

    def test_count_mismatch_detected(self):
        tr = T.Tracer(R.FlightRecorder(capacity=64))
        _mk(tr, "frame", "f-0", 0.0, 1.0, "served")
        fails = R.reconcile(tr.recorder.spans(),
                            frames_served=2, frames_dropped=0)
        assert any("served" in f for f in fails)

    def test_double_fate_detected(self):
        tr = T.Tracer(R.FlightRecorder(capacity=64))
        _mk(tr, "frame", "f-0", 0.0, 1.0, "served")
        _mk(tr, "frame", "f-0", 0.0, 1.0, "dropped:tile/queue_full")
        fails = R.reconcile(tr.recorder.spans(),
                            frames_served=1, frames_dropped=1)
        assert any("more than one root" in f for f in fails)

    def test_non_terminal_root_detected(self):
        tr = T.Tracer(R.FlightRecorder(capacity=64))
        _mk(tr, "frame", "f-0", 0.0, 1.0, "ok")
        fails = R.reconcile(tr.recorder.spans(), frames_served=0,
                            frames_dropped=0)
        assert any("non-terminally" in f for f in fails)

    def test_unended_root_detected(self):
        tr = T.Tracer(R.FlightRecorder(capacity=64))
        s = tr.start("frame", "f-0")
        rec_spans = [s]
        fails = R.reconcile(rec_spans, frames_served=0, frames_dropped=0)
        assert any("never ended" in f for f in fails)

    def test_child_escaping_parent_detected(self):
        tr = T.Tracer(R.FlightRecorder(capacity=64))
        root = _mk(tr, "frame", "f-0", 0.0, 1.0, "served")
        _mk(tr, "tile", "f-0", 0.5, 2.0, parent=root)   # ends after parent
        fails = R.reconcile(tr.recorder.spans(),
                            frames_served=1, frames_dropped=0)
        assert any("escapes" in f for f in fails)

    def test_backwards_clock_detected(self):
        tr = T.Tracer(R.FlightRecorder(capacity=64))
        _mk(tr, "frame", "f-0", 5.0, 1.0, "served")
        fails = R.reconcile(tr.recorder.spans(),
                            frames_served=1, frames_dropped=0)
        assert any("backwards" in f for f in fails)

    def test_nested_request_roots_share_trace_id(self):
        # request spans under a frame legitimately share the frame's
        # trace_id — uniqueness applies only to true roots (no parent)
        tr = T.Tracer(R.FlightRecorder(capacity=64))
        frame = _mk(tr, "frame", "f-0", 0.0, 10.0, "served")
        for _ in range(3):
            _mk(tr, "request", "f-0", 1.0, 2.0, "served", parent=frame)
        fails = R.reconcile(tr.recorder.spans(), served=3, shed=0,
                            root_name="request")
        assert fails == []


class TestWaterfall:
    def test_renders_all_spans(self):
        tr = T.Tracer(R.FlightRecorder(capacity=64))
        root = _mk(tr, "frame", "f-0", 0.0, 10.0, "served")
        _mk(tr, "tile", "f-0", 1.0, 2.0, parent=root)
        out = R.waterfall(tr.recorder.spans(), "f-0")
        assert "frame" in out and "tile" in out and "served" in out

    def test_max_spans_truncates_explicitly(self):
        tr = T.Tracer(R.FlightRecorder(capacity=64))
        root = _mk(tr, "frame", "f-0", 0.0, 10.0, "served")
        for i in range(10):
            _mk(tr, "request", "f-0", 1.0, 2.0, "served", parent=root)
        out = R.waterfall(tr.recorder.spans(), "f-0", max_spans=4)
        assert "+7 more spans" in out

    def test_unknown_trace(self):
        assert "no spans" in R.waterfall([], "nope")


def test_latency_buckets_are_strictly_increasing():
    bs = M.LATENCY_BUCKETS_S
    assert all(a < b for a, b in zip(bs, bs[1:]))
    assert not math.isinf(bs[-1])
