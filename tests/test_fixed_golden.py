"""Golden-vector regression: frozen int32 words for every fixed-point stage.

tests/golden/fixed_golden.json pins the bit-exact int32 outputs of each
pipeline stage (conv, maxpool, PLAN sigmoid, dense, and the fused
conv+PLAN+pool launch) for Q16.16 and Q8.8 in wraparound, saturate, and
truncate modes, with max_int/min_int words injected in the inputs.  Both
the emulated "fixed" substrate and the fixed_pallas kernels must reproduce
every word — any arithmetic drift (rounding, wrap order, limb bugs) fails
here first, against vectors that cannot silently regenerate themselves.

Regenerate (only after an INTENTIONAL semantics change) with:
    PYTHONPATH=src python tests/golden/gen_fixed_golden.py
"""
import json
import pathlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backends as B
from repro.core import fixed_point as fxp
from repro.kernels.fixed_conv import (fixed_conv2d, fixed_maxpool2x2,
                                      fixed_sigmoid)
from repro.kernels.quant_matmul import fixed_dense

_GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "fixed_golden.json").read_text())

CFGS = {name: fxp.FixedPointConfig(**spec)
        for name, spec in _GOLDEN["configs"].items()}


def _i32(a):
    return jnp.asarray(np.asarray(a), jnp.int32)


def _assert_words(got, want, what):
    np.testing.assert_array_equal(
        np.asarray(got, np.int64), np.asarray(want, np.int64),
        err_msg=f"{what}: fixed-point words drifted from golden vectors")


@pytest.fixture(params=sorted(CFGS), ids=sorted(CFGS))
def case(request):
    return CFGS[request.param], _GOLDEN["cases"][request.param]


def test_golden_covers_both_formats_and_modes():
    cfgs = list(CFGS.values())
    assert {c.total_bits for c in cfgs} == {32, 16}
    assert any(c.saturate for c in cfgs) and any(not c.saturate for c in cfgs)
    assert any(not c.round_nearest for c in cfgs)


def test_golden_inputs_exercise_extreme_words(case):
    cfg, g = case
    x = np.asarray(g["conv"]["x"], np.int64)
    assert cfg.max_int in x and cfg.min_int in x, \
        "golden conv input must contain max_int and min_int words"


def test_conv_golden_fixed_emulated(case):
    cfg, g = case
    got = B.conv_fixed(_i32(g["conv"]["x"]), _i32(g["conv"]["w4"]),
                       jnp.int32(g["conv"]["b"]), cfg)
    _assert_words(got, g["conv"]["out"], "emulated conv")


def test_conv_golden_fixed_pallas(case):
    cfg, g = case
    got = fixed_conv2d(_i32(g["conv"]["x"]), _i32(g["conv"]["w4"]),
                       jnp.int32(g["conv"]["b"]), cfg=cfg)
    _assert_words(got, g["conv"]["out"], "pallas conv")


def test_fused_conv_plan_pool_golden(case):
    cfg, g = case
    want = g["conv"]["out_fused_plan_pool"]
    emu = B.maxpool_fixed(fxp.fixed_sigmoid_plan(
        B.conv_fixed(_i32(g["conv"]["x"]), _i32(g["conv"]["w4"]),
                     jnp.int32(g["conv"]["b"]), cfg), cfg))
    _assert_words(emu, want, "emulated conv+plan+pool")
    got = fixed_conv2d(_i32(g["conv"]["x"]), _i32(g["conv"]["w4"]),
                       jnp.int32(g["conv"]["b"]), cfg=cfg,
                       activation="plan", pool=True)
    _assert_words(got, want, "fused pallas conv+plan+pool")


def test_pool_golden_both_substrates(case):
    cfg, g = case
    _assert_words(B.maxpool_fixed(_i32(g["pool"]["x"])), g["pool"]["out"],
                  "emulated maxpool")
    _assert_words(fixed_maxpool2x2(_i32(g["pool"]["x"])), g["pool"]["out"],
                  "pallas maxpool")


def test_sigmoid_golden_both_substrates(case):
    cfg, g = case
    _assert_words(fxp.fixed_sigmoid_plan(_i32(g["sigmoid"]["x"]), cfg),
                  g["sigmoid"]["out"], "emulated PLAN sigmoid")
    _assert_words(fixed_sigmoid(_i32(g["sigmoid"]["x"]), cfg=cfg),
                  g["sigmoid"]["out"], "pallas PLAN sigmoid")


def test_dense_golden_both_substrates(case):
    cfg, g = case
    emu = fxp.fixed_add(
        fxp.fixed_matmul(_i32(g["dense"]["x"]), _i32(g["dense"]["w"]), cfg),
        _i32(g["dense"]["b"]).reshape(1, -1), cfg)
    _assert_words(emu, g["dense"]["out"], "emulated dense")
    got = fixed_dense(_i32(g["dense"]["x"]), _i32(g["dense"]["w"]),
                      _i32(g["dense"]["b"]), cfg=cfg)
    _assert_words(got, g["dense"]["out"], "pallas dense")
