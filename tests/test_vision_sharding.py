"""Vision-serving sharding rules (distributed/sharding.py preset).

Acceptance for the mesh-sharded serving path: on a single CPU device,
`smallnet.apply` under `make_vision_rules(mesh)` is numerically identical
to the unsharded path for EVERY registered backend — exact int32 word
equality for the fixed-point substrates, and bitwise float equality for the
rest (a sharding constraint partitions, it never rounds).

Unlike test_sharding.py (hypothesis-gated LM policy properties), this file
runs on the bare tier-1 environment.
"""
import jax.numpy as jnp
import numpy as np
import pytest

import jax

from repro.core import backends, smallnet
from repro.distributed import sharding as shd
from repro.launch.mesh import make_serving_mesh

BACKENDS = backends.list_backends()


@pytest.fixture(scope="module")
def setup(rng):
    params = smallnet.init_params(jax.random.key(0))
    # nonzero biases so bias handling is inside the parity check
    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(jax.random.key(1), len(leaves))
    params = jax.tree_util.tree_unflatten(treedef, [
        p + 0.1 * jax.random.normal(k, p.shape, p.dtype)
        for p, k in zip(leaves, keys)])
    images = jnp.asarray(rng.uniform(0.0, 1.0, (9, 28, 28, 1)), jnp.float32)
    return params, images


def test_vision_rules_preset():
    mesh = make_serving_mesh()
    rules = shd.make_vision_rules(mesh)
    assert rules["batch"] in ("data", ("data",), ("pod", "data"))
    assert shd.vision_batch_axes(mesh) == ("data",)
    assert shd.vision_batch_multiple(mesh) == mesh.devices.size
    # everything except batch is replicated — smallNet's 510 params are tiny
    assert all(v is None for k, v in rules.items() if k != "batch")


@pytest.mark.parametrize("backend", BACKENDS)
def test_sharded_apply_identical_to_unsharded(setup, backend):
    params, images = setup
    base = np.asarray(smallnet.apply(params, images, backend=backend))
    mesh = make_serving_mesh()
    with mesh, shd.sharding_rules(shd.make_vision_rules(mesh)):
        shard = np.asarray(smallnet.apply(params, images, backend=backend))
    # exact for every dtype — int32 words for fixed/fixed_pallas, bitwise
    # floats for the rest: a sharding constraint partitions, it never rounds
    np.testing.assert_array_equal(shard, base)


@pytest.mark.parametrize("backend", ["ref", "fixed", "fixed_pallas"])
def test_sharded_jitted_step_identical(setup, backend):
    """The engine-shaped program: jit with NamedSharding-constrained in/out
    and the rules live at trace time, compared against a plain jit."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    params, images = setup
    be = backends.get_backend(backend)
    p = be.prepare_params(params)
    mesh = make_serving_mesh()
    rules = shd.make_vision_rules(mesh)

    def fwd(pp, x):
        with shd.sharding_rules(rules):
            return smallnet.apply(pp, x, backend=be)

    with mesh:
        sharded = jax.jit(
            fwd,
            in_shardings=(NamedSharding(mesh, P()),
                          NamedSharding(mesh, P(rules["batch"], None, None, None))),
            out_shardings=NamedSharding(mesh, P(rules["batch"], None)))
        got = np.asarray(sharded(p, images))
    want = np.asarray(jax.jit(
        lambda pp, x: smallnet.apply(pp, x, backend=be))(p, images))
    np.testing.assert_array_equal(got, want)


def test_constrain_batch_noop_without_rules(setup):
    params, images = setup
    x = jnp.ones((4, 7, 7))
    assert smallnet._constrain_batch(x) is x          # no rules -> identity
