"""Open-loop load generator: determinism, process shape, replay clocking."""
import time

import numpy as np
import pytest

from repro.streaming.loadgen import PROCESSES, Arrival, LoadGen, arrival_cv


@pytest.mark.parametrize("process", PROCESSES)
def test_schedule_deterministic_per_seed(process):
    """Two LoadGens with equal args emit byte-identical workloads — the
    SyntheticVideoSource contract at the traffic layer."""
    mk = lambda: LoadGen(process=process, rate_qps=400, duration_s=2.0,
                         n_streams=4, seed=11)
    a, b = mk().schedule(), mk().schedule()
    assert a == b
    assert len(a) > 0
    # ...and a different seed is a different workload
    c = LoadGen(process=process, rate_qps=400, duration_s=2.0,
                n_streams=4, seed=12).schedule()
    assert [x.t for x in a] != [x.t for x in c]


@pytest.mark.parametrize("process", PROCESSES)
def test_schedule_shape(process):
    gen = LoadGen(process=process, rate_qps=600, duration_s=2.0,
                  n_streams=3, seed=0)
    sched = gen.schedule()
    ts = [a.t for a in sched]
    assert ts == sorted(ts)                          # time-ordered
    assert [a.uid for a in sched] == list(range(len(sched)))
    assert all(0.0 <= a.t < gen.duration_s for a in sched)
    assert {a.stream for a in sched} <= set(range(3))
    assert all(0 <= a.label <= 9 for a in sched)
    # realized load near nominal: tight for (in)homogeneous Poisson, loose
    # for bursty — 3 streams x 2s is only a handful of on/off cycles, so
    # the realized rate of the modulated process swings hard around its
    # duty-normalized mean
    lo, hi = (0.5, 1.7) if process == "bursty" else (0.7, 1.3)
    assert lo * 600 <= gen.offered_qps <= hi * 600


def test_images_deterministic_and_shaped():
    gen = LoadGen(process="poisson", rate_qps=100, n_requests=32, seed=3)
    imgs = gen.images()
    assert imgs.shape == (len(gen), 28, 28, 1) and imgs.dtype == np.float32
    assert imgs.min() >= 0.0 and imgs.max() <= 1.0
    np.testing.assert_array_equal(
        imgs, LoadGen(process="poisson", rate_qps=100,
                      n_requests=32, seed=3).images())
    # per-uid render, independent of call order
    a = gen.schedule()[5]
    np.testing.assert_array_equal(gen.image(a), imgs[5])


def test_fixed_count_mode_sizes_duration():
    """n requests at rate r occupy n/r seconds: overload rows take the same
    wall time as underload rows."""
    gen = LoadGen(process="poisson", rate_qps=500, n_requests=250, seed=0)
    assert gen.duration_s == pytest.approx(0.5)
    with pytest.raises(ValueError):
        LoadGen(rate_qps=10, duration_s=1.0, n_requests=10)
    with pytest.raises(ValueError):
        LoadGen(rate_qps=10)


def test_bursty_is_burstier_than_poisson():
    """The Markov-modulated process must actually produce heavier-tailed
    inter-arrival gaps at the same mean rate (CV > Poisson's ~1)."""
    kw = dict(rate_qps=800, duration_s=4.0, n_streams=2, seed=5)
    cv_p = arrival_cv(LoadGen(process="poisson", **kw))
    cv_b = arrival_cv(LoadGen(process="bursty", **kw))
    assert cv_b > cv_p * 1.3
    # duty-cycle normalization holds the average rate (mean is rate-true)
    n_p = len(LoadGen(process="poisson", **kw))
    n_b = len(LoadGen(process="bursty", **kw))
    assert 0.6 * n_p <= n_b <= 1.4 * n_p


def test_diurnal_ramps_toward_midday():
    """The inhomogeneous rate peaks mid-window: the middle half of the
    schedule must hold clearly more arrivals than the outer half."""
    gen = LoadGen(process="diurnal", rate_qps=800, duration_s=4.0,
                  n_streams=2, seed=9, diurnal_floor=0.1)
    ts = np.asarray([a.t for a in gen.schedule()])
    mid = ((ts >= 1.0) & (ts < 3.0)).sum()
    outer = len(ts) - mid
    assert mid > 1.5 * outer


def test_replay_open_loop_clocking():
    """replay() emits on the generator's clock: scheduled timestamps are
    handed to the callback, the full schedule is submitted even when the
    'server' is a black hole, and wall time tracks the duration."""
    gen = LoadGen(process="poisson", rate_qps=200, duration_s=0.5,
                  n_streams=2, seed=1)
    got = []
    t0 = time.perf_counter()
    n = gen.replay(lambda a, t: got.append((a, t)))
    wall = time.perf_counter() - t0
    assert n == len(gen) == len(got)
    assert all(isinstance(a, Arrival) for a, _ in got)
    # scheduled stamps are monotone and span ~the schedule
    stamps = [t for _, t in got]
    assert stamps == sorted(stamps)
    assert stamps[-1] - stamps[0] == pytest.approx(
        gen.schedule()[-1].t - gen.schedule()[0].t, abs=1e-6)
    assert wall >= gen.schedule()[-1].t * 0.9        # it really paced itself


def test_replay_speed_compresses_schedule():
    gen = LoadGen(process="poisson", rate_qps=100, duration_s=1.0,
                  n_streams=1, seed=2)
    t0 = time.perf_counter()
    gen.replay(lambda a, t: None, speed=20.0)
    assert time.perf_counter() - t0 < 0.5            # 1s schedule, 20x speed


def test_bad_args_raise():
    with pytest.raises(ValueError):
        LoadGen(process="lunar", rate_qps=10, duration_s=1.0)
    with pytest.raises(ValueError):
        LoadGen(rate_qps=0, duration_s=1.0)
    with pytest.raises(ValueError):
        LoadGen(rate_qps=10, duration_s=1.0, n_streams=0)
    with pytest.raises(ValueError):
        LoadGen(rate_qps=10, duration_s=1.0, diurnal_floor=0.0)
