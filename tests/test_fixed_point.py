"""Bit-exactness and bound properties of the Qm.n fixed-point substrate."""
import pytest

hp = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
st = pytest.importorskip("hypothesis.strategies")
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixed_point as fxp

I32 = st.integers(-2**31, 2**31 - 1)


def _wrap32(x: np.ndarray) -> np.ndarray:
    return ((x + 2**31) % 2**32 - 2**31).astype(np.int64)


@hp.given(st.lists(I32, min_size=1, max_size=64),
          st.lists(I32, min_size=1, max_size=64))
@hp.settings(max_examples=100, deadline=None)
def test_fixed_mul_truncation_bit_exact(a, b):
    n = min(len(a), len(b))
    a = np.array(a[:n], np.int64)
    b = np.array(b[:n], np.int64)
    cfg = fxp.FixedPointConfig(32, 16, round_nearest=False)
    got = np.asarray(fxp.fixed_mul(jnp.asarray(a, jnp.int32),
                                   jnp.asarray(b, jnp.int32), cfg), np.int64)
    want = _wrap32((a * b) >> 16)
    np.testing.assert_array_equal(got, want)


@hp.given(st.lists(I32, min_size=1, max_size=64),
          st.lists(I32, min_size=1, max_size=64))
@hp.settings(max_examples=100, deadline=None)
def test_fixed_mul_rounding_bit_exact(a, b):
    n = min(len(a), len(b))
    a = np.array(a[:n], np.int64)
    b = np.array(b[:n], np.int64)
    cfg = fxp.FixedPointConfig(32, 16, round_nearest=True)
    got = np.asarray(fxp.fixed_mul(jnp.asarray(a, jnp.int32),
                                   jnp.asarray(b, jnp.int32), cfg), np.int64)
    want = _wrap32(((a * b) >> 16) + (((a * b) >> 15) & 1))
    np.testing.assert_array_equal(got, want)


@hp.given(st.floats(-30000.0, 30000.0))
@hp.settings(max_examples=200, deadline=None)
def test_roundtrip_error_bound(x):
    xf = fxp.from_fixed(fxp.to_fixed(jnp.float32(x)), fxp.Q16_16)
    assert abs(float(xf) - np.float32(x)) <= 2 ** -16


@pytest.mark.parametrize("cfg", [fxp.Q16_16, fxp.FixedPointConfig(32, 20),
                                 fxp.FixedPointConfig(32, 8)])
def test_fixed_matmul_matches_float(cfg, rng):
    x = rng.uniform(-2, 2, (8, 16)).astype(np.float32)
    w = rng.uniform(-2, 2, (16, 4)).astype(np.float32)
    got = fxp.from_fixed(fxp.fixed_matmul(fxp.to_fixed(jnp.asarray(x), cfg),
                                          fxp.to_fixed(jnp.asarray(w), cfg), cfg), cfg)
    tol = 16 * 4.0 * 2 ** -cfg.frac_bits + 1e-4
    np.testing.assert_allclose(np.asarray(got), x @ w, atol=tol)


def test_plan_sigmoid_literature_bound():
    x = jnp.linspace(-10, 10, 4001)
    err = jnp.max(jnp.abs(fxp.sigmoid_plan_f32(x) - jax.nn.sigmoid(x)))
    assert float(err) <= 0.0190            # Amin et al. 1997 bound (~0.0189)


def test_fixed_sigmoid_matches_float_plan(rng):
    x = rng.uniform(-8, 8, 512).astype(np.float32)
    qx = fxp.to_fixed(jnp.asarray(x))
    got = fxp.from_fixed(fxp.fixed_sigmoid_plan(qx), fxp.Q16_16)
    want = fxp.sigmoid_plan_f32(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-4)


def test_saturating_add():
    cfg = fxp.FixedPointConfig(32, 16, saturate=True)
    big = jnp.asarray([2**31 - 10], jnp.int32)
    out = fxp.fixed_add(big, big, cfg)
    assert int(out[0]) == cfg.max_int
