"""Post-training quantization properties (the paper's train->bake flow)."""
import pytest

hp = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
st = pytest.importorskip("hypothesis.strategies")
import jax.numpy as jnp
import numpy as np

from repro.core import ptq


@hp.given(st.integers(0, 2**31 - 1), st.floats(0.1, 100.0))
@hp.settings(max_examples=50, deadline=None)
def test_quantize_roundtrip_bound(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, scale, (16, 8)), jnp.float32)
    qt = ptq.quantize(x)
    err = jnp.abs(qt.dequantize() - x)
    # symmetric int8: per-channel error <= scale/2 = absmax/254
    bound = jnp.max(jnp.abs(x), axis=0, keepdims=True) / 127.0 / 2.0 + 1e-6
    assert bool(jnp.all(err <= bound))


def test_per_channel_beats_per_tensor(rng):
    # one loud channel: per-channel scales must hurt the quiet channels less
    x = np.ones((64, 4), np.float32)
    x[:, 0] *= 100.0
    xq_pc = ptq.quantize(jnp.asarray(x), ptq.QuantConfig(per_channel=True))
    xq_pt = ptq.quantize(jnp.asarray(x), ptq.QuantConfig(per_channel=False))
    err_pc = float(jnp.abs(xq_pc.dequantize() - x)[:, 1:].max())
    err_pt = float(jnp.abs(xq_pt.dequantize() - x)[:, 1:].max())
    assert err_pc < err_pt


def test_quantize_tree_structure(rng):
    params = {"dense": {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
                        "b": jnp.zeros((4,), jnp.float32)},
              "norm": jnp.ones((8,), jnp.float32)}
    qp = ptq.quantize_tree(params)
    assert isinstance(qp["dense"]["w"], ptq.QuantTensor)
    assert not isinstance(qp["dense"]["b"], ptq.QuantTensor)   # 1-D stays float
    deq = ptq.dequantize_tree(qp)
    assert deq["dense"]["w"].shape == (8, 4)
    errs = ptq.quantization_error(params, qp)
    assert all(v < 0.02 for v in errs.values())


def test_quantized_matmul_accuracy(rng):
    x = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    xq = ptq.quantize(x, ptq.QuantConfig(per_channel=False))
    wq = ptq.quantize(w)
    got = ptq.quantized_matmul_ref(xq, ptq.QuantTensor(wq.q, wq.scale.reshape(-1)))
    rel = float(jnp.linalg.norm(got - x @ w) / jnp.linalg.norm(x @ w))
    assert rel < 0.02


def test_activation_calibration(rng):
    samples = jnp.asarray(rng.normal(size=(1024,)), jnp.float32)
    s = ptq.calibrate_activation_scale(samples)
    q = ptq.quantize_activation(samples, s)
    assert q.q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(q.dequantize() - samples))) <= float(s.reshape(())) / 2 + 1e-6
