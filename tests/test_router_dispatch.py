"""Router dispatch regressions: the cold-fleet SLO hole, round-robin
re-aliasing under healthy-set churn, and the torn slo pick snapshot.

Each test here was red against the pre-fix router:

  * `_projected_waits` projected 0.0 wait for every replica when the fleet
    had no serving history — even with an arbitrarily deep backlog — so the
    slo door never shed during a cold-start burst.
  * round_robin indexed `clock % len(healthy)`: when the healthy set
    churned (failover, autoscale spawn/retire) the rotation re-aliased,
    double-dispatching to one replica while starving another.
  * the slo policy read the wait map and the depth tiebreaker in two
    separately-locked passes, so a concurrent submit landing between them
    made the pick inconsistent with either view of the fleet.
"""
import collections

import jax
import numpy as np
import pytest

from repro.core import smallnet
from repro.serving.router import ReplicaRouter
from repro.serving.vision_engine import VisionEngine


@pytest.fixture(scope="module")
def params():
    return smallnet.init_params(jax.random.key(0))


def _engine(params, **kw):
    kw.setdefault("backend", "ref")
    kw.setdefault("batch_size", 1)
    kw.setdefault("warmup", False)
    return VisionEngine(params, **kw)


# ---------------------------------------------------------------------------
# 1. cold-fleet SLO hole
# ---------------------------------------------------------------------------


def test_seed_rate_comes_from_min_step_floor(params):
    eng = _engine(params, batch_size=4, min_step_s=0.05)
    assert eng.service_rate_qps() is None        # no history yet
    assert eng.seed_rate_qps() == pytest.approx(80.0)   # 4 / 0.05
    assert _engine(params).seed_rate_qps() is None      # no floor, no seed


def test_cold_fleet_slo_door_sheds_on_burst(params):
    """2x-capacity burst at a COLD fleet (no serving history anywhere):
    the slo door must shed.  Pre-fix, every projected wait was 0.0 and all
    40 requests were queued toward a blown p99."""
    # two replicas, min_step_s floor: deterministic capacity 20 qps each,
    # so a 100 ms SLO tolerates a depth of 2 per replica (wait = depth/20)
    router = ReplicaRouter(
        [_engine(params, min_step_s=0.05) for _ in range(2)],
        policy="slo", slo_ms=100.0)
    uids = [router.submit(np.zeros((28, 28, 1), np.float32))
            for _ in range(40)]
    shed = router.pop_shed(uids)
    st = router.stats()
    assert st["n"] == 0                          # nothing served: still cold
    assert shed, "cold fleet admitted a 2x-capacity burst without shedding"
    assert set(shed.values()) == {"slo_wait"}
    # the door opened for what the fleet CAN plausibly serve (depth <= 2
    # per replica within the 100 ms budget), and shed the rest
    admitted = len(uids) - len(shed)
    assert 2 <= admitted <= 8
    assert st["accounted"]


def test_cold_fleet_unknown_rate_with_backlog_is_pessimistic(params):
    """No floor, no history: an idle replica projects 0.0 (serve now), but
    ANY backlog with no rate evidence projects an infinite wait — the door
    sheds instead of betting the deadline on an unknowable rate."""
    router = ReplicaRouter([_engine(params)], policy="slo", slo_ms=50.0)
    img = np.zeros((28, 28, 1), np.float32)
    first = router.submit(img)                   # depth 0: admitted
    second = router.submit(img)                  # depth 1, rate unknown
    shed = router.pop_shed([first, second])
    assert first not in shed
    assert shed.get(second) == "slo_wait"


# ---------------------------------------------------------------------------
# 2. round-robin re-aliasing under churn
# ---------------------------------------------------------------------------


def test_round_robin_no_double_dispatch_on_failover(params):
    """Deterministic red-before case: after serving replica 2, replica 0
    fails.  The modular clock re-aliased (clock=3, healthy=[1,2], 3%2=1)
    and dispatched to 2 AGAIN, starving 1; stable-id rotation advances to
    the next surviving id."""
    router = ReplicaRouter([_engine(params) for _ in range(3)],
                           policy="round_robin")
    assert [router._pick()[0] for _ in range(3)] == [0, 1, 2]
    router._errors[0] = RuntimeError("replica 0 died")
    assert router._pick()[0] == 1                # pre-fix: 2 (double hit)
    assert router._pick()[0] == 2


def test_round_robin_near_uniform_under_spawn_retire_churn(params):
    """Scripted churn — fail, retire, spawn — with dispatch counts per
    phase: rotation over stable ids keeps every phase near-uniform (max
    and min counts within 1) and never picks the same replica twice in a
    row while siblings are healthy."""
    router = ReplicaRouter([_engine(params) for _ in range(3)],
                           policy="round_robin")
    phases = []

    def run_phase(n_picks):
        counts = collections.Counter(router._pick()[0]
                                     for _ in range(n_picks))
        phases.append(counts)

    run_phase(7)                                 # [0, 1, 2]
    router._errors[1] = RuntimeError("fault")    # failover churn
    run_phase(8)                                 # [0, 2]
    router.replicas.append(_engine(params))      # autoscale spawn
    router._pending.append([])
    router._served_by.setdefault(3, 0)
    run_phase(9)                                 # [0, 2, 3]
    router._retired.add(0)                       # autoscale retire
    run_phase(8)                                 # [2, 3]
    for counts in phases:
        assert max(counts.values()) - min(counts.values()) <= 1, phases
    # churn boundaries included: no consecutive double-dispatch anywhere
    picks = [router._pick()[0] for _ in range(6)]
    assert all(a != b for a, b in zip(picks, picks[1:]))


# ---------------------------------------------------------------------------
# 3. torn slo pick snapshot
# ---------------------------------------------------------------------------


class _ShiftyReplica:
    """Stand-in replica whose load() changes between successive reads —
    the situation a concurrent submit creates.  Counts its reads so the
    test can pin 'exactly one consistent snapshot per pick'."""

    def __init__(self, loads, rate):
        self._loads = list(loads)
        self._rate = rate
        self.load_calls = 0
        self.batch_size = 8

    def load(self):
        self.load_calls += 1
        return self._loads.pop(0) if len(self._loads) > 1 \
            else self._loads[0]

    def service_rate_qps(self):
        return self._rate

    def seed_rate_qps(self):
        return None


def test_slo_pick_reads_one_snapshot(params):
    """Equal projected waits tiebreak on depth.  Pre-fix the tiebreaker
    re-read queue_depths() under a second lock acquisition; with replica
    0's load shifting 0 -> 100 between the reads, the pick flipped to
    replica 1 — disagreeing with the wait map it had just computed.  One
    snapshot means one load() read per replica and a pick consistent with
    that frozen view."""
    shifty = _ShiftyReplica(loads=[0, 100], rate=50.0)
    steady = _ShiftyReplica(loads=[0], rate=50.0)
    router = ReplicaRouter([shifty, steady], policy="slo", slo_ms=100.0)
    i, shed = router._pick(100.0)
    assert shed is None
    assert i == 0                                # pre-fix: 1
    assert shifty.load_calls == 1
    assert steady.load_calls == 1


def test_projected_waits_pure_given_frozen_snapshot():
    """The wait map is a pure function of one snapshot: deterministic on
    replay, pessimistic (inf) only for backlogged replicas with no rate
    from any source, and 0.0 for idle unknowns."""
    snapshot = {0: (4, 50.0, None, 8),           # observed rate
                1: (4, None, 25.0, 8),           # seed rate only
                2: (0, None, None, 8),           # idle, unknown rate
                3: (9, None, None, 8)}           # backlogged, unknown rate
    waits = ReplicaRouter._projected_waits_from(snapshot)
    assert waits == ReplicaRouter._projected_waits_from(dict(snapshot))
    assert waits[0] == pytest.approx(4 / 50.0)
    # replicas without their own observation borrow the fleet-median
    # observed rate (preferred over replica 1's own seed: real traffic
    # beats the configured floor)
    assert waits[1] == pytest.approx(4 / 50.0)
    assert waits[2] == 0.0
    assert waits[3] == pytest.approx(9 / 50.0)
    # with no observed rates anywhere, seeds take over
    waits = ReplicaRouter._projected_waits_from(
        {0: (4, None, 25.0, 8), 1: (2, None, None, 8)})
    assert waits[0] == pytest.approx(4 / 25.0)
    assert waits[1] == pytest.approx(2 / 25.0)   # fleet-median seed
    # pessimistic inf ONLY when no rate exists from ANY source fleet-wide
    # AND a full batch is already backlogged; a sub-batch cold queue is
    # absorbed by the first step (that step establishes the rate)
    waits = ReplicaRouter._projected_waits_from(
        {0: (8, None, None, 8), 1: (7, None, None, 8)})
    assert waits[0] == float("inf")
    assert waits[1] == 0.0
