"""Roofline/HLO-parser correctness: loop multipliers, dot flops, collectives."""
import jax
import jax.numpy as jnp

from repro.analysis.hlo_parse import analyze_hlo
from repro.analysis.roofline import (analytic_bytes, model_flops, param_count)
from repro.configs.base import SHAPES, get_config


def test_scan_loop_multiplier_exact():
    """An 8-iteration scanned matmul must report exactly 8x the body flops."""
    L, B, D = 8, 32, 64

    def model(x, ws):
        def step(c, w):
            return jnp.tanh(c @ w), None
        x, _ = jax.lax.scan(step, x, ws)
        return x

    xs = jax.ShapeDtypeStruct((B, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    txt = jax.jit(model).lower(xs, ws).compile().as_text()
    s = analyze_hlo(txt)
    expect = 2 * B * D * D * L
    assert abs(s.flops - expect) / expect < 0.01, (s.flops, expect)
    # and the once-count matches cost_analysis's known undercount
    assert abs(s.dot_flops_once - expect / L) / (expect / L) < 0.01


def test_unrolled_matches_scan_total():
    B, D, L = 16, 32, 4

    def scan_model(x, ws):
        x, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, ws)
        return x

    def unroll_model(x, ws):
        for i in range(L):
            x = x @ ws[i]
        return x

    xs = jax.ShapeDtypeStruct((B, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    s1 = analyze_hlo(jax.jit(scan_model).lower(xs, ws).compile().as_text())
    s2 = analyze_hlo(jax.jit(unroll_model).lower(xs, ws).compile().as_text())
    assert abs(s1.flops - s2.flops) / s2.flops < 0.01


def test_param_count_sane():
    """Analytic parameter counts should land near the arch's nameplate."""
    cases = {"llama3-405b": (380e9, 440e9),
             "granite-3-2b": (2.0e9, 3.3e9),
             "command-r-plus-104b": (95e9, 120e9),
             "qwen2.5-14b": (12e9, 17e9),
             "rwkv6-3b": (2.5e9, 3.9e9),
             "qwen3-moe-235b-a22b": (200e9, 260e9),
             "jamba-1.5-large-398b": (330e9, 420e9)}
    for arch, (lo, hi) in cases.items():
        total, active = param_count(get_config(arch))
        assert lo <= total <= hi, (arch, total)
        assert active <= total


def test_moe_active_params():
    total, active = param_count(get_config("qwen3-moe-235b-a22b"))
    assert active < 0.25 * total          # 235B total vs 22B active


def test_model_flops_monotone():
    cfg = get_config("granite-3-2b")
    t = model_flops(cfg, SHAPES["train_4k"])
    p = model_flops(cfg, SHAPES["prefill_32k"])
    d = model_flops(cfg, SHAPES["decode_32k"])
    assert t > p > d       # train(6ND, 1M tok) > prefill(2ND, 1M tok) > decode


def test_analytic_bytes_decode_dominated_by_cache():
    cfg = get_config("llama3-405b")
    b = analytic_bytes(cfg, SHAPES["decode_32k"], 256)
    params_b = param_count(cfg)[0] * 2 / 256
    assert b > params_b      # KV cache read exceeds weight read at B=128
