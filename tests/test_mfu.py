"""analysis/mfu.py: the analytic workload model is hand-countable at the
28x28 unit cell, scales exactly with frame area, brackets the megakernel's
DMA traffic between the fused ideal and the per-window tiler, and the
device database + MFU arithmetic can never silently produce a value
outside (0, 1]."""
import pytest

from repro.analysis import mfu
from repro.analysis.mfu import (BACKEND_NUMERICS, DEVICE_DB, DTYPE_CLASSES,
                                Workload, backend_numerics, lookup,
                                modeled_seconds, mfu_clock, resolve,
                                route_workload, trunk_workload)


# ---------------------------------------------------------------------------
# hand-counted unit cell
# ---------------------------------------------------------------------------

def test_deployed_workload_hand_count():
    """One 28x28 window: conv1 = 4 taps x 28x28, conv2 = 4 taps x 14x14,
    dense 49->10 — 2 flops per MAC."""
    wl = mfu.deployed_workload()
    assert wl.flops == 2 * (4 * 784 + 4 * 196 + 49 * 10) == 8820
    assert wl.bytes_in == 784 * 4
    assert wl.bytes_out == 10 * 4
    assert wl.bytes_params == 510 * 4


def test_trunk_workload_hand_count_28():
    wl = trunk_workload(28, 28, "trunk")
    assert wl.flops == 2 * (4 * 784 + 4 * 196) == 7840
    assert wl.bytes_in == 784 * 4
    assert wl.bytes_out == (784 // 16) * 4


def test_composed_cascade_hand_count_28():
    """Quad role-map cascade: 9 live taps over the full frame at level 0,
    25 live taps over the quarter-area maps at level 1."""
    wl = trunk_workload(28, 28, "sweep_composed")
    assert wl.flops == 2 * 9 * 784 + 2 * 25 * 196 == 23912


def test_tiler_workload_is_windows_times_deployed():
    d = mfu.deployed_workload()
    wl = mfu.tiler_workload(144)
    assert wl.flops == 144 * d.flops
    assert wl.bytes_in == 144 * d.bytes_in     # every window re-reads pixels
    assert wl.bytes_params == d.bytes_params   # weights counted once


# ---------------------------------------------------------------------------
# scaling laws
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("route", ["trunk", "sweep_composed"])
def test_frame_scaling_is_exactly_area(route):
    """Doubling H and W must scale flops and frame bytes by exactly 4 —
    param bytes are the constant remainder."""
    for base_side, H in ((112, 224), (128, 256), (128, 512)):
        base = trunk_workload(base_side, base_side, route)
        big = trunk_workload(H, H, route)
        k = (H * H) // (base_side * base_side)
        assert big.flops == k * base.flops
        assert big.bytes_in == k * base.bytes_in
        assert big.bytes_out == k * base.bytes_out
        assert big.bytes_params == base.bytes_params


def test_megakernel_bytes_bracketed():
    """The megakernel's input traffic is the real halo'd tile DMA: more
    than the fused ideal (halos re-read seams), far less than the
    per-window tiler (overlapping windows re-read everything)."""
    for H, n_windows in ((112, 144), (512, 3844)):
        ideal = trunk_workload(H, H, "trunk")
        mega = trunk_workload(H, H, "sweep_megakernel")
        tiler = mfu.tiler_workload(n_windows)
        assert ideal.bytes_in < mega.bytes_in < tiler.bytes_in
        # halo'd conv extents also cost slightly MORE arithmetic
        assert mega.flops > trunk_workload(H, H, "sweep_composed").flops


def test_megakernel_dma_matches_choose_tile():
    from repro.kernels.frame_trunk.ops import HALO, choose_tile
    H = W = 512
    th, tw = choose_tile(H, W)
    n_tiles = (H // th) * (W // tw)
    wl = trunk_workload(H, W, "sweep_megakernel")
    assert wl.bytes_in == n_tiles * (th + HALO) * (tw + HALO) * 4
    assert wl.bytes_out == 4 * (H // 4) * (W // 4) * 4


def test_hlo_crosscheck_agrees_with_model():
    """XLA's own conv FLOP count on the ref trunk matches the analytic
    model (the one path HLO can see — Pallas launches are opaque)."""
    from repro.analysis.run_roofline import _hlo_crosscheck
    assert _hlo_crosscheck() == []


# ---------------------------------------------------------------------------
# device database
# ---------------------------------------------------------------------------

def test_lookup_is_total():
    with pytest.raises(KeyError, match="unknown device kind"):
        lookup("quantum-abacus-9000")
    assert lookup("tpu-v5e").name == "tpu-v5e"              # exact key
    assert lookup("NVIDIA A100-SXM4-80GB").name == "a100"   # substring
    assert lookup("TPU v5 lite").name == "tpu-v5e"          # longest kind
    with pytest.raises(KeyError, match="no peak for dtype"):
        DEVICE_DB["cpu"].peak("fp4")


def test_every_entry_covers_every_dtype_class():
    for spec in DEVICE_DB.values():
        for dt in DTYPE_CLASSES:
            assert spec.peak(dt) > 0
        assert spec.mem_bw > 0


def test_resolve_cpu_is_interpret_fallback():
    spec, interpret = resolve()
    assert spec.name == "cpu"
    assert interpret is True


def test_backend_numerics_total():
    with pytest.raises(KeyError, match="no MFU numerics"):
        backend_numerics("tpu_only_backend")


# ---------------------------------------------------------------------------
# MFU arithmetic
# ---------------------------------------------------------------------------

def test_mfu_in_unit_interval_for_every_backend_and_route():
    """With the interpret-mode clock (the roofline floor), MFU is
    compute_floor / max(floors) — in (0, 1] by construction for every
    registered backend on every ledger route."""
    device = DEVICE_DB["cpu"]
    for backend in BACKEND_NUMERICS:
        dtype, wb = backend_numerics(backend)
        for route in mfu.ROUTE_WORKLOADS:
            wl = route_workload(route, 112, 112, 144, wb)
            t, basis = mfu_clock(wl, 123.0, device=device, dtype=dtype,
                                 interpret=True)
            assert basis == "roofline_model"
            assert t == modeled_seconds(wl, device=device, dtype=dtype)
            val = mfu.mfu(wl, t, device=device, dtype=dtype)
            assert 0.0 < val <= 1.0, (backend, route, val)


def test_mfu_clock_measured_on_real_hardware():
    device = DEVICE_DB["tpu-v5e"]
    wl = route_workload("sweep_megakernel", 112, 112, 144, 4)
    t, basis = mfu_clock(wl, 0.5, device=device, dtype="int32",
                         interpret=False)
    assert (t, basis) == (0.5, "measured")


def test_megakernel_attainable_mfu_beats_composed():
    """The structural claim the ledger gate pins: at the roofline floor,
    the megakernel's ~20x byte reduction turns into strictly higher MFU
    than the composed cascade on every backend."""
    device = DEVICE_DB["cpu"]
    for backend in ("fixed", "fixed_pallas"):
        dtype, wb = backend_numerics(backend)
        vals = {}
        for route in ("sweep_composed", "sweep_megakernel"):
            wl = route_workload(route, 112, 112, 144, wb)
            t = modeled_seconds(wl, device=device, dtype=dtype)
            vals[route] = mfu.mfu(wl, t, device=device, dtype=dtype)
        assert vals["sweep_megakernel"] > vals["sweep_composed"]


def test_achieved_rejects_nonpositive_time():
    wl = Workload("w", 100, 4, 4, 4)
    with pytest.raises(ValueError, match="positive duration"):
        mfu.achieved(wl, 0.0)
