"""Hypothesis property battery for the FCN sweep geometry.

Pure lattice math — no model evaluation — so the properties range over
arbitrary (H, W, stride) frames: the sweep's window set must equal
`tile_positions` on the stride-4 pooled lattice, every pooled-map gather
must stay in bounds (the 7x7 block of window (y, x) ends at pooled row
y/4 + 6 <= H/4 - 1), coverage must be complete whenever the stride does
not exceed the patch, and geometries that break the edge contract must
raise rather than quietly score a misaligned window.

Tier-1 runs the bounded versions; the `slow`-marked deep battery
multiplies the example budget for the nightly lane, mirroring
tests/test_fixed_pallas_props.py.
"""
import numpy as np
import pytest

hp = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.streaming.fcn_sweep import FcnSweep
from repro.streaming.tiler import tile_positions

PATCH = 28
POOL = 4


def _aligned_geometry():
    """(H, W, stride) satisfying the sweep edge contract."""
    side = st.integers(0, 40).map(lambda k: PATCH + POOL * k)
    stride = st.integers(1, 10).map(lambda j: POOL * j)
    return st.tuples(side, side, stride)


def _check_geometry(H, W, stride):
    s = FcnSweep(stride=stride)
    pos = s.positions((H, W))

    # identical to the host tiler's window set (same clamped edge handling)
    assert pos == tile_positions((H, W), PATCH, stride)

    # every window on the pooled lattice, fully inside the frame
    for y, x in pos:
        assert y % POOL == 0 and x % POOL == 0
        assert 0 <= y <= H - PATCH and 0 <= x <= W - PATCH

    # the position list is the full product of its row/col lattices, and
    # the counts match the stride arithmetic (what confidence_grid needs)
    ys = sorted({y for y, _ in pos})
    xs = sorted({x for _, x in pos})
    assert len(pos) == len(ys) * len(xs)
    assert ys == sorted(set(list(range(0, H - PATCH, stride)) + [H - PATCH]))

    # no out-of-bounds pooled gather: the window's 7x7 block ends in-map
    k = PATCH // POOL
    Hp, Wp = H // POOL, W // POOL  # pooled-map extent (H, W multiples of 4)
    for y, x in pos:
        assert y // POOL + k - 1 <= Hp - 1
        assert x // POOL + k - 1 <= Wp - 1

    # complete coverage whenever windows can overlap-or-touch
    if stride <= PATCH:
        covered = np.zeros((H, W), bool)
        for y, x in pos:
            covered[y:y + PATCH, x:x + PATCH] = True
        assert covered.all()


@hp.given(_aligned_geometry())
@hp.settings(max_examples=30, deadline=None)
def test_sweep_geometry_bounded(geom):
    _check_geometry(*geom)


@pytest.mark.slow
@hp.given(_aligned_geometry())
@hp.settings(max_examples=500, deadline=None)
def test_sweep_geometry_deep(geom):
    _check_geometry(*geom)


@hp.given(st.integers(PATCH, PATCH + 160), st.integers(1, 40))
@hp.settings(max_examples=30, deadline=None)
def test_misaligned_geometry_raises(H, stride):
    """Any (H - patch) % 4 != 0 frame or stride % 4 != 0 must raise."""
    if stride % POOL:
        with pytest.raises(ValueError):
            FcnSweep(stride=stride)
        return
    s = FcnSweep(stride=stride)
    if (H - PATCH) % POOL:
        with pytest.raises(ValueError):
            s.positions((H, H))
    else:
        assert s.positions((H, H))
