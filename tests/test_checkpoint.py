"""Fault tolerance: atomic checkpoints, bitwise restart, corruption
detection, retention, elastic (cross-mesh) restore."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (CheckpointManager, latest_step,
                                   restore_checkpoint, save_checkpoint)
from repro.configs.base import get_config
from repro.runtime.trainer import Trainer, TrainerConfig


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"w": jax.random.normal(k, (16, 8)),
            "b": jnp.arange(8, dtype=jnp.float32),
            "nested": {"m": jnp.ones((4,), jnp.bfloat16)}}


def test_save_restore_bitwise(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 7, t)
    assert latest_step(tmp_path) == 7
    r = restore_checkpoint(tmp_path, t)
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_corruption_detected(tmp_path):
    t = _tree()
    d = save_checkpoint(tmp_path, 1, t)
    man = json.loads((d / "manifest.json").read_text())
    next(iter(man["arrays"].values()))["crc32"] ^= 0xDEADBEEF
    (d / "manifest.json").write_text(json.dumps(man))
    with pytest.raises(IOError, match="corruption"):
        restore_checkpoint(tmp_path, t)


def test_atomic_no_partial_visible(tmp_path):
    # a .tmp dir must never be picked up as a checkpoint
    (tmp_path / "step_9.tmp").mkdir(parents=True)
    assert latest_step(tmp_path) is None


def test_manager_retention_and_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, _tree(s))
    mgr.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir())
    assert steps == [3, 4]
    (r, step) = mgr.restore_latest(_tree())
    assert step == 4


def test_restart_bitwise_identical(tmp_path):
    """Train 4 steps straight vs 2 steps -> crash -> resume 2 more: the
    resulting parameters must be bitwise identical (deterministic data +
    exact checkpoint)."""
    cfg = get_config("granite-3-2b").smoke()
    base = dict(total_steps=4, seq_len=32, global_batch=4, ckpt_every=2,
                log_every=100)
    t_full = Trainer(cfg, TrainerConfig(**base))
    state_full, hist_full = t_full.run()

    ckdir = tmp_path / "ck"
    t_a = Trainer(cfg, TrainerConfig(**{**base, "total_steps": 2},
                                     ckpt_dir=str(ckdir)))
    t_a.run()
    # "crash": new trainer process resumes from latest checkpoint
    t_b = Trainer(cfg, TrainerConfig(**base, ckpt_dir=str(ckdir)))
    state_b, hist_b = t_b.run()

    for a, b in zip(jax.tree_util.tree_leaves(state_full["params"]),
                    jax.tree_util.tree_leaves(state_b["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_restore_dtype_and_shape(tmp_path):
    """Restore with a different target structure dtype (elastic re-shard is
    exercised in test_sharding via subprocess; here: dtype casting path)."""
    t = _tree()
    save_checkpoint(tmp_path, 1, t)
    like = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), t)
    r = restore_checkpoint(tmp_path, like)
    for leaf in jax.tree_util.tree_leaves(r):
        assert leaf.dtype == jnp.float32
