"""Serving engine: batched decode, continuous refill, quantized deployment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import ptq
from repro.models import model as M
from repro.serving.engine import Engine, Request


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("granite-3-2b").smoke()
    m = M.build(cfg)
    params, _ = m.init(jax.random.key(0))
    return cfg, params


def _reqs(n, rng):
    return [Request(uid=i, prompt=rng.integers(1, 100, size=4).astype(np.int32),
                    max_new_tokens=4) for i in range(n)]


def test_all_requests_complete(setup, rng):
    cfg, params = setup
    eng = Engine(cfg, params, batch_size=2, max_len=32)
    reqs = _reqs(5, rng)                     # 5 requests > 2 slots -> refill
    done = eng.submit_and_run(reqs)
    assert all(r.done for r in done)
    assert all(len(r.out) == 4 for r in done)
    assert all(0 <= t < cfg.vocab for r in done for t in r.out)


def test_greedy_determinism(setup, rng):
    cfg, params = setup
    prompts = _reqs(2, np.random.default_rng(3))
    out1 = Engine(cfg, params, batch_size=2, max_len=32).submit_and_run(
        [Request(r.uid, r.prompt.copy(), r.max_new_tokens) for r in prompts])
    out2 = Engine(cfg, params, batch_size=2, max_len=32).submit_and_run(
        [Request(r.uid, r.prompt.copy(), r.max_new_tokens) for r in prompts])
    assert [r.out for r in out1] == [r.out for r in out2]


def test_quantized_deployment_flow(setup, rng):
    """The paper's pipeline on an LM: train(init) -> PTQ -> serve; the
    quantized engine must produce mostly the same greedy tokens."""
    cfg, params = setup
    qp = ptq.quantize_tree(params)
    deq = ptq.dequantize_tree(qp)
    reqs = _reqs(2, np.random.default_rng(5))
    base = Engine(cfg, params, batch_size=2, max_len=32).submit_and_run(
        [Request(r.uid, r.prompt.copy(), r.max_new_tokens) for r in reqs])
    quant = Engine(cfg, deq, batch_size=2, max_len=32).submit_and_run(
        [Request(r.uid, r.prompt.copy(), r.max_new_tokens) for r in reqs])
    agree = np.mean([a == b for r1, r2 in zip(base, quant)
                     for a, b in zip(r1.out, r2.out)])
    assert agree >= 0.5      # random-init logits are near-ties; int8 stays close


def test_int8_quanttensor_serving_direct(setup, rng):
    """Serve directly from QuantTensor (int8) params — the baked-deployment
    path (dequant-on-use in layers.linear/embed), no dequantized copy."""
    cfg, params = setup
    qp = ptq.quantize_tree(params)
    reqs = [Request(uid=i, prompt=rng.integers(1, 100, size=4).astype(np.int32),
                    max_new_tokens=3) for i in range(2)]
    done = Engine(cfg, qp, batch_size=2, max_len=32).submit_and_run(reqs)
    assert all(r.done and len(r.out) == 3 for r in done)
    assert all(0 <= t < cfg.vocab for r in done for t in r.out)
