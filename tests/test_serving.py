"""Serving engine: batched decode, continuous refill, quantized deployment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import ptq
from repro.models import model as M
from repro.serving.engine import Engine, Request


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("granite-3-2b").smoke()
    m = M.build(cfg)
    params, _ = m.init(jax.random.key(0))
    return cfg, params


def _reqs(n, rng):
    return [Request(uid=i, prompt=rng.integers(1, 100, size=4).astype(np.int32),
                    max_new_tokens=4) for i in range(n)]


def test_all_requests_complete(setup, rng):
    cfg, params = setup
    eng = Engine(cfg, params, batch_size=2, max_len=32)
    reqs = _reqs(5, rng)                     # 5 requests > 2 slots -> refill
    done = eng.submit_and_run(reqs)
    assert all(r.done for r in done)
    assert all(len(r.out) == 4 for r in done)
    assert all(0 <= t < cfg.vocab for r in done for t in r.out)


def test_greedy_determinism(setup, rng):
    cfg, params = setup
    prompts = _reqs(2, np.random.default_rng(3))
    out1 = Engine(cfg, params, batch_size=2, max_len=32).submit_and_run(
        [Request(r.uid, r.prompt.copy(), r.max_new_tokens) for r in prompts])
    out2 = Engine(cfg, params, batch_size=2, max_len=32).submit_and_run(
        [Request(r.uid, r.prompt.copy(), r.max_new_tokens) for r in prompts])
    assert [r.out for r in out1] == [r.out for r in out2]


def test_quantized_deployment_flow(setup, rng):
    """The paper's pipeline on an LM: train(init) -> PTQ -> serve; the
    quantized engine must produce mostly the same greedy tokens."""
    cfg, params = setup
    qp = ptq.quantize_tree(params)
    deq = ptq.dequantize_tree(qp)
    reqs = _reqs(2, np.random.default_rng(5))
    base = Engine(cfg, params, batch_size=2, max_len=32).submit_and_run(
        [Request(r.uid, r.prompt.copy(), r.max_new_tokens) for r in reqs])
    quant = Engine(cfg, deq, batch_size=2, max_len=32).submit_and_run(
        [Request(r.uid, r.prompt.copy(), r.max_new_tokens) for r in reqs])
    agree = np.mean([a == b for r1, r2 in zip(base, quant)
                     for a, b in zip(r1.out, r2.out)])
    assert agree >= 0.5      # random-init logits are near-ties; int8 stays close


def test_int8_quanttensor_serving_direct(setup, rng):
    """Serve directly from QuantTensor (int8) params — the baked-deployment
    path (dequant-on-use in layers.linear/embed), no dequantized copy."""
    cfg, params = setup
    qp = ptq.quantize_tree(params)
    reqs = [Request(uid=i, prompt=rng.integers(1, 100, size=4).astype(np.int32),
                    max_new_tokens=3) for i in range(2)]
    done = Engine(cfg, qp, batch_size=2, max_len=32).submit_and_run(reqs)
    assert all(r.done and len(r.out) == 3 for r in done)
    assert all(0 <= t < cfg.vocab for r in done for t in r.out)


# ---------------------------------------------------------------------------
# Vision engine: streaming single-image requests over batched backend steps
# ---------------------------------------------------------------------------

from repro.core import smallnet
from repro.launch.mesh import make_serving_mesh
from repro.serving.router import FleetExhaustedError, ReplicaRouter
from repro.serving.vision_engine import VisionEngine


@pytest.fixture(scope="module")
def vision_setup(rng):
    params = smallnet.init_params(jax.random.key(0))
    images = rng.uniform(0.0, 1.0, (104, 28, 28, 1)).astype(np.float32)
    return params, images


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_vision_engine_serves_100_requests(vision_setup, backend):
    """Acceptance: >= 100 queued single-image requests drain through batched
    jitted steps with per-request latency reported, for two backends."""
    params, images = vision_setup
    eng = VisionEngine(params, backend=backend, batch_size=32)
    res = eng.serve(list(images))
    assert len(res) == 104
    assert [r.uid for r in res] == list(range(104))
    assert all(r.latency_s > 0 for r in res)
    stats = eng.stats()
    assert stats["n"] == 104
    assert stats["batches"] == 4                      # ceil(104/32) batched steps
    assert stats["padded_slots"] == 4 * 32 - 104
    assert stats["latency_p95_ms"] >= stats["latency_p50_ms"] > 0
    assert stats["throughput_qps"] > 0


def test_vision_engine_matches_direct_apply(vision_setup):
    params, images = vision_setup
    eng = VisionEngine(params, backend="ref", batch_size=16)
    res = eng.serve(list(images[:20]))
    direct = smallnet.predict(smallnet.apply(params, jnp.asarray(images[:20]),
                                             backend="ref"))
    assert [r.pred for r in res] == [int(t) for t in direct]
    np.testing.assert_allclose(np.stack([r.scores for r in res]),
                               np.asarray(smallnet.apply(
                                   params, jnp.asarray(images[:20]))),
                               rtol=1e-6, atol=1e-6)


def test_vision_engine_async_submit_then_step(vision_setup):
    """submit() queues without running; step() serves at most one batch."""
    params, images = vision_setup
    eng = VisionEngine(params, backend="ref", batch_size=8)
    uids = [eng.submit(img) for img in images[:11]]
    assert eng.results() == {}                         # nothing served yet
    assert eng.step() == 8                             # first coalesced batch
    assert set(eng.results()) == set(uids[:8])
    assert eng.step() == 3                             # padded remainder batch
    assert eng.step() == 0                             # queue drained
    assert set(eng.results()) == set(uids)


def test_vision_engine_fixed_backend_int_scores(vision_setup):
    params, images = vision_setup
    eng = VisionEngine(params, backend="fixed", batch_size=8)
    res = eng.serve(list(images[:10]))
    assert all(r.scores.dtype == np.int32 for r in res)
    want = smallnet.predict(smallnet.apply(params, jnp.asarray(images[:10]),
                                           backend="fixed"))
    assert [r.pred for r in res] == [int(t) for t in want]


def test_vision_engine_fixed_pallas_serves_bit_exact_words(vision_setup):
    """The fused fixed kernel path through the FULL serving loop (padded
    batches, jitted step) must return the same int32 score words as an
    emulated-fixed engine serving the identical workload."""
    params, images = vision_setup
    res_k = VisionEngine(params, backend="fixed_pallas",
                         batch_size=8).serve(list(images[:20]))
    res_e = VisionEngine(params, backend="fixed",
                         batch_size=8).serve(list(images[:20]))
    assert all(r.scores.dtype == np.int32 for r in res_k)
    np.testing.assert_array_equal(np.stack([r.scores for r in res_k]),
                                  np.stack([r.scores for r in res_e]))
    assert [r.pred for r in res_k] == [r.pred for r in res_e]


# ---------------------------------------------------------------------------
# Engine lifecycle: continuous batching — the intake never closes (regression
# for the old wave model's run()/reopen() churn)
# ---------------------------------------------------------------------------


def test_vision_engine_intake_stays_open_across_drains(vision_setup):
    """run() drains the current queue but the intake stays open: submits
    after a drain serve on the next step, uids keep counting, and the
    served ledger accumulates across bursts."""
    params, images = vision_setup
    eng = VisionEngine(params, backend="ref", batch_size=4, warmup=False)
    eng.submit_many(list(images[:6]))
    assert eng.run() == 6
    res = eng.serve(list(images[6:9]))               # second burst just works
    assert [r.uid for r in res] == [6, 7, 8]
    s = eng.stats()
    assert s["n"] == 9 and s["submitted"] == 9 and s["accounted"]


def test_vision_engine_serving_thread_continuous_batches(vision_setup):
    """start() serves whatever arrives, across separated bursts, with no
    lifecycle calls in between; stop(drain=True) finishes the tail."""
    params, images = vision_setup
    eng = VisionEngine(params, backend="ref", batch_size=4)
    eng.start()
    try:
        uids1 = eng.submit_many(list(images[:5]))
        eng.wait(uids1, timeout=30)
        uids2 = eng.submit_many(list(images[5:8]))   # second burst, same engine
        eng.wait(uids2, timeout=30)
    finally:
        eng.stop()
    res = eng.pop_results(uids1 + uids2)
    assert sorted(res) == sorted(uids1 + uids2)
    assert eng.stats()["accounted"] and eng.stats()["shed"] == 0


# ---------------------------------------------------------------------------
# Mesh-sharded engine: the jitted step splits the batch over the serving mesh
# (degenerate 1-device mesh here; the multi-device case runs in a subprocess)
# ---------------------------------------------------------------------------


def test_vision_engine_sharded_serves_identical_words(vision_setup):
    """A mesh-sharded fixed-point engine must serve the exact int32 score
    words of the unsharded engine (sharding only partitions, never rounds)."""
    params, images = vision_setup
    mesh = make_serving_mesh()
    res_m = VisionEngine(params, backend="fixed", batch_size=8,
                         mesh=mesh).serve(list(images[:20]))
    res_u = VisionEngine(params, backend="fixed",
                         batch_size=8).serve(list(images[:20]))
    np.testing.assert_array_equal(np.stack([r.scores for r in res_m]),
                                  np.stack([r.scores for r in res_u]))
    assert [r.pred for r in res_m] == [r.pred for r in res_u]


def test_vision_engine_sharded_multi_device_subprocess(vision_setup):
    """8 virtual CPU devices: the engine rounds its batch to the mesh
    multiple, serves a ragged workload, and matches the unsharded engine
    word-for-word. Runs in a subprocess so the 1-device default of the rest
    of the suite is untouched."""
    import subprocess
    import sys
    import textwrap
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, numpy as np
        from repro.core import smallnet
        from repro.distributed import sharding as shd
        from repro.launch.mesh import make_serving_mesh
        from repro.serving.vision_engine import VisionEngine

        params = smallnet.init_params(jax.random.key(0))
        imgs = np.random.default_rng(0).uniform(
            0, 1, (19, 28, 28, 1)).astype(np.float32)
        mesh = make_serving_mesh()
        assert shd.vision_batch_multiple(mesh) == 8
        eng = VisionEngine(params, backend="fixed", batch_size=6, mesh=mesh)
        assert eng.batch_size == 8          # 6 rounded UP to the mesh multiple
        res = eng.serve(list(imgs))
        base = VisionEngine(params, backend="fixed",
                            batch_size=8).serve(list(imgs))
        ok = (len(res) == 19
              and all((a.scores == b.scores).all() and a.pred == b.pred
                      for a, b in zip(res, base))
              and eng.stats()["mesh_devices"] == 8)
        print(json.dumps({"ok": bool(ok)}))
    """)
    import os
    import pathlib
    src = str(pathlib.Path(__file__).parents[1] / "src")
    env = dict(os.environ,
               PYTHONPATH=src + os.pathsep + os.environ.get("PYTHONPATH", ""))
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=560, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    import json as _json
    assert _json.loads(r.stdout.strip().splitlines()[-1])["ok"]


# ---------------------------------------------------------------------------
# Replica router: least-loaded dispatch, failover isolation, fleet stats
# ---------------------------------------------------------------------------


def test_router_two_replicas_per_request_correct(vision_setup):
    """>= 2 replicas drive a workload to completion and every request's
    scores match a direct apply on the backend that served it."""
    params, images = vision_setup
    router = ReplicaRouter.from_backends(params, ["ref", "fixed"],
                                         batch_size=8, warmup=False)
    res = router.serve(list(images[:30]))
    assert len(res) == 30
    assert [r.uid for r in res] == list(range(30))
    names = [eng.backend.name for eng in router.replicas]
    direct = {n: np.asarray(smallnet.apply(params, jnp.asarray(images[:30]),
                                           backend=n)) for n in set(names)}
    for i, r in enumerate(res):
        want = direct[names[r.replica]][i]
        np.testing.assert_allclose(r.scores, want, rtol=1e-6, atol=1e-6)
        assert r.pred == int(np.argmax(want))
    s = router.stats()
    assert s["n"] == 30 and s["healthy"] == 2 and s["failed"] == []
    assert all(v > 0 for v in s["served_by"].values())   # both replicas worked


def test_router_least_loaded_dispatch(vision_setup):
    params, images = vision_setup
    router = ReplicaRouter.from_backends(params, ["ref", "ref", "ref"],
                                         batch_size=8, warmup=False)
    router.submit_many(list(images[:9]))
    assert router.queue_depths() == [3, 3, 3]            # balanced lanes
    # a pre-loaded replica is avoided until the others catch up
    router2 = ReplicaRouter.from_backends(params, ["ref", "ref"],
                                          batch_size=8, warmup=False)
    router2._pending[0] = [None] * 5                     # simulate deep lane
    assigned = [router2._assignment[router2.submit(images[0])]
                for _ in range(5)]
    assert assigned == [1, 1, 1, 1, 1]


def test_router_replica_failure_is_isolated(vision_setup):
    """One replica whose jitted step faults mid-drain must not poison the
    fleet: its requests fail over to the survivor and all complete."""
    params, images = vision_setup
    router = ReplicaRouter.from_backends(params, ["ref", "ref"],
                                         batch_size=8, warmup=False)

    def faulting_step(p, x):
        raise RuntimeError("replica hardware fault")

    router.replicas[0]._step_fn = faulting_step
    uids = router.submit_many(list(images[:20]))
    assert router.run() == 20
    assert set(router.results()) == set(uids)
    s = router.stats()
    assert s["failed"] == [0] and s["healthy"] == 1
    assert s["served_by"] == {0: 0, 1: 20}
    assert isinstance(router.errors()[0], RuntimeError)
    # post-fault submits route around the dead replica
    assert router._assignment[router.submit(images[0])] == 1


def test_router_reclaims_lane_stranded_on_dead_replica(vision_setup):
    """Requests routed to a replica in the window before its fault is
    recorded must fail over at the next run(), not sit on a lane nothing
    drains."""
    params, images = vision_setup
    router = ReplicaRouter.from_backends(params, ["ref", "ref"],
                                         batch_size=8, warmup=False)
    uids = router.submit_many(list(images[:6]))          # balanced 3 / 3
    router._errors[0] = RuntimeError("died before its drain")
    assert router.run() == 6                             # all six served
    assert set(router.results()) == set(uids)
    assert router.stats()["served_by"] == {0: 0, 1: 6}


def test_router_fleet_exhausted_raises(vision_setup):
    params, images = vision_setup
    router = ReplicaRouter.from_backends(params, ["ref"], batch_size=4,
                                         warmup=False)
    router.replicas[0]._step_fn = lambda p, x: (_ for _ in ()).throw(
        RuntimeError("down"))
    router.submit_many(list(images[:4]))
    with pytest.raises(FleetExhaustedError):
        router.run()


def test_router_stats_aggregation(vision_setup):
    """Fleet stats must reconcile with the per-replica engine stats and the
    routed results (latency from ROUTER submit, so >= engine latency)."""
    params, images = vision_setup
    router = ReplicaRouter.from_backends(params, ["ref", "plan"],
                                         batch_size=8, warmup=False)
    res = router.serve(list(images[:24]))
    s = router.stats()
    assert s["n"] == 24 == sum(s["served_by"].values())
    assert sum(p["n"] for p in s["per_replica"]) == 24
    assert s["latency_p95_ms"] >= s["latency_p50_ms"] > 0
    assert s["latency_max_ms"] >= max(r.latency_s for r in res) * 1e3 * (1 - 1e-9)
    assert s["throughput_qps"] > 0
    per_backend = {p["backend"]: p["n"] for p in s["per_replica"]}
    assert per_backend == {"ref": s["served_by"][0], "plan": s["served_by"][1]}
