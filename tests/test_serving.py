"""Serving engine: batched decode, continuous refill, quantized deployment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import ptq
from repro.models import model as M
from repro.serving.engine import Engine, Request


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("granite-3-2b").smoke()
    m = M.build(cfg)
    params, _ = m.init(jax.random.key(0))
    return cfg, params


def _reqs(n, rng):
    return [Request(uid=i, prompt=rng.integers(1, 100, size=4).astype(np.int32),
                    max_new_tokens=4) for i in range(n)]


def test_all_requests_complete(setup, rng):
    cfg, params = setup
    eng = Engine(cfg, params, batch_size=2, max_len=32)
    reqs = _reqs(5, rng)                     # 5 requests > 2 slots -> refill
    done = eng.submit_and_run(reqs)
    assert all(r.done for r in done)
    assert all(len(r.out) == 4 for r in done)
    assert all(0 <= t < cfg.vocab for r in done for t in r.out)


def test_greedy_determinism(setup, rng):
    cfg, params = setup
    prompts = _reqs(2, np.random.default_rng(3))
    out1 = Engine(cfg, params, batch_size=2, max_len=32).submit_and_run(
        [Request(r.uid, r.prompt.copy(), r.max_new_tokens) for r in prompts])
    out2 = Engine(cfg, params, batch_size=2, max_len=32).submit_and_run(
        [Request(r.uid, r.prompt.copy(), r.max_new_tokens) for r in prompts])
    assert [r.out for r in out1] == [r.out for r in out2]


def test_quantized_deployment_flow(setup, rng):
    """The paper's pipeline on an LM: train(init) -> PTQ -> serve; the
    quantized engine must produce mostly the same greedy tokens."""
    cfg, params = setup
    qp = ptq.quantize_tree(params)
    deq = ptq.dequantize_tree(qp)
    reqs = _reqs(2, np.random.default_rng(5))
    base = Engine(cfg, params, batch_size=2, max_len=32).submit_and_run(
        [Request(r.uid, r.prompt.copy(), r.max_new_tokens) for r in reqs])
    quant = Engine(cfg, deq, batch_size=2, max_len=32).submit_and_run(
        [Request(r.uid, r.prompt.copy(), r.max_new_tokens) for r in reqs])
    agree = np.mean([a == b for r1, r2 in zip(base, quant)
                     for a, b in zip(r1.out, r2.out)])
    assert agree >= 0.5      # random-init logits are near-ties; int8 stays close


def test_int8_quanttensor_serving_direct(setup, rng):
    """Serve directly from QuantTensor (int8) params — the baked-deployment
    path (dequant-on-use in layers.linear/embed), no dequantized copy."""
    cfg, params = setup
    qp = ptq.quantize_tree(params)
    reqs = [Request(uid=i, prompt=rng.integers(1, 100, size=4).astype(np.int32),
                    max_new_tokens=3) for i in range(2)]
    done = Engine(cfg, qp, batch_size=2, max_len=32).submit_and_run(reqs)
    assert all(r.done and len(r.out) == 3 for r in done)
    assert all(0 <= t < cfg.vocab for r in done for t in r.out)


# ---------------------------------------------------------------------------
# Vision engine: streaming single-image requests over batched backend steps
# ---------------------------------------------------------------------------

from repro.core import smallnet
from repro.serving.vision_engine import VisionEngine


@pytest.fixture(scope="module")
def vision_setup(rng):
    params = smallnet.init_params(jax.random.key(0))
    images = rng.uniform(0.0, 1.0, (104, 28, 28, 1)).astype(np.float32)
    return params, images


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_vision_engine_serves_100_requests(vision_setup, backend):
    """Acceptance: >= 100 queued single-image requests drain through batched
    jitted steps with per-request latency reported, for two backends."""
    params, images = vision_setup
    eng = VisionEngine(params, backend=backend, batch_size=32)
    res = eng.serve(list(images))
    assert len(res) == 104
    assert [r.uid for r in res] == list(range(104))
    assert all(r.latency_s > 0 for r in res)
    stats = eng.stats()
    assert stats["n"] == 104
    assert stats["batches"] == 4                      # ceil(104/32) batched steps
    assert stats["padded_slots"] == 4 * 32 - 104
    assert stats["latency_p95_ms"] >= stats["latency_p50_ms"] > 0
    assert stats["throughput_qps"] > 0


def test_vision_engine_matches_direct_apply(vision_setup):
    params, images = vision_setup
    eng = VisionEngine(params, backend="ref", batch_size=16)
    res = eng.serve(list(images[:20]))
    direct = smallnet.predict(smallnet.apply(params, jnp.asarray(images[:20]),
                                             backend="ref"))
    assert [r.pred for r in res] == [int(t) for t in direct]
    np.testing.assert_allclose(np.stack([r.scores for r in res]),
                               np.asarray(smallnet.apply(
                                   params, jnp.asarray(images[:20]))),
                               rtol=1e-6, atol=1e-6)


def test_vision_engine_async_submit_then_step(vision_setup):
    """submit() queues without running; step() serves at most one batch."""
    params, images = vision_setup
    eng = VisionEngine(params, backend="ref", batch_size=8)
    uids = [eng.submit(img) for img in images[:11]]
    assert eng.results() == {}                         # nothing served yet
    assert eng.step() == 8                             # first coalesced batch
    assert set(eng.results()) == set(uids[:8])
    assert eng.step() == 3                             # padded remainder batch
    assert eng.step() == 0                             # queue drained
    assert set(eng.results()) == set(uids)


def test_vision_engine_fixed_backend_int_scores(vision_setup):
    params, images = vision_setup
    eng = VisionEngine(params, backend="fixed", batch_size=8)
    res = eng.serve(list(images[:10]))
    assert all(r.scores.dtype == np.int32 for r in res)
    want = smallnet.predict(smallnet.apply(params, jnp.asarray(images[:10]),
                                           backend="fixed"))
    assert [r.pred for r in res] == [int(t) for t in want]


def test_vision_engine_fixed_pallas_serves_bit_exact_words(vision_setup):
    """The fused fixed kernel path through the FULL serving loop (padded
    batches, jitted step) must return the same int32 score words as an
    emulated-fixed engine serving the identical workload."""
    params, images = vision_setup
    res_k = VisionEngine(params, backend="fixed_pallas",
                         batch_size=8).serve(list(images[:20]))
    res_e = VisionEngine(params, backend="fixed",
                         batch_size=8).serve(list(images[:20]))
    assert all(r.scores.dtype == np.int32 for r in res_k)
    np.testing.assert_array_equal(np.stack([r.scores for r in res_k]),
                                  np.stack([r.scores for r in res_e]))
    assert [r.pred for r in res_k] == [r.pred for r in res_e]
