"""Per-arch smoke tests (assignment requirement): reduced same-family config,
one forward/train step on CPU, output shapes + no NaNs; plus decode/prefill
consistency for the dense family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, ShapeSpec, get_config
from repro.models import model as M
from repro.models import transformer

SMOKE_TRAIN = ShapeSpec("smoke_train", 64, 4, "train")
SMOKE_PREFILL = ShapeSpec("smoke_prefill", 64, 2, "prefill")
SMOKE_DECODE = ShapeSpec("smoke_decode", 64, 2, "decode")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).smoke()
    m = M.build(cfg)
    params, axes = m.init(jax.random.key(0))
    batch = M.synth_batch(cfg, SMOKE_TRAIN)
    loss, metrics = jax.jit(m.loss)(params, batch)
    assert jnp.isfinite(loss), arch
    logits, _ = jax.jit(m.forward)(params, batch)
    assert logits.shape == (4, 64, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # axes tree mirrors params tree
    assert (jax.tree_util.tree_structure(params).num_leaves
            == len(jax.tree_util.tree_leaves(
                axes, is_leaf=lambda x: isinstance(x, tuple))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch):
    cfg = get_config(arch).smoke()
    m = M.build(cfg)
    params, _ = m.init(jax.random.key(0))
    pb = M.synth_batch(cfg, SMOKE_PREFILL)
    logits, cache = jax.jit(m.prefill)(params, pb)
    assert logits.shape == (2, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits)))
    db = M.synth_batch(cfg, SMOKE_DECODE)
    lg, cache2 = jax.jit(m.decode_step)(params, db["cache"], db["token"], db["pos"])
    assert lg.shape == (2, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(lg)))
    # cache structure preserved (engine reuses buffers across steps)
    assert (jax.tree_util.tree_structure(db["cache"])
            == jax.tree_util.tree_structure(cache2))
    for a, b in zip(jax.tree_util.tree_leaves(db["cache"]),
                    jax.tree_util.tree_leaves(cache2)):
        assert a.shape == b.shape and a.dtype == b.dtype


def test_decode_matches_forward_dense():
    """Integration: token-by-token decode must reproduce the parallel
    forward pass logits (granite smoke, the dense GQA representative)."""
    cfg = dataclasses.replace(get_config("granite-3-2b").smoke(), q_chunk=8)
    m = M.build(cfg)
    params, _ = m.init(jax.random.key(1))
    T = 16
    toks = jax.random.randint(jax.random.key(2), (1, T), 0, cfg.vocab, jnp.int32)
    full_logits, _ = m.forward(params, {"tokens": toks})
    cache = transformer.zeros_cache(cfg, 1, T)
    step = jax.jit(m.decode_step)
    for t in range(T):
        lg, cache = step(params, cache, toks[:, t:t + 1], jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg[0]),
                                   np.asarray(full_logits[0, t]),
                                   rtol=2e-3, atol=2e-3)


def test_decode_matches_forward_rwkv():
    """Same consistency property for the recurrent (attention-free) family."""
    cfg = get_config("rwkv6-3b").smoke()
    m = M.build(cfg)
    params, _ = m.init(jax.random.key(1))
    T = 8
    toks = jax.random.randint(jax.random.key(2), (1, T), 0, cfg.vocab, jnp.int32)
    full_logits, _ = m.forward(params, {"tokens": toks})
    cache = transformer.zeros_cache(cfg, 1, T)
    step = jax.jit(m.decode_step)
    for t in range(T):
        lg, cache = step(params, cache, toks[:, t:t + 1], jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg[0]),
                                   np.asarray(full_logits[0, t]),
                                   rtol=3e-3, atol=3e-3)


def test_long_context_skip_rule():
    """DESIGN.md §4: long_500k runs only for sub-quadratic families."""
    expect = {"rwkv6-3b": True, "jamba-1.5-large-398b": True}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        assert cfg.supports_long_context() == expect.get(arch, False), arch


def test_exact_assigned_configs():
    """The full (non-smoke) configs must match the assignment table."""
    spec = {
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    }
    for arch, (L, d, H, K, ff, V) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, H, K, ff, V), arch
    moe = get_config("qwen3-moe-235b-a22b")
    assert (moe.n_experts, moe.top_k) == (128, 8)
    moon = get_config("moonshot-v1-16b-a3b")
    assert (moon.n_experts, moon.top_k) == (64, 6)
    jam = get_config("jamba-1.5-large-398b")
    assert (jam.n_experts, jam.top_k, jam.attn_period) == (16, 2, 8)
