"""Paper Table (§IV-C): classification accuracy across numeric paths.

Reproduces the paper's accuracy ladder (float CPU 93.47 % -> fixed-sim
88.03 % -> hardware 81 %) on the MNIST-proxy dataset, and extends it with
the paper's §III-B 'limitations of numerical representations' analysis: a
Qm.n fraction-bits sweep showing where fixed-point inference falls off.
"""
from __future__ import annotations

import time

import jax.numpy as jnp

from repro.core import deploy, fixed_point as fxp, smallnet
from repro.data import synth_mnist


def run(trained=None, n_test: int = 1500):
    t0 = time.perf_counter()
    if trained is None:
        trained = deploy.train_smallnet(n_train=8000, n_test=2000, epochs=16)
    rows = []
    accs = deploy.evaluate_all_paths(trained.params, n_test=n_test)
    for name, acc in accs.items():
        rows.append((f"accuracy/{name}", None, f"acc={acc:.4f}"))
    # Q-format sweep: fixed-point accuracy vs fraction bits
    xte, yte = synth_mnist.make_dataset(n_test, seed=1)
    xte = jnp.asarray(xte); yte = jnp.asarray(yte)
    for frac in (4, 6, 8, 10, 12, 16, 20):
        cfg = fxp.FixedPointConfig(32, frac)
        qp = smallnet.quantize_params_fixed(trained.params, cfg)
        acc = smallnet.accuracy(
            lambda q, x: smallnet.forward_fixed(q, x, cfg), qp, xte, yte)
        rows.append((f"accuracy/fixed_q{31-frac}_{frac}", None, f"acc={acc:.4f}"))
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(("accuracy_table_total", dt, f"n_test={n_test}"))
    return rows, trained
