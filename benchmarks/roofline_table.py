"""Roofline table: LLM three-term rows (from the 512-device dry-run sweep)
plus smallNet's own analytic hot-path rows (tiler / composed sweep /
megakernel sweep, ref + fixed_pallas numerics) — both read from
benchmarks/roofline_results.json, produced by
`python -m repro.analysis.run_roofline [--smoke]`.

    PYTHONPATH=src python -m benchmarks.roofline_table --smoke

--smoke recomputes the smallnet rows in-process (no JSON required) and
exits nonzero on NaN/zero-denominator rooflines or HLO-model drift — the
CI bench-smoke gate for the observability layer.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

_HERE = pathlib.Path(__file__).resolve().parent


def _smallnet_row(key: str, v: dict):
    return (f"roofline/{key}", None,
            f"bound={v['bound']} flops={v['flops']:.3g} "
            f"bytes={v['bytes']:.3g} intensity={v['intensity']:.1f} "
            f"attainable={v['attainable_flops']:.3g}FLOP/s "
            f"device={v.get('device', v.get('dtype', ''))}")


def run():
    rows = []
    p = _HERE / "roofline_results.json"
    if not p.exists():
        rows.append(("roofline/missing", None,
                     "run: PYTHONPATH=src python -m repro.analysis.run_roofline"))
        return rows
    res = json.loads(p.read_text())
    for key, v in sorted(res.items()):
        if "error" in v:
            rows.append((f"roofline/{key}", None, f"ERROR {v['error'][:60]}"))
            continue
        if key.startswith("smallnet"):
            rows.append(_smallnet_row(key, v))
            continue
        rows.append((f"roofline/{key}", v["step_time_s"] * 1e6,
                     f"dom={v['dominant']} comp={v['compute_s']*1e3:.1f}ms "
                     f"mem={v['memory_s']*1e3:.1f}ms coll={v['collective_s']*1e3:.1f}ms "
                     f"frac={v['roofline_fraction']:.3f} useful={v['useful_ratio']:.2f}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="recompute smallnet rooflines and gate finiteness "
                         "(nonzero exit on NaN/zero denominators)")
    ap.add_argument("--device", default="tpu-v5e")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    if args.smoke:
        from repro.analysis.run_roofline import smallnet_rows
        rows, failures = smallnet_rows(args.device)
        for key in sorted(rows):
            name, _, derived = _smallnet_row(key, rows[key])
            print(f"{name},,{derived}")
        for f in failures:
            print(f"roofline/FAIL,,{f}")
        print(f"roofline/result,,{'FAIL' if failures else 'OK'}")
        sys.exit(1 if failures else 0)

    for name, val, derived in run():
        val_s = f"{val:.2f}" if val is not None else ""
        print(f"{name},{val_s},{derived}")


if __name__ == "__main__":
    main()
