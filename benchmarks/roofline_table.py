"""Roofline table: three terms per (arch x shape), single-pod production mesh.
Reads benchmarks/roofline_results.json produced by
`python -m repro.analysis.run_roofline` (512-device dry-run process)."""
from __future__ import annotations

import json
import pathlib

_HERE = pathlib.Path(__file__).resolve().parent


def run():
    rows = []
    p = _HERE / "roofline_results.json"
    if not p.exists():
        rows.append(("roofline/missing", None,
                     "run: PYTHONPATH=src python -m repro.analysis.run_roofline"))
        return rows
    res = json.loads(p.read_text())
    for key, v in sorted(res.items()):
        if "error" in v:
            rows.append((f"roofline/{key}", None, f"ERROR {v['error'][:60]}"))
            continue
        rows.append((f"roofline/{key}", v["step_time_s"] * 1e6,
                     f"dom={v['dominant']} comp={v['compute_s']*1e3:.1f}ms "
                     f"mem={v['memory_s']*1e3:.1f}ms coll={v['collective_s']*1e3:.1f}ms "
                     f"frac={v['roofline_fraction']:.3f} useful={v['useful_ratio']:.2f}"))
    return rows
