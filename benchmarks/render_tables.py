"""Render §Dry-run and §Roofline markdown tables from the sweep JSONs into
EXPERIMENTS.md (between the *_TABLE_START/END markers).

    PYTHONPATH=src python -m benchmarks.render_tables
"""
from __future__ import annotations

import json
import pathlib
import re

HERE = pathlib.Path(__file__).resolve().parent
EXP = HERE.parent / "EXPERIMENTS.md"


def dryrun_table() -> str:
    res = json.loads((HERE / "dryrun_results.json").read_text())
    lines = ["| arch | shape | mesh | ok | peak GiB/dev | args GiB/dev | compile s |",
             "|---|---|---|---|---|---|---|"]
    for key in sorted(res):
        v = res[key]
        arch, shape, mesh = key.split("|")
        if v.get("ok"):
            m = v["memory"]
            lines.append(
                f"| {arch} | {shape} | {mesh} | ✓ "
                f"| {m['peak_estimate_per_device']/2**30:.2f} "
                f"| {m['argument_bytes_per_device']/2**30:.2f} "
                f"| {v.get('compile_seconds','')} |")
        else:
            lines.append(f"| {arch} | {shape} | {mesh} | ✗ {v.get('error','')[:40]} | | | |")
    ok = sum(1 for v in res.values() if v.get("ok"))
    lines.append(f"\n**{ok}/{len(res)} cells compile.**")
    return "\n".join(lines)


def roofline_table() -> str:
    res = json.loads((HERE / "roofline_results.json").read_text())
    lines = ["| arch | shape | compute s | memory s | collective s | dominant "
             "| MODEL_FLOPS | useful | roofline frac | one-line bottleneck note |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    notes = {
        "compute": "MXU-bound: raise via less remat recompute / int8 MXU",
        "memory": "HBM-bound: int8 weights/KV halve the dominant reads",
        "collective": "ICI-bound: AR->RS on SP boundaries + comm/compute overlap",
    }
    for key in sorted(res):
        v = res[key]
        if "error" in v:
            lines.append(f"| {key} | ERROR {v['error'][:40]} |" + " |" * 8)
            continue
        arch, shape = key.split("|")
        lines.append(
            f"| {arch} | {shape} | {v['compute_s']:.3f} | {v['memory_s']:.4f} "
            f"| {v['collective_s']:.3f} | **{v['dominant']}** "
            f"| {v['model_flops_total']:.3g} | {v['useful_ratio']:.2f} "
            f"| {v['roofline_fraction']:.3f} | {notes[v['dominant']]} |")
    return "\n".join(lines)


def inject(text: str, start: str, end: str, payload: str) -> str:
    pat = re.compile(re.escape(start) + r".*?" + re.escape(end), re.S)
    return pat.sub(start + "\n" + payload + "\n" + end, text)


def main():
    t = EXP.read_text()
    t = inject(t, "<!-- DRYRUN_TABLE_START -->", "<!-- DRYRUN_TABLE_END -->",
               dryrun_table())
    t = inject(t, "<!-- ROOFLINE_TABLE_START -->", "<!-- ROOFLINE_TABLE_END -->",
               roofline_table())
    EXP.write_text(t)
    print("EXPERIMENTS.md tables updated")


if __name__ == "__main__":
    main()
