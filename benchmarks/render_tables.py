"""Render the experiment tables from the committed JSON artifacts.

    PYTHONPATH=src python -m benchmarks.render_tables [--out PATH]

Three tables, each skipped gracefully when its source JSON is absent (a
fresh checkout carries only the BENCH_<pr>.json ledgers):

  * §Dry-run  — LLM cell compile sweep (benchmarks/dryrun_results.json)
  * §Roofline — LLM three-term rows + smallNet analytic rows
                (benchmarks/roofline_results.json)
  * §Perf trajectory — one row per (ledger, backend, route) across every
                committed BENCH_<pr>.json: FPS, device ms, bytes/frame and
                MFU, so the cross-PR perf story reads off one table.

Output goes to EXPERIMENTS.md between the *_TABLE_START/END markers when
that file exists (the original seed behavior), else to benchmarks/TABLES.md
as a standalone page — this is what the nightly CI lane uploads as an
artifact next to the raw ledgers.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import re

HERE = pathlib.Path(__file__).resolve().parent
EXP = HERE.parent / "EXPERIMENTS.md"
DEFAULT_OUT = HERE / "TABLES.md"


def dryrun_table() -> str | None:
    p = HERE / "dryrun_results.json"
    if not p.exists():
        return None
    res = json.loads(p.read_text())
    lines = ["| arch | shape | mesh | ok | peak GiB/dev | args GiB/dev | compile s |",
             "|---|---|---|---|---|---|---|"]
    for key in sorted(res):
        v = res[key]
        arch, shape, mesh = key.split("|")
        if v.get("ok"):
            m = v["memory"]
            lines.append(
                f"| {arch} | {shape} | {mesh} | ✓ "
                f"| {m['peak_estimate_per_device']/2**30:.2f} "
                f"| {m['argument_bytes_per_device']/2**30:.2f} "
                f"| {v.get('compile_seconds','')} |")
        else:
            lines.append(f"| {arch} | {shape} | {mesh} | ✗ {v.get('error','')[:40]} | | | |")
    ok = sum(1 for v in res.values() if v.get("ok"))
    lines.append(f"\n**{ok}/{len(res)} cells compile.**")
    return "\n".join(lines)


def roofline_table() -> str | None:
    p = HERE / "roofline_results.json"
    if not p.exists():
        return None
    res = json.loads(p.read_text())
    lines = ["| arch | shape | compute s | memory s | collective s | dominant "
             "| MODEL_FLOPS | useful | roofline frac | one-line bottleneck note |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    notes = {
        "compute": "MXU-bound: raise via less remat recompute / int8 MXU",
        "memory": "HBM-bound: int8 weights/KV halve the dominant reads",
        "collective": "ICI-bound: AR->RS on SP boundaries + comm/compute overlap",
    }
    for key in sorted(res):
        v = res[key]
        if "error" in v:
            lines.append(f"| {key} | ERROR {v['error'][:40]} |" + " |" * 8)
            continue
        if key.startswith("smallnet"):
            name, route = key.split("|")
            lines.append(
                f"| {name} | {route} | {v['compute_s']:.2e} "
                f"| {v['memory_s']:.2e} | — | **{v['bound']}** "
                f"| {v['flops']:.3g} | — "
                f"| {v['attainable_flops']/v['peak_flops']:.3f} "
                f"| intensity {v['intensity']:.1f} flop/B on "
                f"{v.get('device', v.get('dtype', '?'))} |")
            continue
        arch, shape = key.split("|")
        lines.append(
            f"| {arch} | {shape} | {v['compute_s']:.3f} | {v['memory_s']:.4f} "
            f"| {v['collective_s']:.3f} | **{v['dominant']}** "
            f"| {v['model_flops_total']:.3g} | {v['useful_ratio']:.2f} "
            f"| {v['roofline_fraction']:.3f} | {notes[v['dominant']]} |")
    return "\n".join(lines)


def trajectory_table() -> str | None:
    """Cross-PR perf trajectory from every committed BENCH_<pr>.json.
    Older ledgers predate the MFU schema; their rows render with em-dashes
    rather than being dropped (the FPS trajectory is still the record)."""
    from benchmarks.perf_ledger import ledger_paths

    paths = ledger_paths()
    if not paths:
        return None
    lines = ["| ledger | backend | route | fps | p50 ms | launches/frame "
             "| bytes/frame | device ms | mfu | basis |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for p in paths:
        led = json.loads(p.read_text())
        for backend in sorted(led.get("rows", {})):
            for route, row in sorted(led["rows"][backend].items()):
                mfu_v = row.get("mfu")
                lines.append(
                    f"| {p.name} | {backend} | {route} "
                    f"| {row.get('sustained_fps', '—')} "
                    f"| {row.get('latency_p50_ms', '—')} "
                    f"| {row.get('program_launches_per_frame', '—')} "
                    f"| {row.get('bytes_per_frame', '—')} "
                    f"| {row.get('device_ms_per_frame', '—')} "
                    f"| {f'{mfu_v:.3e}' if mfu_v is not None else '—'} "
                    f"| {row.get('mfu_basis', '—')} |")
    return "\n".join(lines)


def inject(text: str, start: str, end: str, payload: str) -> str:
    pat = re.compile(re.escape(start) + r".*?" + re.escape(end), re.S)
    return pat.sub(start + "\n" + payload + "\n" + end, text)


def standalone_page(tables: dict[str, str | None]) -> str:
    parts = ["# Experiment tables\n",
             "Rendered by `python -m benchmarks.render_tables` from the "
             "committed JSON artifacts.\n"]
    for title, body in tables.items():
        parts.append(f"## {title}\n")
        parts.append(body if body is not None
                     else "_source JSON not present in this checkout_\n")
    return "\n".join(parts) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=pathlib.Path, default=None,
                    help="write a standalone markdown page here instead of "
                         "injecting into EXPERIMENTS.md "
                         f"(default: EXPERIMENTS.md if present, else "
                         f"{DEFAULT_OUT.name})")
    args = ap.parse_args()

    tables = {"Dry-run": dryrun_table(),
              "Roofline": roofline_table(),
              "Perf trajectory": trajectory_table()}

    if args.out is None and EXP.exists():
        t = EXP.read_text()
        if tables["Dry-run"] is not None:
            t = inject(t, "<!-- DRYRUN_TABLE_START -->",
                       "<!-- DRYRUN_TABLE_END -->", tables["Dry-run"])
        if tables["Roofline"] is not None:
            t = inject(t, "<!-- ROOFLINE_TABLE_START -->",
                       "<!-- ROOFLINE_TABLE_END -->", tables["Roofline"])
        EXP.write_text(t)
        print("EXPERIMENTS.md tables updated")
        return
    out = args.out or DEFAULT_OUT
    out.write_text(standalone_page(tables))
    rendered = [k for k, v in tables.items() if v is not None]
    print(f"wrote {out} ({', '.join(rendered) or 'no sources present'})")


if __name__ == "__main__":
    main()
