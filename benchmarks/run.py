# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table + the scale deliverables.

    PYTHONPATH=src python -m benchmarks.run [--fast]

  accuracy_table  — paper §IV-C accuracy ladder + Qm.n degradation sweep
  latency_table   — paper §IV-B software vs deployed latency / speedup
  resource_table  — paper §IV-A resources/power analogues + per-arch HBM
  roofline_table  — three-term roofline per (arch x shape), single pod
"""
import argparse
import sys


def _emit(rows):
    for name, us, derived in rows:
        us_s = f"{us:.2f}" if us is not None else ""
        print(f"{name},{us_s},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller smallNet training run")
    args = ap.parse_args()

    from benchmarks import accuracy_table, latency_table, resource_table, roofline_table
    from repro.core import deploy

    print("name,us_per_call,derived")
    trained = deploy.train_smallnet(
        n_train=3000 if args.fast else 8000,
        n_test=800 if args.fast else 2000,
        epochs=8 if args.fast else 16)
    rows, trained = accuracy_table.run(trained=trained,
                                       n_test=800 if args.fast else 1500)
    _emit(rows)
    _emit(latency_table.run(trained))
    _emit(resource_table.run(trained))
    _emit(roofline_table.run())


if __name__ == "__main__":
    main()
