# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table + the scale deliverables.

    PYTHONPATH=src python -m benchmarks.run [--fast]
    PYTHONPATH=src python -m benchmarks.run --backends   # parity smoke, no training

  accuracy_table  — paper §IV-C accuracy ladder + Qm.n degradation sweep
  latency_table   — paper §IV-B software vs deployed latency / speedup
  resource_table  — paper §IV-A resources/power analogues + per-arch HBM
  roofline_table  — three-term roofline per (arch x shape), single pod

`--backends` runs one tiny batch through every registered inference backend
(ref / plan / pallas / pallas_plan / fixed / fixed_pallas / int8) plus a
mini vision-engine drain, checks parity against the reference substrate
(and int32 WORD EQUALITY between fixed and fixed_pallas — the fused-kernel
bit-exactness contract), and exits nonzero on failure — catches benchmark
drift without a full training run.
"""
import argparse
import sys


def _emit(rows):
    for name, us, derived in rows:
        us_s = f"{us:.2f}" if us is not None else ""
        print(f"{name},{us_s},{derived}")


def backend_smoke() -> int:
    """Tiny-batch parity sweep over every registered backend. Returns a
    process exit code (0 = all substrates agree within tolerance)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import backends, smallnet
    from repro.core import fixed_point as fxp
    from repro.data import synth_mnist
    from repro.serving.vision_engine import VisionEngine

    params = smallnet.init_params(jax.random.key(0))
    # init_params zeroes the biases, which would make bias-handling drift
    # invisible to the parity check — give every leaf a nonzero value
    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(jax.random.key(1), len(leaves))
    params = jax.tree_util.tree_unflatten(treedef, [
        l + 0.1 * jax.random.normal(k, l.shape, l.dtype)
        for l, k in zip(leaves, keys)])
    x = jnp.asarray(synth_mnist.make_dataset(8, seed=0)[0])
    ref = smallnet.apply(params, x, backend="ref")
    plan = smallnet.apply(params, x, backend="plan")
    # (comparison target, max-abs-error tolerance) per substrate
    spec = {
        "ref": (ref, 0.0),
        "plan": (plan, 0.0),
        "pallas": (ref, 1e-4),          # interpret-mode float assoc. noise
        "pallas_plan": (plan, 1e-4),
        "fixed": (plan, 5e-3),          # Q16.16 quantization steps
        "fixed_pallas": (plan, 5e-3),   # same Qm.n words as "fixed"
        "int8": (ref, 0.15),            # int8 PTQ + PLAN sigmoid
    }
    print("name,us_per_call,derived")
    failed = False
    for name in backends.list_backends():
        scores = smallnet.apply(params, x, backend=name)
        if scores.dtype == jnp.int32:
            scores = fxp.from_fixed(scores)
        want, tol = spec.get(name, (ref, 0.05))   # conservative for extras
        err = float(jnp.abs(scores - want).max())
        ok = err <= tol
        failed |= not ok
        print(f"smoke/parity_{name},,max_err={err:.2e} tol={tol:g} "
              f"{'OK' if ok else 'FAIL'}")
    # the fused fixed kernel's contract is stronger than a tolerance: its
    # int32 words must be IDENTICAL to the emulated fixed substrate
    fix = smallnet.apply(params, x, backend="fixed")
    fixp = smallnet.apply(params, x, backend="fixed_pallas")
    n_drift = int(jnp.sum(fix != fixp))
    ok = n_drift == 0
    failed |= not ok
    print(f"smoke/bitexact_fixed_pallas,,drifted_words={n_drift}/"
          f"{fix.size} {'OK' if ok else 'FAIL'}")
    # mini engine drain: the serving path must work for every backend too
    for name in backends.list_backends():
        eng = VisionEngine(params, backend=name, batch_size=4, warmup=False)
        res = eng.serve(list(np.asarray(x)))
        ok = len(res) == 8 and all(r.latency_s > 0 for r in res)
        failed |= not ok
        s = eng.stats()
        print(f"smoke/engine_{name},{s['latency_mean_ms']*1e3:.2f},"
              f"served={s['n']} {'OK' if ok else 'FAIL'}")
    print(f"smoke/result,,{'FAIL' if failed else 'OK'}")
    return 1 if failed else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller smallNet training run")
    ap.add_argument("--backends", action="store_true",
                    help="backend parity smoke (tiny batch, no training); "
                         "exits nonzero on parity failure")
    args = ap.parse_args()

    if args.backends:
        sys.exit(backend_smoke())

    from benchmarks import accuracy_table, latency_table, resource_table, roofline_table
    from repro.core import deploy

    print("name,us_per_call,derived")
    trained = deploy.train_smallnet(
        n_train=3000 if args.fast else 8000,
        n_test=800 if args.fast else 2000,
        epochs=8 if args.fast else 16)
    rows, trained = accuracy_table.run(trained=trained,
                                       n_test=800 if args.fast else 1500)
    _emit(rows)
    _emit(latency_table.run(trained))
    _emit(resource_table.run(trained))
    _emit(roofline_table.run())


if __name__ == "__main__":
    main()
