"""Streaming table: sustained FPS / frame latency / drop rate per substrate.

The paper's real deployment target is frame-rate-bound, not per-image-bound:
this table runs the SAME seeded synthetic clip through the full streaming
pipeline (paced source -> sliding-window tiler -> batched engine waves ->
detections) on every inference substrate and serving topology, and reports

    sustained FPS, p50/p99 frame latency, drop rate, batch occupancy

per row.  Always validated (nonzero exit on failure): every row accounts for
all of its frames (in == served + dropped), and the `ref` backend meets the
FPS target.  `--smoke` trims the sweep for the tier-1 CI lane and adds the
detection assertions: the clip produces a deterministic nonzero detection
count, and `fixed` vs `fixed_pallas` detections are bit-identical.

`--sweep` (implied by `--smoke`) additionally benchmarks the host tiler
against the fully-convolutional frame sweep (`streaming/fcn_sweep.py`) in
THROUGHPUT mode — unpaced, so sustained FPS is the raw pipeline rate, not
the camera clock — at the same stride-8 window lattice, and reports the
speedup per backend.  The smoke lane asserts the two paths' frozen-clip
detections are identical on ref/fixed/fixed_pallas and that the sweep is
STRICTLY faster than the host tiler on `ref` (the whole point of moving
the windowing on device).

The sweep lane also rows the `kernels/frame_trunk` MEGAKERNEL route
(FcnSweep(megakernel=True)) against the composed cascade on both fixed
substrates, with three smoke gates: the megakernel trunk must trace to
exactly ONE `pallas_call` per frame (the composed fixed_pallas cascade to
many), its frozen-clip detections must be bit-identical to the composed
sweep's, and its FPS must hold the perf_ledger band (>= 85% of the
composed sweep measured in the same run).

`--disagg` rows the disaggregated trunk/head fleet (`serving/disagg.py`)
against the monolithic sweep on a query-repetition clip (each frame
queried DISAGG_REPEATS times — the overlapping-window workload the
feature-map cache exists for), on both fixed substrates.  The smoke gates
pin the whole disagg value proposition: window scores word-exact vs the
monolithic sweep, frozen-clip detection parity, measured cache hit rate
above DISAGG_HIT_RATE, disagg FPS at least DISAGG_FPS_GAIN x the
monolithic rate on that clip, and the cached path at least as fast as the
recompute path (an all-distinct clip through a fresh fleet).

`--trace` runs the ref pipeline once more under the span tracer
(`repro/obs`): every frame becomes a `frame` root span with tile/infer/
aggregate children and engine `request`/`device_step` spans below, the
flight-recorder ring is dumped to `<trace-dir>/stream_trace.jsonl` next to
a `metrics.prom` Prometheus exposition, every span is reconciled against
the pipeline AND engine ledgers, and (with `--smoke`) traced FPS must hold
>= 95% of the untraced rate measured in the same process.

`--real-device` flips the process-wide interpret switch off
(`backends.set_interpret(False)`): every Pallas kernel compiles for the
attached accelerator instead of running the CPU interpreter.  The CPU CI
lanes keep the interpret default; the flag is for bench runs on real
hardware.

    PYTHONPATH=src python -m benchmarks.stream_table --frames 100 --sweep
    PYTHONPATH=src python -m benchmarks.stream_table --frames 30 --smoke
"""
from __future__ import annotations

import argparse
import sys

BACKENDS = ("ref", "pallas", "fixed", "fixed_pallas")
SMOKE_BACKENDS = ("ref", "fixed", "fixed_pallas")
SWEEP_STRIDE = 8               # the sweep lattice: must be a multiple of 4
PARITY_BACKENDS = SMOKE_BACKENDS   # sweep-vs-tiler detection parity set
TRACE_OVERHEAD_BAND = 0.95     # traced FPS must hold >= 95% of untraced
TRACE_CAPACITY = 1 << 16       # flight-recorder ring for the --trace lane
DISAGG_BACKENDS = ("fixed", "fixed_pallas")   # word-exactness substrates
DISAGG_REPEATS = 4             # queries per distinct frame (75% cacheable)
DISAGG_HIT_RATE = 0.5          # measured hit rate floor on the repeated clip
DISAGG_FPS_GAIN = 1.5          # disagg must beat monolithic by this factor


def _params():
    """Seeded params with every leaf nonzero — no training run needed
    (the shared `smallnet.seeded_params` recipe the golden generators and
    frozen-clip tests pin)."""
    from repro.core import smallnet
    return smallnet.seeded_params()


def _calibrated_tiler(params, source, stride: int):
    """Pin the detection threshold to the 80th percentile of the clip's
    first-frame confidences on the "fixed" substrate (the PLAN + Qm.n
    landscape every fixed-point row shares, and a close proxy for the float
    rows), so the sweep always has real detections to aggregate
    (deterministic for a frozen clip)."""
    import numpy as np

    from repro.streaming.tiler import Tiler
    t0 = Tiler(stride=stride)
    tiles, _ = t0.extract(next(iter(source)))
    conf = t0._confidences(t0.score(params, tiles, backend="fixed")).max(-1)
    return Tiler(stride=stride, threshold=float(np.quantile(conf, 0.8)))


def _run_row(params, source, tiler, engine, *, fps: float):
    from repro.streaming.pipeline import StreamConfig, StreamingPipeline
    from repro.streaming.sources import PacedPlayer
    pipe = StreamingPipeline(
        PacedPlayer(source, fps=fps), engine, tiler,
        config=StreamConfig(deadline_ms=3e3 / fps, queue_size=4))
    pipe.run()
    return pipe.stats()


def _sweep_vs_tiler(params, *, frames: int, backends, smoke: bool):
    """Throughput-mode tiler-vs-FCN-sweep pairs on the same stride-8 window
    lattice: rows + failures (smoke gates detection parity and the ref
    speedup)."""
    from repro.serving.vision_engine import VisionEngine
    from repro.streaming.fcn_sweep import FcnSweep
    from repro.streaming.pipeline import StreamingPipeline
    from repro.streaming.sources import SyntheticVideoSource

    source = SyntheticVideoSource(n_frames=frames, seed=7)
    host = _calibrated_tiler(params, source, SWEEP_STRIDE)
    tilers = {"tiler": host,
              "sweep": FcnSweep(stride=SWEEP_STRIDE,
                                threshold=host.threshold)}

    rows, failures = [], []
    for backend in backends:
        fps_by = {}
        for kind, tiler in tilers.items():
            # compile outside the serving clock (the VisionEngine warmup
            # idiom): a one-time trace must not masquerade as steady-state
            # frame cost.  The engine warms its batched step here; sweep
            # pipelines warm their whole-frame program at construction.
            eng = VisionEngine(params, backend=backend, batch_size=64,
                               warmup=(kind == "tiler"))
            # best of 2 runs: the speedup gate compares steady-state rates,
            # and a single run is one scheduler hiccup away from flaking
            best = None
            for _ in range(2):
                pipe = StreamingPipeline(source, eng, tiler)  # throughput
                pipe.run()
                s = pipe.stats()
                if best is None or s["sustained_fps"] > best["sustained_fps"]:
                    best = s
            s = best
            fps_by[kind] = s["sustained_fps"]
            rows.append((
                f"stream/{kind}_{backend}", s.get("latency_p50_ms"),
                f"fps={s['sustained_fps']:.1f} "
                f"p50={s.get('latency_p50_ms', 0):.1f}ms "
                f"p99={s.get('latency_p99_ms', 0):.1f}ms "
                f"drop_rate={s['drop_rate']:.2f} "
                f"served={s['frames_served']}/{s['frames_in']} "
                f"detections={s['detections_total']} "
                f"accounted={'OK' if s['accounted'] else 'FAIL'}"))
            if not s["accounted"]:
                failures.append(f"{kind}_{backend}: unaccounted frames")
        speedup = fps_by["sweep"] / fps_by["tiler"] if fps_by["tiler"] else 0.0
        rows.append((f"stream/sweep_speedup_{backend}", None,
                     f"speedup={speedup:.2f}x tiler={fps_by['tiler']:.1f} "
                     f"sweep={fps_by['sweep']:.1f}"))
        if smoke and backend == "ref" and not fps_by["sweep"] > fps_by["tiler"]:
            failures.append(
                f"FCN sweep is not strictly faster than the host tiler on "
                f"'ref': {fps_by['sweep']:.1f} vs {fps_by['tiler']:.1f} FPS")

    if smoke:
        clip = SyntheticVideoSource(n_frames=min(frames, 8), seed=7).frames()
        for backend in PARITY_BACKENDS:
            dt = [tilers["tiler"].detect(params, f, backend=backend)
                  for f in clip]
            ds = [tilers["sweep"].detect(params, f, backend=backend)
                  for f in clip]
            n = sum(len(d) for d in dt)
            # the fixed substrates are word-exact by construction, so their
            # Detections (float scores included) must be identical; float
            # backends carry ~1-ulp conv summation-order latitude, so the
            # gate there is labels/positions exact + scores within 1e-5
            # (a jaxlib upgrade must not redden the smoke on correct code)
            exact = backend in ("fixed", "fixed_pallas")
            ok = all(_same_detections(a, b, exact) for a, b in zip(dt, ds))
            rows.append((f"stream/sweep_parity_{backend}", None,
                         f"n={n} frames={len(clip)} "
                         f"identical={'OK' if ok else 'FAIL'}"))
            if not ok:
                diff = sum(not _same_detections(a, b, exact)
                           for a, b in zip(dt, ds))
                failures.append(f"sweep vs tiler detections differ on "
                                f"{diff}/{len(clip)} frames ({backend})")
            if backend == "fixed" and n == 0:
                failures.append("sweep parity clip produced zero detections")
    return rows, failures


def _megakernel_rows(params, *, frames: int, smoke: bool):
    """Composed-cascade vs one-launch-megakernel sweep rows on the fixed
    substrates: launch topology (static jaxpr counts), frozen-clip
    detection parity, and the in-run FPS band — the stream-side view of
    what benchmarks/perf_ledger.py persists."""
    import jax.numpy as jnp

    from benchmarks.perf_ledger import FPS_BAND, MEGA_BACKENDS
    from repro.analysis.launches import count_pallas_launches
    from repro.core import backends as B
    from repro.serving.vision_engine import VisionEngine
    from repro.streaming import fcn_sweep as fs
    from repro.streaming.fcn_sweep import FcnSweep
    from repro.streaming.pipeline import StreamingPipeline
    from repro.streaming.sources import SyntheticVideoSource

    source = SyntheticVideoSource(n_frames=frames, seed=7)
    host = _calibrated_tiler(params, source, SWEEP_STRIDE)
    H, W = source.frame_shape
    probe = jnp.zeros((1, H, W, 1), jnp.float32)

    rows, failures = [], []
    for backend in MEGA_BACKENDS:
        be = B.get_backend(backend)
        p = be.prepare_params(params)
        launches = {mega: count_pallas_launches(
            lambda f: fs._trunk_quad(be, p, f, mega), probe)
            for mega in (False, True)}
        fps_by, det_by = {}, {}
        for kind, mega in (("composed", False), ("mega", True)):
            tiler = FcnSweep(stride=SWEEP_STRIDE, threshold=host.threshold,
                             megakernel=mega)
            eng = VisionEngine(params, backend=backend, batch_size=64,
                               warmup=False)
            best = None            # best of 2, as in _sweep_vs_tiler
            for _ in range(2):
                pipe = StreamingPipeline(source, eng, tiler)
                pipe.run()
                s = pipe.stats()
                if best is None or s["sustained_fps"] > best["sustained_fps"]:
                    best = s
            fps_by[kind] = best["sustained_fps"]
            clip = SyntheticVideoSource(n_frames=min(frames, 8),
                                        seed=7).frames()
            det_by[kind] = [tiler.detect(params, f, backend=backend)
                            for f in clip]
            rows.append((
                f"stream/{kind}_trunk_{backend}",
                best.get("latency_p50_ms"),
                f"fps={best['sustained_fps']:.1f} "
                f"p50={best.get('latency_p50_ms', 0):.1f}ms "
                f"p99={best.get('latency_p99_ms', 0):.1f}ms "
                f"drop_rate={best['drop_rate']:.2f} "
                f"trunk_launches/frame={launches[mega]}"))
        ratio = fps_by["mega"] / fps_by["composed"] if fps_by["composed"] else 0
        parity = det_by["mega"] == det_by["composed"]
        rows.append((f"stream/mega_vs_composed_{backend}", None,
                     f"fps_ratio={ratio:.2f} launches "
                     f"{launches[False]}->{launches[True]} "
                     f"detections_identical={'OK' if parity else 'FAIL'}"))
        if smoke:
            if launches[True] != 1:
                failures.append(
                    f"megakernel trunk on '{backend}' traces to "
                    f"{launches[True]} pallas_calls per frame, not 1")
            if backend == "fixed_pallas" and launches[False] <= 1:
                failures.append(
                    "composed fixed_pallas cascade unexpectedly traces to "
                    f"{launches[False]} launches — the megakernel row is "
                    "no longer measuring a fusion")
            if not parity:
                diff = sum(a != b for a, b in
                           zip(det_by["mega"], det_by["composed"]))
                failures.append(
                    f"megakernel vs composed sweep detections differ on "
                    f"{diff} frames ({backend}) — word-exactness broke")
            if fps_by["mega"] < FPS_BAND * fps_by["composed"]:
                failures.append(
                    f"megakernel sweep on '{backend}' fell past the "
                    f"{FPS_BAND:.0%} FPS band: {fps_by['mega']:.1f} vs "
                    f"composed {fps_by['composed']:.1f}")
    return rows, failures


def _trace_rows(params, *, frames: int, smoke: bool, trace_dir: str):
    """Traced-vs-untraced overhead + span/ledger reconciliation rows.

    Runs the ref-backend throughput pipeline best-of-2 per side (the same
    flake armour as the sweep gates): first with the tracer disabled, then
    with a fresh flight recorder per repetition.  The best traced rep's
    spans must reconcile with BOTH ledgers of the same run — the pipeline
    (one terminal `frame` root per frame, counts equal to served/dropped)
    and the engine (`request` roots vs served + shed) — and under --smoke
    traced FPS must hold >= TRACE_OVERHEAD_BAND of the untraced rate
    measured in the same process.  Artifacts land in `trace_dir`:
    stream_trace.jsonl (flight-recorder dump, header line + one span per
    line) and metrics.prom (Prometheus exposition of the whole registry).
    """
    import gc
    import os

    from repro.obs import recorder as R
    from repro.obs import trace as T
    from repro.serving.vision_engine import VisionEngine
    from repro.streaming.pipeline import StreamingPipeline
    from repro.streaming.sources import SyntheticVideoSource

    source = SyntheticVideoSource(n_frames=frames, seed=7)
    tiler = _calibrated_tiler(params, source, SWEEP_STRIDE)

    def one_run():
        eng = VisionEngine(params, backend="ref", batch_size=64)
        pipe = StreamingPipeline(source, eng, tiler)     # throughput mode
        pipe.run()
        return pipe.stats()

    rows, failures = [], []
    # Overhead methodology: single-run FPS on a shared CI box swings far
    # more than the ~1-2% the tracer actually costs, so the comparison
    #   - POOLS wall time over N reps per side (pooled fps = frames/wall;
    #     variance shrinks with N where single-pair ratios don't),
    #   - ALTERNATES side order between pairs (off,on / on,off) so slow
    #     drift cancels instead of biasing whichever side runs second,
    #   - pins the GC during every measured rep, both sides equally (the
    #     pyperf idiom: collection pauses land on whichever run happens
    #     to cross a threshold, which reads as fake overhead),
    #   - and on a failing band DOUBLES the rep count once before calling
    #     it — a real regression stays slow on every extra rep.
    T.disable()
    one_run()                                     # warm the jitted step
    wall = {False: 0.0, True: 0.0}                # traced? -> total seconds
    frames_by = {False: 0, True: 0}
    best = None
    n_reps = 0

    def measured(traced):
        nonlocal best, n_reps
        n_reps += traced
        if traced:
            tr = T.enable(capacity=TRACE_CAPACITY, dump_dir=trace_dir)
        else:
            T.disable()
        gc.collect()
        gc.disable()
        try:
            s = one_run()
        finally:
            gc.enable()
        wall[traced] += s["frames_in"] / s["sustained_fps"]
        frames_by[traced] += s["frames_in"]
        if traced and (best is None
                       or s["sustained_fps"] > best[0]["sustained_fps"]):
            best = (s, tr.recorder.spans(), tr.recorder)

    def pooled_ratio():
        fps_off = frames_by[False] / wall[False]
        fps_on = frames_by[True] / wall[True]
        return fps_on / fps_off, fps_off, fps_on

    # Up to 3 independent 4-pair windows, best window wins: a burst that
    # pollutes one window must not be merged into the next (the estimates
    # stay independent), and a REAL regression fails every window while
    # noise has to get unlucky three times in a row.
    ratio, fps_off, fps_on = 0.0, 0.0, 0.0
    for window in range(3):
        wall.update({False: 0.0, True: 0.0})
        frames_by.update({False: 0, True: 0})
        for rep in range(4):
            first = rep % 2 == 0
            measured(first)
            measured(not first)
        r = pooled_ratio()
        if r[0] > ratio:
            ratio, fps_off, fps_on = r
        if not smoke or ratio >= TRACE_OVERHEAD_BAND:
            break
    T.disable()
    s, spans, rec = best

    rows.append(("stream/trace_overhead", None,
                 f"untraced_fps={fps_off:.1f} traced_fps={fps_on:.1f} "
                 f"ratio={ratio:.3f} reps={n_reps}x2 "
                 f"band={TRACE_OVERHEAD_BAND:.2f} "
                 f"gated={'yes' if smoke else 'no'}"))
    if smoke and ratio < TRACE_OVERHEAD_BAND:
        failures.append(
            f"tracing overhead exceeds the {1 - TRACE_OVERHEAD_BAND:.0%} "
            f"band: pooled traced/untraced FPS ratio {ratio:.3f} "
            f"({fps_on:.1f} vs {fps_off:.1f} over {n_reps} reps per side)")

    if rec.evicted:
        failures.append(
            f"flight recorder evicted {rec.evicted} spans during the traced "
            f"run — raise TRACE_CAPACITY; reconciliation needs the full run")
    fails = R.reconcile(spans, frames_served=s["frames_served"],
                        frames_dropped=s["frames_dropped"])
    es = s["engine"]
    fails += R.reconcile(spans, served=es["n"], shed=es["shed"],
                         root_name="request")
    rows.append(("stream/trace_reconcile", None,
                 f"spans={len(spans)} frames={s['frames_in']} "
                 f"requests={es['submitted']} "
                 f"reconciled={'OK' if not fails else 'FAIL'}"))
    failures += [f"trace reconcile: {f}" for f in fails]

    jsonl = rec.dump_jsonl(os.path.join(trace_dir, "stream_trace.jsonl"),
                           reason="stream_table",
                           detail=f"frames={frames} backend=ref")
    prom = R.dump_prometheus(os.path.join(trace_dir, "metrics.prom"))
    rows.append(("stream/trace_artifacts", None,
                 f"jsonl={jsonl} prom={prom} spans={len(spans)}"))
    return rows, failures


def _disagg_rows(params, *, frames: int, smoke: bool):
    """Monolithic-sweep vs disaggregated trunk/head serving on a
    query-repetition clip (every frame queried DISAGG_REPEATS times — the
    overlapping-window workload `serving/disagg.py` exists for).

    Per fixed substrate, all best-of-2: the monolithic `FcnSweep.score`
    loop (recomputes the fused trunk+head program per query), the disagg
    `score_frame` loop on the same repeated clip (fresh server per rep, so
    the hit rate is the workload's, not an artifact of a pre-warmed
    cache), and the disagg loop on the all-distinct base clip (the
    recompute path — every query a cache miss).  The serving lanes are
    driven DIRECTLY (not through `StreamingPipeline`): the speedup gate
    compares serving cost, and the pipeline's fixed ~1 ms/frame of asyncio
    scheduling would otherwise dilute both sides equally and hide the
    ratio.  A separate pipeline-driven row proves the wiring (the disagg
    server slots in where the sweep does) and gates accounting only.

    Smoke gates: window scores word-exact vs the monolithic sweep,
    frozen-clip detection parity, measured hit rate above DISAGG_HIT_RATE,
    disagg FPS >= DISAGG_FPS_GAIN x monolithic on the repeated clip,
    cached-path FPS >= recompute-path FPS, and every ledger accounted."""
    import time

    import jax
    import numpy as np

    from repro.serving.disagg import DisaggServer
    from repro.streaming.fcn_sweep import FcnSweep
    from repro.streaming.pipeline import StreamingPipeline
    from repro.streaming.sources import (RepeatedClipSource,
                                         SyntheticVideoSource)

    distinct = max(2, frames // DISAGG_REPEATS)
    base = SyntheticVideoSource(n_frames=distinct, seed=7)
    repeated = RepeatedClipSource(base, repeats=DISAGG_REPEATS)
    rep_px = [f.pixels[None] for f in repeated.frames()]
    base_px = [f.pixels[None] for f in base.frames()]
    host = _calibrated_tiler(params, base, SWEEP_STRIDE)

    rows, failures = [], []
    for backend in DISAGG_BACKENDS:
        sweep = FcnSweep(stride=SWEEP_STRIDE, threshold=host.threshold)

        def mono_run():
            t0 = time.perf_counter()
            for px in rep_px:
                jax.block_until_ready(sweep.score(params, px,
                                                  backend=backend))
            return len(rep_px) / (time.perf_counter() - t0)

        def disagg_run(clip_px):
            # fresh server per rep: the measured hit rate is what THIS
            # clip earns, and construction (compile + warmup) stays
            # outside the measured window
            srv = DisaggServer(params, backend=backend,
                               frame_shape=base.frame_shape,
                               stride=SWEEP_STRIDE,
                               cache_capacity=distinct + 2)
            t0 = time.perf_counter()
            for px in clip_px:
                srv.score_frame(px)
            return len(clip_px) / (time.perf_counter() - t0), srv.stats()

        jax.block_until_ready(sweep.score(params, rep_px[0],
                                          backend=backend))   # compile
        mono_fps = max(mono_run() for _ in range(2))
        dis_fps, dis_d = max((disagg_run(rep_px) for _ in range(2)),
                             key=lambda fd: fd[0])
        rec_fps, rec_d = max((disagg_run(base_px) for _ in range(2)),
                             key=lambda fd: fd[0])

        # pipeline wiring row: the disagg server driven exactly where the
        # monolithic sweep runs (accounting gated; FPS informational —
        # the asyncio harness cost dominates at smallNet per-frame scale)
        pipe_srv = DisaggServer(params, backend=backend,
                                frame_shape=base.frame_shape,
                                stride=SWEEP_STRIDE,
                                cache_capacity=distinct + 2)
        pipe = StreamingPipeline(repeated, pipe_srv, sweep)
        pipe.run()
        pipe_s, pipe_d = pipe.stats(), pipe_srv.stats()

        rows.append((
            f"stream/disagg_mono_{backend}", None,
            f"fps={mono_fps:.1f} queries={len(rep_px)} "
            f"repeats={DISAGG_REPEATS}"))
        cache = dis_d["cache"]
        rows.append((
            f"stream/disagg_{backend}", None,
            f"fps={dis_fps:.1f} served={dis_d['n']}/{dis_d['submitted']} "
            f"hit_rate={cache['hit_rate']:.2f} "
            f"hits={cache['hits']} misses={cache['misses']} "
            f"trunk={dis_d['topology']['trunk']} "
            f"head={dis_d['topology']['head']} "
            f"accounted={'OK' if dis_d['accounted'] else 'FAIL'}"))
        speedup = dis_fps / mono_fps if mono_fps else 0.0
        cached_vs_rec = dis_fps / rec_fps if rec_fps else 0.0
        rows.append((
            f"stream/disagg_speedup_{backend}", None,
            f"vs_mono={speedup:.2f}x mono={mono_fps:.1f} "
            f"disagg={dis_fps:.1f} recompute={rec_fps:.1f} "
            f"cached_vs_recompute={cached_vs_rec:.2f}x"))
        rows.append((
            f"stream/disagg_pipeline_{backend}",
            pipe_s.get("latency_p50_ms"),
            f"fps={pipe_s['sustained_fps']:.1f} "
            f"served={pipe_s['frames_served']}/{pipe_s['frames_in']} "
            f"hit_rate={pipe_d['cache']['hit_rate']:.2f} "
            f"accounted="
            f"{'OK' if pipe_s['accounted'] and pipe_d['accounted'] else 'FAIL'}"))

        if not (dis_d["accounted"] and rec_d["accounted"]
                and pipe_s["accounted"] and pipe_d["accounted"]):
            failures.append(f"disagg_{backend}: unaccounted frames/queries")
        if smoke and pipe_s["frames_served"] != pipe_s["frames_in"]:
            failures.append(
                f"disagg pipeline on '{backend}' dropped "
                f"{pipe_s['frames_dropped']} of {pipe_s['frames_in']} "
                f"frames in throughput mode")
        if not smoke:
            continue
        # word-exactness: the disagg chain (trunk pool -> cache -> head
        # pool) must reproduce the monolithic sweep's window-score words
        # exactly on the fixed substrates — same ints, same dtype
        clip = base.frames()[:4]
        for f in clip:
            a = np.asarray(sweep.score(params, f.pixels[None],
                                       backend=backend))
            srv = DisaggServer(params, backend=backend,
                               frame_shape=base.frame_shape,
                               stride=SWEEP_STRIDE,
                               cache_capacity=distinct + 2)
            b = np.asarray(srv.score_frame(f.pixels[None]))
            if a.dtype != b.dtype or not np.array_equal(a, b):
                failures.append(
                    f"disagg scores not word-exact vs monolithic sweep on "
                    f"'{backend}' frame {f.index} "
                    f"(dtype {a.dtype} vs {b.dtype})")
                break
            dt = sweep.aggregate(a, list(srv.positions))
            dd = srv.detect(f, tiler=sweep)
            if dt != dd:
                failures.append(
                    f"disagg vs monolithic detections differ on "
                    f"'{backend}' frame {f.index}")
                break
        if cache["hit_rate"] <= DISAGG_HIT_RATE:
            failures.append(
                f"disagg cache hit rate {cache['hit_rate']:.2f} on the "
                f"repeated clip ({backend}) is not above "
                f"{DISAGG_HIT_RATE:.0%}")
        if dis_fps < DISAGG_FPS_GAIN * mono_fps:
            failures.append(
                f"disagg on '{backend}' fell short of "
                f"{DISAGG_FPS_GAIN:g}x monolithic on the repeated clip: "
                f"{dis_fps:.1f} vs {mono_fps:.1f} FPS")
        if dis_fps < rec_fps:
            failures.append(
                f"cached path on '{backend}' is slower than the recompute "
                f"path: {dis_fps:.1f} vs {rec_fps:.1f} FPS — the cache is "
                f"costing more than the trunk it skips")
    return rows, failures


def _same_detections(a, b, exact: bool) -> bool:
    """Frame detection-list parity: strict equality for the word-exact
    fixed substrates, float-tolerant scores for the float backends."""
    if exact:
        return a == b
    return len(a) == len(b) and all(
        da.label == db.label and da.y == db.y and da.x == db.x
        and da.size == db.size and abs(da.score - db.score) <= 1e-5
        for da, db in zip(a, b))


def run(*, frames: int, fps: float, stride: int, smoke: bool,
        sweep: bool = False, trace: bool = False,
        trace_dir: str = "traces", disagg: bool = False):
    """Returns (rows, failures).  Rows follow the benchmarks CSV contract."""
    from repro.launch.mesh import make_serving_mesh
    from repro.serving.router import ReplicaRouter
    from repro.serving.vision_engine import VisionEngine
    from repro.streaming.sources import SyntheticVideoSource

    params = _params()
    source = SyntheticVideoSource(n_frames=frames, seed=7)
    tiler = _calibrated_tiler(params, source, stride)
    n_tiles = len(tiler.positions(source.frame_shape))

    rows, failures = [], []
    rows.append(("stream/clip", None,
                 f"frames={frames} shape={source.frame_shape} "
                 f"tiles/frame={n_tiles} stride={stride} "
                 f"threshold={tiler.threshold:.4f} target_fps={fps:g}"))

    def engine_for(backend):
        return VisionEngine(params, backend=backend, batch_size=64)

    names = SMOKE_BACKENDS if smoke else BACKENDS
    topologies = {} if smoke else {
        "topology_sharded": lambda: VisionEngine(
            params, backend="ref", batch_size=64, mesh=make_serving_mesh()),
        "topology_routed_x2": lambda: ReplicaRouter.from_backends(
            params, ["ref", "ref"], batch_size=64),
    }
    sweeps = {f"backend_{n}": (lambda n=n: engine_for(n)) for n in names}
    sweeps.update(topologies)

    for label, build in sweeps.items():
        s = _run_row(params, source, tiler, build(), fps=fps)
        occ = s.get("batch_occupancy")
        occ_s = f"{occ:.2f}" if occ is not None else "n/a"
        rows.append((
            f"stream/{label}", s.get("latency_p50_ms"),
            f"fps={s['sustained_fps']:.1f} p50={s.get('latency_p50_ms', 0):.1f}ms "
            f"p99={s.get('latency_p99_ms', 0):.1f}ms "
            f"drop_rate={s['drop_rate']:.2f} occupancy={occ_s} "
            f"served={s['frames_served']}/{s['frames_in']} "
            f"detections={s['detections_total']} "
            f"accounted={'OK' if s['accounted'] else 'FAIL'}"))
        if not s["accounted"]:
            failures.append(f"{label}: {s['frames_in']} frames in != "
                            f"{s['frames_served']} served + "
                            f"{s['frames_dropped']} dropped")
        if label == "backend_ref":
            # the frame-rate target every future perf PR measures against
            if s["sustained_fps"] < 0.8 * fps:
                failures.append(f"ref backend misses the {fps:g} FPS target: "
                                f"sustained {s['sustained_fps']:.1f}")
            if s["drop_rate"] >= 1.0:
                failures.append("ref backend dropped every frame")

    if smoke:
        failures += _detection_smoke(params, tiler, frames=min(frames, 10))
    if sweep or smoke:
        srows, sfail = _sweep_vs_tiler(
            params, frames=min(frames, 20),
            backends=("ref",) if smoke else names, smoke=smoke)
        rows += srows
        failures += sfail
        mrows, mfail = _megakernel_rows(
            params, frames=min(frames, 20), smoke=smoke)
        rows += mrows
        failures += mfail
    if disagg:
        drows, dfail = _disagg_rows(
            params, frames=min(frames, 24), smoke=smoke)
        rows += drows
        failures += dfail
    if trace:
        trows, tfail = _trace_rows(
            params, frames=min(frames, 30), smoke=smoke,
            trace_dir=trace_dir)
        rows += trows
        failures += tfail
    return rows, failures


def _detection_smoke(params, tiler, *, frames: int) -> list[str]:
    """Frozen-clip detection assertions for the CI lane: nonzero count, and
    bit-identical output between the two fixed-point substrates."""
    from repro.streaming.sources import SyntheticVideoSource
    clip = SyntheticVideoSource(n_frames=frames, seed=7).frames()
    det_f = [tiler.detect(params, f, backend="fixed") for f in clip]
    det_fp = [tiler.detect(params, f, backend="fixed_pallas") for f in clip]
    failures = []
    n = sum(len(d) for d in det_f)
    if n == 0:
        failures.append("frozen clip produced zero detections on 'fixed'")
    if det_f != det_fp:
        diff = sum(a != b for a, b in zip(det_f, det_fp))
        failures.append(f"fixed vs fixed_pallas detections differ on "
                        f"{diff}/{frames} frames")
    print(f"stream/detection_smoke,,n={n} frames={frames} "
          f"bitexact={'OK' if det_f == det_fp else 'FAIL'}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=100)
    ap.add_argument("--fps", type=float, default=10.0,
                    help="paced source frame rate (the real-time target)")
    ap.add_argument("--stride", type=int, default=14,
                    help="sliding-window stride over the frame")
    ap.add_argument("--smoke", action="store_true",
                    help="trimmed sweep + detection assertions (CI tier-1); "
                         "implies --sweep for the ref backend")
    ap.add_argument("--sweep", action="store_true",
                    help="add throughput-mode tiler-vs-FCN-sweep comparison "
                         "rows (speedup per backend)")
    ap.add_argument("--trace", action="store_true",
                    help="run the ref pipeline under the span tracer: emits "
                         "stream_trace.jsonl + metrics.prom under "
                         "--trace-dir, reconciles every frame against the "
                         "pipeline/engine ledgers, and (with --smoke) gates "
                         "traced FPS >= 95%% of untraced")
    ap.add_argument("--trace-dir", default="traces",
                    help="directory for --trace artifacts")
    ap.add_argument("--disagg", action="store_true",
                    help="add disaggregated trunk/head serving rows on a "
                         "query-repetition clip: monolithic vs disagg FPS, "
                         "cache hit rate, and (with --smoke) the "
                         "word-exactness / parity / hit-rate / speedup "
                         "gates")
    ap.add_argument("--real-device", action="store_true",
                    help="compile Pallas kernels for the attached "
                         "accelerator instead of the CPU interpreter "
                         "(backends.set_interpret(False), process-wide)")
    args = ap.parse_args()
    if args.real_device:
        from repro.core import backends as B
        B.set_interpret(False)

    print("name,us_per_call,derived")
    rows, failures = run(frames=args.frames, fps=args.fps,
                         stride=args.stride, smoke=args.smoke,
                         sweep=args.sweep, trace=args.trace,
                         trace_dir=args.trace_dir, disagg=args.disagg)
    for name, val, derived in rows:
        val_s = f"{val:.2f}" if val is not None else ""
        print(f"{name},{val_s},{derived}")
    for f in failures:
        print(f"stream/FAIL,,{f}")
    print(f"stream/result,,{'FAIL' if failures else 'OK'}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
