"""Paper Table (§IV-A): resource utilization & power.

FPGA metrics (2052 LUTs / 1587 FFs / 25 KB BRAM / 48 DSPs / 1.505 W) map to
the TPU-deployment analogues: weight bytes, per-device HBM from the compiled
dry-run, and an energy-per-inference estimate (roofline time x chip TDP,
clearly labeled an estimate).  v5e TDP ~ 170-220 W; we use 200 W.
"""
from __future__ import annotations

import json
import pathlib

from repro.core import smallnet

_TDP_W = 200.0
_HERE = pathlib.Path(__file__).resolve().parent


def run(trained):
    rows = []
    n = smallnet.param_count(trained.params)
    rows.append(("resource/smallnet_params", None, f"{n} (paper: 510)"))
    rows.append(("resource/smallnet_weight_bytes_f32", None,
                 f"{n * 4} B (paper: ~1.99 KB fixed)"))
    rows.append(("resource/smallnet_weight_bytes_int8", None, f"{n} B"))
    # paper's BRAM analogue: VMEM working set of the conv kernel
    vmem = (29 * 29 * 1 + 28 * 28 * 1) * 4
    rows.append(("resource/conv_kernel_vmem_bytes", None,
                 f"{vmem} B of 16 MiB VMEM (paper: 25 KB BRAM)"))
    # energy per inference estimate from the latency-table roofline time
    t = max((28*28*4*2 + 14*14*4*2 + 490*2) / 197e12, (28*28*4 + 510*4) / 819e9)
    rows.append(("resource/energy_per_inference_estimate", None,
                 f"{t * _TDP_W * 1e6:.3f} uJ @ {_TDP_W:.0f} W TDP "
                 f"(paper: 1.505 W x 109 ms = 164 mJ)"))

    # per-arch deployed HBM from the dry-run (the 'fits the device' table)
    p = _HERE / "dryrun_results.json"
    if p.exists():
        res = json.loads(p.read_text())
        for key, v in sorted(res.items()):
            if v.get("ok") and v.get("memory"):
                rows.append((f"resource/hbm_peak/{key}", None,
                             f"{v['memory']['peak_estimate_per_device']/2**30:.2f} GiB/device"))
    return rows
