"""Goodput under a latency SLO, per serving topology, per arrival process.

FPS on a paced clip says nothing about overload: the north-star question is
how much *useful* work the stack completes when requests arrive on the
users' clock — goodput = fraction of SUBMITTED requests answered within
their deadline (sheds and late answers both count against it).

For each serving topology this table:

  1. calibrates the topology's service capacity (drain a full backlog,
     read the busy-time service rate — idle never deflates it),
  2. replays seeded open-loop arrival schedules (`streaming/loadgen.py`:
     Poisson / bursty / diurnal) at offered loads of 0.5x and 2.0x that
     capacity — same request count per row, so wall time is load-invariant,
  3. reports goodput, shed counts per reason, latency percentiles, and the
     accounting invariant `submitted == served + shed` per row.

Topologies: a single continuous-batching `VisionEngine` on the float ref
and fused fixed-point Pallas substrates (admission bound `max_queue`,
per-request deadlines), a 2-replica `ReplicaRouter` under the SLO-aware
policy (projected-wait dispatch, door shedding), and the disaggregated
trunk/head fleet (`serving/disagg.DisaggServer`, `disagg_fixed`): trunk
and head pools with independent replica counts and service floors joined
by the feature-map cache, replaying 112x112 frame queries over a
cache-hot pool — its rows land next to the batched engines' so trunk vs
head scaling shows up in the same goodput columns.

Each row also reports `mfu_load` — MFU under load: the busy-time served
rate times the deployed per-image model FLOPs (analysis/mfu.py), over the
resolved device's peak at the backend's dtype class.  It answers "how much
of the machine does the serving discipline actually keep busy" and is the
load-side twin of the perf ledger's per-route `mfu` column.

`--smoke` is the CI gate (Poisson + bursty):
  - every row's ledger reconciles (engine AND fleet level),
  - the 2.0x rows shed (overload must engage admission control — a queue
    that never sheds is an unbounded queue),
  - queue high-water stays within the admission bound,
  - goodput is monotone in offered-load headroom (0.5x >= 2.0x per
    topology/process).

    PYTHONPATH=src python -m benchmarks.goodput_table --smoke
    PYTHONPATH=src python -m benchmarks.goodput_table --full   # + diurnal
    PYTHONPATH=src python -m benchmarks.goodput_table --smoke --trace

`--trace` records request/batch spans for every row (`repro/obs`) and
dumps `goodput_trace.jsonl` + `goodput_metrics.prom` under `--trace-dir`
— the serving-side observability artifacts next to the stream table's.
"""
from __future__ import annotations

import argparse
import sys


def _params():
    from benchmarks.stream_table import _params as p
    return p()


SEED = 7
BATCH = 32
QUEUE_BOUND = 4          # max_queue = QUEUE_BOUND * batch_size
FLOOR_MS = 10.0          # per-step service-time floor: a deterministic rate
                         # limiter (capacity ~= batch/floor) so the open-loop
                         # rows measure serving DISCIPLINE, not host speed —
                         # on real hardware run with --floor-ms 0
LOADS = {"0.5x": 0.5, "2.0x": 2.0}
SMOKE_PROCESSES = ("poisson", "bursty")
# dtype class whose device peak the MFU-under-load column divides by.
# The disagg topology is deliberately absent: its feature-map cache skips
# trunk FLOPs on hits, so served-qps x full-model-FLOPs is not the work
# the device actually did and the MFU identity would overstate it.
TOPO_BACKEND = {"engine_ref": "ref", "engine_fixed_pallas": "fixed_pallas",
                "router_slo_x2": "ref"}
# disagg_fixed fleet shape: trunk/head replica counts scale independently
DISAGG_TRUNKS = 2
DISAGG_HEADS = 2
DISAGG_FRAMES = 32       # distinct 112x112 frames in the query pool; uids
                         # cycle over them, so steady state is cache-hot


def _mfu_under_load(topo: str, stats: dict) -> float | None:
    """Busy-time served qps x deployed per-image model FLOPs / device peak.
    None when the row carries no throughput (nothing served) or the
    topology has no single FLOPs-per-query identity (disagg + cache)."""
    from repro.analysis import mfu

    qps = stats.get("throughput_qps")
    backend = TOPO_BACKEND.get(topo)
    if not qps or backend is None:
        return None
    device, _ = mfu.resolve()
    dtype, word_bytes = mfu.backend_numerics(backend)
    flops = mfu.deployed_workload(word_bytes).flops
    return qps * flops / device.peak(dtype)


def _deadline_ms(capacity_qps: float, batch: int) -> float:
    """SLO for a topology: ~6 batch-service-times (comfortable at half
    load, hopeless for a 2x backlog), floored so scheduler jitter on a
    fast machine can't dominate."""
    return max(25.0, 6.0 * batch / capacity_qps * 1e3)


def _calibrate_engine(params, backend: str, batch: int,
                      floor_s: float) -> float:
    """Busy-time service rate (qps) of one engine draining a full backlog
    of 8 batches — the capacity the offered loads are scaled against.
    With a service floor this converges to batch/floor_s by construction."""
    import numpy as np

    from repro.serving.vision_engine import VisionEngine

    eng = VisionEngine(params, backend=backend, batch_size=batch,
                      min_step_s=floor_s)
    imgs = np.zeros((8 * batch, 28, 28, 1), np.float32)
    eng.submit_many(imgs)
    eng.run()
    rate = eng.service_rate_qps()
    assert rate is not None and rate > 0
    return rate


def _run_engine_row(params, backend: str, gen, images, slo_ms: float,
                    floor_s: float) -> dict:
    from repro.serving.vision_engine import VisionEngine

    eng = VisionEngine(params, backend=backend, batch_size=BATCH,
                       max_queue=QUEUE_BOUND * BATCH, min_step_s=floor_s)
    eng.start()
    try:
        gen.replay(lambda a, t: eng.submit(images[a.uid], deadline_ms=slo_ms,
                                           t_submit=t))
    finally:
        eng.stop(drain=True)
    s = eng.stats()
    s["queue_bound"] = QUEUE_BOUND * BATCH
    return s


def _mk_disagg(params, floor_s: float, max_queue: int | None):
    """The disagg_fixed fleet: trunk replicas carry the heavy-stage floor,
    head replicas a quarter of it (the paper's stage asymmetry), so with a
    cache-hot pool the heads are the serialization point and capacity is
    ~DISAGG_HEADS / (floor_s / 4) by construction."""
    from repro.serving.disagg import DisaggServer

    return DisaggServer(params, backend="fixed",
                        n_trunk=DISAGG_TRUNKS, n_head=DISAGG_HEADS,
                        trunk_floor_s=floor_s, head_floor_s=floor_s / 4,
                        cache_capacity=DISAGG_FRAMES + 4,
                        max_queue=max_queue,
                        n_workers=DISAGG_TRUNKS + DISAGG_HEADS)


def _disagg_frames(params):
    """The disagg query pool: DISAGG_FRAMES distinct seeded 112x112 frames
    (the server's native geometry — LoadGen's 28x28 images are the batched
    engines' shape, not a frame)."""
    from repro.streaming.sources import SyntheticVideoSource

    src = SyntheticVideoSource(n_frames=DISAGG_FRAMES, seed=SEED)
    return [f.pixels for f in src.frames()]


def _calibrate_disagg(params, frame_px, floor_s: float) -> float:
    """Drain 8 passes over the query pool through a fresh fleet and read
    the served rate — the engine-calibration idiom for the disagg server
    (the first pass pays the trunk misses; the other seven amortize them
    into the cache-hot steady state the replay rows actually run in)."""
    srv = _mk_disagg(params, floor_s, max_queue=None)
    srv.start()
    try:
        uids = [srv.submit(px) for px in frame_px * 8]
        srv.wait(uids)
    finally:
        srv.stop(drain=True)
    s = srv.stats()
    assert s["accounted"] and s["n"] == len(frame_px) * 8
    return s["n"] / s["wall_s"]


def _run_disagg_row(params, gen, frame_px, slo_ms: float,
                    floor_s: float) -> dict:
    srv = _mk_disagg(params, floor_s, max_queue=QUEUE_BOUND * BATCH)
    srv.start()
    try:
        gen.replay(lambda a, t: srv.submit(
            frame_px[a.uid % len(frame_px)], deadline_ms=slo_ms,
            t_submit=t))
    finally:
        srv.stop(drain=True)
    s = srv.stats()
    s["queue_bound"] = QUEUE_BOUND * BATCH
    return s


def _run_router_row(params, gen, images, slo_ms: float,
                    floor_s: float) -> dict:
    from repro.serving.router import ReplicaRouter

    router = ReplicaRouter.from_backends(
        params, ["ref", "ref"], batch_size=BATCH // 2, policy="slo",
        slo_ms=slo_ms, engine_kw={"max_queue": QUEUE_BOUND * BATCH,
                                  "min_step_s": floor_s})
    router.start()
    try:
        gen.replay(lambda a, t: router.submit(images[a.uid], t_submit=t))
    finally:
        router.stop(drain=True)
    s = router.stats()
    s["queue_bound"] = QUEUE_BOUND * BATCH
    return s


def measure(*, processes, n_requests: int, topologies=None,
            floor_s: float = FLOOR_MS / 1e3) -> list[dict]:
    """All (topology, process, load) rows.  Per row: a fresh engine/fleet,
    a seeded open-loop replay, and the stats ledger."""
    from repro.streaming.loadgen import LoadGen

    params = _params()
    topo_caps = {}
    topo_caps["engine_ref"] = _calibrate_engine(params, "ref", BATCH,
                                                floor_s)
    topo_caps["engine_fixed_pallas"] = _calibrate_engine(
        params, "fixed_pallas", BATCH, floor_s)
    # 2 replicas at half batch each: fleet capacity ~= one full-batch engine
    topo_caps["router_slo_x2"] = 2 * _calibrate_engine(params, "ref",
                                                       BATCH // 2, floor_s)
    frame_px = _disagg_frames(params)
    topo_caps["disagg_fixed"] = _calibrate_disagg(params, frame_px, floor_s)
    if topologies is not None:
        topo_caps = {k: v for k, v in topo_caps.items() if k in topologies}

    rows = []
    for topo, cap in topo_caps.items():
        slo_ms = _deadline_ms(cap, BATCH)
        for process in processes:
            for load_name, factor in LOADS.items():
                rate = factor * cap
                gen = LoadGen(process=process, rate_qps=rate,
                              n_requests=n_requests, n_streams=4, seed=SEED)
                if topo == "disagg_fixed":
                    s = _run_disagg_row(params, gen, frame_px, slo_ms,
                                        floor_s)
                elif topo == "router_slo_x2":
                    images = gen.images()  # render off the serving clock
                    s = _run_router_row(params, gen, images, slo_ms, floor_s)
                elif topo.startswith("engine_"):
                    images = gen.images()
                    s = _run_engine_row(params, topo[len("engine_"):],
                                        gen, images, slo_ms, floor_s)
                else:
                    raise ValueError(topo)
                rows.append({
                    "topology": topo, "process": process, "load": load_name,
                    "capacity_qps": cap, "offered_qps": gen.offered_qps,
                    "slo_ms": slo_ms, "stats": s,
                    "mfu_under_load": _mfu_under_load(topo, s),
                })
    return rows


def gate(rows: list[dict]) -> list[str]:
    """The --smoke CI conditions over a measured row set."""
    failures = []
    goodput = {}
    for r in rows:
        s = r["stats"]
        tag = f"{r['topology']}/{r['process']}/{r['load']}"
        if not s["accounted"]:
            failures.append(
                f"{tag}: ledger does not reconcile: submitted="
                f"{s['submitted']} served={s['n']} shed={s['shed']} "
                f"pending={s['pending']}")
        for rep in s.get("per_replica", []):
            if not rep["accounted"]:
                failures.append(f"{tag}: replica-level ledger does not "
                                f"reconcile: {rep['shed_by_reason']}")
        for name, st in s.get("per_stage", {}).items():
            if not st["accounted"]:
                failures.append(f"{tag}: stage '{name}' ledger does not "
                                f"reconcile: {st['shed_by_reason']}")
        if "goodput" not in s:
            failures.append(f"{tag}: no goodput reported")
            continue
        goodput[(r["topology"], r["process"], r["load"])] = s["goodput"]
        hwm = s.get("queue_hwm", 0)
        if isinstance(hwm, (int, float)) and hwm > s["queue_bound"]:
            failures.append(f"{tag}: queue high-water {hwm} exceeded the "
                            f"admission bound {s['queue_bound']}")
        if r["load"] == "2.0x" and s["shed"] == 0:
            failures.append(
                f"{tag}: no shedding under 2x-capacity offered load — "
                f"admission control never engaged (unbounded queue?)")
        mfu_load = r.get("mfu_under_load")
        if mfu_load is not None and not 0.0 < mfu_load <= 1.0:
            failures.append(
                f"{tag}: mfu_under_load={mfu_load:.3e} outside (0, 1] — "
                f"served-rate or device-peak accounting broke")
    for (topo, proc, load), g_hi in goodput.items():
        if load != "2.0x":
            continue
        g_lo = goodput.get((topo, proc, "0.5x"))
        if g_lo is not None and g_lo < g_hi:
            failures.append(
                f"{topo}/{proc}: goodput not monotone in headroom: "
                f"0.5x={g_lo:.3f} < 2.0x={g_hi:.3f}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small row set + CI gates (nonzero exit on fail)")
    ap.add_argument("--full", action="store_true",
                    help="all three arrival processes, bigger schedules")
    ap.add_argument("--requests", type=int, default=None,
                    help="arrivals per row (default: 1500 smoke / 4000 full)")
    ap.add_argument("--floor-ms", type=float, default=FLOOR_MS,
                    help="per-step service floor; 0 = raw hardware capacity")
    ap.add_argument("--trace", action="store_true",
                    help="record request/batch spans for every row and dump "
                         "goodput_trace.jsonl + goodput_metrics.prom under "
                         "--trace-dir")
    ap.add_argument("--trace-dir", default="traces",
                    help="directory for --trace artifacts")
    args = ap.parse_args()

    tracer = None
    if args.trace:
        from repro.obs import trace as T
        # every row's requests land in one ring (capacity sized for the
        # full smoke row set: ~18k requests x 2 spans + batch spans)
        tracer = T.enable(capacity=1 << 18, dump_dir=args.trace_dir)

    from repro.streaming.loadgen import PROCESSES
    processes = PROCESSES if args.full else SMOKE_PROCESSES
    n = args.requests or (4000 if args.full else 1500)
    rows = measure(processes=processes, n_requests=n,
                   floor_s=args.floor_ms / 1e3)

    print("name,us_per_call,derived")
    for r in rows:
        s = r["stats"]
        mfu_load = r.get("mfu_under_load")
        mfu_s = f"{mfu_load:.3e}" if mfu_load is not None else "n/a"
        print(f"goodput/{r['topology']}_{r['process']}_{r['load']},,"
              f"goodput={s.get('goodput', 0.0):.3f} "
              f"submitted={s['submitted']} served={s['n']} shed={s['shed']} "
              f"offered_qps={r['offered_qps']:.0f} "
              f"capacity_qps={r['capacity_qps']:.0f} "
              f"slo_ms={r['slo_ms']:.1f} "
              f"p99_ms={s.get('latency_p99_ms', 0.0):.2f} "
              f"mfu_load={mfu_s} "
              f"shed_by={s['shed_by_reason']}")

    if tracer is not None:
        import os

        from repro.obs import recorder as R
        from repro.obs import trace as T
        jsonl = tracer.recorder.dump_jsonl(
            os.path.join(args.trace_dir, "goodput_trace.jsonl"),
            reason="goodput_table",
            detail=f"requests={n} processes={','.join(processes)}")
        prom = R.dump_prometheus(
            os.path.join(args.trace_dir, "goodput_metrics.prom"))
        print(f"goodput/trace_artifacts,,jsonl={jsonl} prom={prom} "
              f"spans={len(tracer.recorder)} "
              f"evicted={tracer.recorder.evicted}")
        T.disable()

    failures = gate(rows) if args.smoke else []
    for f in failures:
        print(f"goodput/FAIL,,{f}")
    print(f"goodput/result,,{'FAIL' if failures else 'OK'}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
