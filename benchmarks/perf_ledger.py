"""Persisted per-PR perf ledger: BENCH_<pr>.json, with MFU + bytes-moved.

Each ledger row pins one (backend, route) of the streaming stack — host
tiler, composed FCN sweep, `kernels/frame_trunk` megakernel sweep — over
the deterministic smoke clip (SyntheticVideoSource seed 7, the same frozen
frames the golden vectors use).  Alongside the PR-6 columns (sustained
FPS, p50/p99 frame latency, drop rate, static launch topology), every row
now carries the roofline account from `analysis/mfu.py`:

    model_flops_per_frame   analytic model FLOPs of the route's algorithm
                            (2/MAC, conv + dense only — NOT HLO counts)
    bytes_per_frame         off-chip bytes the route moves per frame (the
                            megakernel rows count the real halo'd
                            HBM->VMEM tile DMA via `choose_tile`)
    device_ms_per_frame     median direct timing of the route's jitted
                            per-frame device program (pipeline FPS keeps
                            measuring the whole stack; this isolates the
                            per-frame program itself)
    achieved_flops / achieved_bw / mfu / mfu_basis
                            model FLOPs/s, bytes/s, and the fraction of
                            the device-database peak at the backend's
                            dtype class (`DEVICE_DB` lookup is total;
                            unknown devices fail loudly).  The clock these
                            divide by is `mfu_basis`: "measured" wall time
                            on real accelerators, the "roofline_model"
                            floor under interpret-mode emulation — the
                            interpreter's wall clock times the emulator,
                            not the device program, and the modeled floor
                            keeps committed MFU machine-independent (see
                            `analysis/mfu.py::mfu_clock`)

Ledger discovery is per-PR: `--check` gates the NEWEST committed
BENCH_<pr>.json (schema + launch topology + every committed mfu in (0,1]
+ megakernel-vs-composed MFU ordering) against a fresh measurement, and
reports MFU deltas against the PREVIOUS ledger so the perf trajectory is
diffable across PRs.  Launch counts are STATIC (jaxpr traversal) and
machine-independent, so they are pinned exactly; FPS and MFU absolutes are
machine-dependent records — the in-run regression gate remains the
megakernel >= `fps_band` (0.85) of the composed sweep measured in the same
process, plus the structural claim that the megakernel's committed MFU is
strictly higher than the composed cascade's (one launch moving ~20x fewer
bytes must never be the worse-utilized program).

    PYTHONPATH=src python -m benchmarks.perf_ledger --out BENCH_8.json
    PYTHONPATH=src python -m benchmarks.perf_ledger --check   # CI tier-1
"""
from __future__ import annotations

import argparse
import json
import pathlib
import re
import statistics
import sys
import time

FRAMES = 16
SEED = 7
STRIDE = 8
FPS_BAND = 0.85          # megakernel FPS >= band * composed-sweep FPS
SCHEMA_VERSION = 2
TIMING_REPS = 7          # direct device-program timings per row (median)
BACKENDS = ("ref", "fixed", "fixed_pallas")
MEGA_BACKENDS = ("fixed", "fixed_pallas")
ROOT = pathlib.Path(__file__).resolve().parent.parent
_LEDGER_RE = re.compile(r"BENCH_(\d+)\.json$")

ROW_KEYS = ("sustained_fps", "latency_p50_ms", "latency_p99_ms",
            "drop_rate", "trunk_launches_per_frame",
            "program_launches_per_frame")
MFU_KEYS = ("model_flops_per_frame", "bytes_per_frame",
            "device_ms_per_frame", "achieved_flops", "achieved_bw",
            "mfu", "mfu_basis")


def ledger_paths() -> list[pathlib.Path]:
    """All committed BENCH_<pr>.json, oldest PR first."""
    found = []
    for p in ROOT.glob("BENCH_*.json"):
        m = _LEDGER_RE.match(p.name)
        if m:
            found.append((int(m.group(1)), p))
    return [p for _, p in sorted(found)]


def newest_ledger() -> pathlib.Path | None:
    paths = ledger_paths()
    return paths[-1] if paths else None


def previous_ledger() -> pathlib.Path | None:
    paths = ledger_paths()
    return paths[-2] if len(paths) > 1 else None


def _launch_counts(be, params, frame_shape, positions, megakernel):
    """(trunk launches, whole-program launches) for one sweep route —
    static jaxpr counts, identical on every host."""
    import jax.numpy as jnp

    from repro.analysis.launches import count_pallas_launches
    from repro.streaming import fcn_sweep as fs

    H, W = frame_shape
    frame = jnp.zeros((1, H, W, 1), jnp.float32)
    p = be.prepare_params(params)
    trunk = count_pallas_launches(
        lambda f: fs._trunk_quad(be, p, f, megakernel), frame)
    fn = fs._sweep_fn(be, (H, W), 28, tuple(positions), megakernel)
    program = count_pallas_launches(fn, params, frame)
    return trunk, program


def _tiler_launches(be, params, n_windows):
    """Whole-program launches for one host-tiler engine wave (all windows
    of one frame in a single batched `apply`)."""
    import jax.numpy as jnp

    from repro.analysis.launches import count_pallas_launches
    from repro.core import smallnet

    tiles = jnp.zeros((n_windows, 28, 28, 1), jnp.float32)
    return count_pallas_launches(
        lambda t: smallnet.apply(params, t, backend=be), tiles)


def _time_device_program(fn, *args) -> float:
    """Median wall seconds of one call of an already-jitted per-frame
    program: one warmup call (compile), then TIMING_REPS timed calls.
    This is the MFU denominator's clock — the device program alone, no
    pipeline stages, no host tiling."""
    import jax
    jax.block_until_ready(fn(*args))
    times = []
    for _ in range(TIMING_REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return statistics.median(times)


def _route_device_seconds(be, params, frame_shape, positions, route):
    """Direct per-frame device timing for one (backend, route)."""
    import jax
    import jax.numpy as jnp

    from repro.core import smallnet
    from repro.streaming import fcn_sweep as fs

    H, W = frame_shape
    if route == "tiler":
        tiles = jnp.zeros((len(positions), 28, 28, 1), jnp.float32)
        fn = jax.jit(lambda t: smallnet.apply(params, t, backend=be))
        return _time_device_program(fn, tiles)
    frame = jnp.zeros((1, H, W, 1), jnp.float32)
    fn = fs._sweep_fn(be, (H, W), 28, tuple(positions),
                      route == "sweep_megakernel")
    return _time_device_program(fn, params, frame)


def _throughput(params, source, engine, tiler):
    """Best-of-3 unpaced pipeline run (the stream_table throughput idiom,
    one run deeper: the ledger's FPS band is a gate, so one scheduler
    hiccup must not decide it)."""
    from repro.streaming.pipeline import StreamingPipeline
    best = None
    for _ in range(3):
        pipe = StreamingPipeline(source, engine, tiler)
        pipe.run()
        s = pipe.stats()
        if best is None or s["sustained_fps"] > best["sustained_fps"]:
            best = s
    return best


def measure() -> dict:
    """One full ledger measurement: the deterministic smoke config."""
    from repro.analysis import mfu
    from repro.core import backends as B
    from repro.serving.vision_engine import VisionEngine
    from repro.streaming.fcn_sweep import FcnSweep
    from repro.streaming.sources import SyntheticVideoSource

    from benchmarks import latency_table
    from benchmarks.stream_table import _calibrated_tiler, _params

    params = _params()
    source = SyntheticVideoSource(n_frames=FRAMES, seed=SEED)
    H, W = source.frame_shape
    host = _calibrated_tiler(params, source, STRIDE)
    positions = host.positions((H, W))
    device, interpret = mfu.resolve()
    routes = {
        "tiler": host,
        "sweep_composed": FcnSweep(stride=STRIDE, threshold=host.threshold,
                                   megakernel=False),
        "sweep_megakernel": FcnSweep(stride=STRIDE, threshold=host.threshold,
                                     megakernel=True),
    }

    ledger = {
        "config": {"schema_version": SCHEMA_VERSION,
                   "frames": FRAMES, "seed": SEED, "stride": STRIDE,
                   "frame_shape": [H, W], "windows_per_frame": len(positions),
                   "fps_band": FPS_BAND},
        "context": {"deployed_us_per_image":
                    round(latency_table.smoke(params), 1),
                    # machine-dependent provenance for the MFU columns —
                    # recorded, never gated (config above IS gated)
                    "device": device.name,
                    "interpret": interpret,
                    "mem_bw": device.mem_bw},
        "rows": {},
    }
    for name in BACKENDS:
        be = B.get_backend(name)
        dtype, word_bytes = mfu.backend_numerics(name)
        ledger["rows"][name] = {}
        for route, tiler in routes.items():
            if route == "sweep_megakernel" and name not in MEGA_BACKENDS:
                continue   # no megakernel off the fixed substrates
            if route == "tiler":
                trunk, program = None, _tiler_launches(be, params,
                                                       len(positions))
            else:
                trunk, program = _launch_counts(
                    be, params, (H, W), positions,
                    route == "sweep_megakernel")
            eng = VisionEngine(params, backend=name, batch_size=64,
                               warmup=(route == "tiler"))
            s = _throughput(params, source, eng, tiler)
            wl = mfu.route_workload(route, H, W, len(positions), word_bytes)
            dev_s = _route_device_seconds(be, params, (H, W), positions,
                                          route)
            mfu_s, basis = mfu.mfu_clock(wl, dev_s, device=device,
                                         dtype=dtype, interpret=interpret)
            rates = mfu.achieved(wl, mfu_s)
            ledger["rows"][name][route] = {
                "sustained_fps": round(s["sustained_fps"], 1),
                "latency_p50_ms": round(s.get("latency_p50_ms", 0.0), 2),
                "latency_p99_ms": round(s.get("latency_p99_ms", 0.0), 2),
                "drop_rate": round(s["drop_rate"], 3),
                "trunk_launches_per_frame": trunk,
                "program_launches_per_frame": program,
                "model_flops_per_frame": wl.flops,
                "bytes_per_frame": wl.bytes_total,
                "device_ms_per_frame": round(dev_s * 1e3, 3),
                "achieved_flops": round(rates["achieved_flops"], 1),
                "achieved_bw": round(rates["achieved_bw"], 1),
                "mfu": round(mfu.mfu(wl, mfu_s, device=device, dtype=dtype),
                             9),
                "mfu_basis": basis,
            }
    return ledger


def validate(ledger: dict) -> list[str]:
    """Schema gate for a committed ledger: every row carries the full
    column set, every mfu lies in (0, 1], flops/bytes are positive, and
    wherever both sweep routes exist the megakernel's committed MFU is
    strictly higher than the composed cascade's."""
    failures = []
    cfg = ledger.get("config", {})
    if cfg.get("schema_version") != SCHEMA_VERSION:
        failures.append(
            f"ledger schema_version {cfg.get('schema_version')!r} != "
            f"{SCHEMA_VERSION} (regenerate with --out BENCH_<pr>.json)")
    rows = ledger.get("rows", {})
    if not rows:
        failures.append("ledger has no rows")
    for name, routes in rows.items():
        for route, row in routes.items():
            tag = f"{name}/{route}"
            missing = [k for k in ROW_KEYS + MFU_KEYS if k not in row]
            if missing:
                failures.append(f"{tag}: missing columns {missing}")
                continue
            if not 0.0 < row["mfu"] <= 1.0:
                failures.append(
                    f"{tag}: mfu={row['mfu']!r} outside (0, 1] — the "
                    f"workload model or the device-database peak is wrong")
            for key in ("model_flops_per_frame", "bytes_per_frame"):
                if not row[key] > 0:
                    failures.append(f"{tag}: {key}={row[key]!r} must be "
                                    f"positive")
            if row["mfu_basis"] not in ("measured", "roofline_model"):
                failures.append(f"{tag}: unknown mfu_basis "
                                f"{row['mfu_basis']!r}")
        mega, comp = routes.get("sweep_megakernel"), routes.get("sweep_composed")
        if mega is not None and comp is not None and "mfu" in mega \
                and "mfu" in comp and mega["mfu"] <= comp["mfu"]:
            failures.append(
                f"{name}: committed megakernel mfu {mega['mfu']:.3e} <= "
                f"composed {comp['mfu']:.3e} — the one-launch trunk must "
                f"not be the worse-utilized program")
    return failures


def check(ledger: dict, fresh: dict) -> list[str]:
    """Regression gates: committed schema (validate), committed launch
    topology vs fresh static counts EXACTLY — in BOTH directions: a fresh
    row missing from the ledger fails, and a committed row missing from
    the fresh sweep fails too (a backend or route silently dropped from
    the measurement is exactly the regression this gate exists to catch).
    The in-run megakernel-vs-composed FPS ratio must hold the band, and
    fresh mfu values must land in (0, 1] on THIS machine too.  (Committed
    FPS/MFU absolutes are a record, not a gate — rates are
    machine-dependent.)"""
    failures = validate(ledger)
    if ledger.get("config") != fresh["config"]:
        failures.append(f"ledger config drifted: committed "
                        f"{ledger.get('config')} vs {fresh['config']}")
        return failures
    for name, routes in ledger.get("rows", {}).items():
        for route in routes:
            if fresh["rows"].get(name, {}).get(route) is None:
                failures.append(
                    f"committed row {name}/{route} vanished from the fresh "
                    f"measurement (backend/route dropped from the sweep?)")
    for name, routes in fresh["rows"].items():
        for route, row in routes.items():
            committed = ledger["rows"].get(name, {}).get(route)
            if committed is None:
                failures.append(f"ledger misses row {name}/{route}")
                continue
            for key in ("trunk_launches_per_frame",
                        "program_launches_per_frame"):
                if committed.get(key) != row[key]:
                    failures.append(
                        f"{name}/{route}: {key} changed "
                        f"{committed.get(key)} -> {row[key]} (commit a "
                        f"regenerated BENCH_<pr>.json if intentional)")
            if not 0.0 < row["mfu"] <= 1.0:
                failures.append(
                    f"{name}/{route}: freshly measured mfu={row['mfu']:.3e} "
                    f"outside (0, 1] on this machine")
        mega = routes.get("sweep_megakernel")
        if mega is not None:
            if mega["trunk_launches_per_frame"] != 1:
                failures.append(
                    f"{name}: megakernel trunk is "
                    f"{mega['trunk_launches_per_frame']} launches, not 1")
            composed_fps = routes["sweep_composed"]["sustained_fps"]
            if mega["sustained_fps"] < FPS_BAND * composed_fps:
                failures.append(
                    f"{name}: megakernel sweep regressed past the "
                    f"{FPS_BAND:.0%} band: {mega['sustained_fps']:.1f} vs "
                    f"composed {composed_fps:.1f} FPS")
    return failures


def mfu_deltas(previous: dict | None, current: dict) -> list[str]:
    """Cross-PR trajectory diff: one line per (backend, route) shared with
    the previous ledger.  Informational — machine-dependent absolutes are
    never a gate — but this is what makes the perf trajectory readable
    without replaying old PRs."""
    lines = []
    prev_rows = (previous or {}).get("rows", {})
    for name, routes in current.get("rows", {}).items():
        for route, row in routes.items():
            cur = row.get("mfu")
            if cur is None:
                continue
            old = prev_rows.get(name, {}).get(route, {}).get("mfu")
            if old is None:
                lines.append(f"{name}/{route}: mfu={cur:.3e} (no previous)")
            else:
                lines.append(f"{name}/{route}: mfu {old:.3e} -> {cur:.3e} "
                             f"({(cur - old) / old:+.1%})")
    return lines


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=pathlib.Path, default=None,
                    help="measure and write the ledger JSON (commit it as "
                         "BENCH_<pr>.json in the repo root)")
    ap.add_argument("--check", action="store_true",
                    help="re-measure and gate against the newest committed "
                         "BENCH_<pr>.json; reports MFU deltas vs the "
                         "previous ledger")
    args = ap.parse_args()

    fresh = measure()
    print("name,us_per_call,derived")
    for name, routes in fresh["rows"].items():
        for route, row in routes.items():
            print(f"perf_ledger/{name}_{route},,"
                  f"fps={row['sustained_fps']} "
                  f"p50={row['latency_p50_ms']}ms "
                  f"p99={row['latency_p99_ms']}ms "
                  f"drop_rate={row['drop_rate']} "
                  f"trunk_launches={row['trunk_launches_per_frame']} "
                  f"program_launches={row['program_launches_per_frame']} "
                  f"device_ms={row['device_ms_per_frame']} "
                  f"flops/frame={row['model_flops_per_frame']} "
                  f"bytes/frame={row['bytes_per_frame']} "
                  f"achieved_bw={row['achieved_bw']:.3g}B/s "
                  f"mfu={row['mfu']:.3e} mfu_basis={row['mfu_basis']}")

    failures = []
    if args.check:
        newest = newest_ledger()
        if newest is None:
            failures.append("no committed BENCH_<pr>.json ledger found")
        else:
            committed = json.loads(newest.read_text())
            print(f"perf_ledger/newest,,{newest.name}")
            failures = check(committed, fresh)
            prev = previous_ledger()
            prev_d = json.loads(prev.read_text()) if prev else None
            for line in mfu_deltas(prev_d, committed):
                print(f"perf_ledger/mfu_delta,,"
                      f"vs={prev.name if prev else 'none'} {line}")
    if args.out is not None:
        args.out.write_text(json.dumps(fresh, indent=1) + "\n")
        print(f"perf_ledger/wrote,,{args.out}")

    for f in failures:
        print(f"perf_ledger/FAIL,,{f}")
    print(f"perf_ledger/result,,{'FAIL' if failures else 'OK'}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
