"""Persisted perf ledger for the trunk megakernel: BENCH_6.json.

The megakernel PR's claim is a launch-topology change — the composed FCN
sweep dispatches O(stages x role-maps) Pallas launches per frame, the
`kernels/frame_trunk` megakernel exactly ONE — so this ledger persists the
numbers that pin it: per (backend, route) rows of

    sustained FPS, p50/p99 frame latency, drop rate,
    trunk launches/frame, whole-program launches/frame

over the deterministic smoke clip (SyntheticVideoSource seed 7, the same
frozen frames the golden vectors and stream-smoke gates use), for the three
routes: host tiler, composed sweep (megakernel=False), megakernel sweep
(megakernel=True; fixed substrates only).

Launch counts are STATIC (jaxpr traversal, `analysis/launches.py`) and
machine-independent, so `--check` pins them exactly against the committed
file.  FPS is machine-dependent, so the committed numbers are a record of
the measurement, not a gate; the regression gate is the in-run RATIO — the
megakernel sweep must hold >= `fps_band` (0.85) of the composed sweep's FPS
measured in the same process, i.e. the one-launch trunk can never regress
more than 15% behind the many-launch cascade it replaced.

    PYTHONPATH=src python -m benchmarks.perf_ledger --out BENCH_6.json
    PYTHONPATH=src python -m benchmarks.perf_ledger --check   # CI tier-1
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

FRAMES = 16
SEED = 7
STRIDE = 8
FPS_BAND = 0.85          # megakernel FPS >= band * composed-sweep FPS
BACKENDS = ("ref", "fixed", "fixed_pallas")
MEGA_BACKENDS = ("fixed", "fixed_pallas")
LEDGER = pathlib.Path(__file__).resolve().parent.parent / "BENCH_6.json"


def _launch_counts(be, params, frame_shape, positions, megakernel):
    """(trunk launches, whole-program launches) for one sweep route —
    static jaxpr counts, identical on every host."""
    import jax.numpy as jnp

    from repro.analysis.launches import count_pallas_launches
    from repro.streaming import fcn_sweep as fs

    H, W = frame_shape
    frame = jnp.zeros((1, H, W, 1), jnp.float32)
    p = be.prepare_params(params)
    trunk = count_pallas_launches(
        lambda f: fs._trunk_quad(be, p, f, megakernel), frame)
    fn = fs._sweep_fn(be, (H, W), 28, tuple(positions), megakernel)
    program = count_pallas_launches(fn, params, frame)
    return trunk, program


def _tiler_launches(be, params, n_windows):
    """Whole-program launches for one host-tiler engine wave (all windows
    of one frame in a single batched `apply`)."""
    import jax.numpy as jnp

    from repro.analysis.launches import count_pallas_launches
    from repro.core import smallnet

    tiles = jnp.zeros((n_windows, 28, 28, 1), jnp.float32)
    return count_pallas_launches(
        lambda t: smallnet.apply(params, t, backend=be), tiles)


def _throughput(params, source, engine, tiler):
    """Best-of-3 unpaced pipeline run (the stream_table throughput idiom,
    one run deeper: the ledger's FPS band is a gate, so one scheduler
    hiccup must not decide it)."""
    from repro.streaming.pipeline import StreamingPipeline
    best = None
    for _ in range(3):
        pipe = StreamingPipeline(source, engine, tiler)
        pipe.run()
        s = pipe.stats()
        if best is None or s["sustained_fps"] > best["sustained_fps"]:
            best = s
    return best


def measure() -> dict:
    """One full ledger measurement: the deterministic smoke config."""
    from repro.core import backends as B
    from repro.serving.vision_engine import VisionEngine
    from repro.streaming.fcn_sweep import FcnSweep
    from repro.streaming.sources import SyntheticVideoSource

    from benchmarks import latency_table
    from benchmarks.stream_table import _calibrated_tiler, _params

    params = _params()
    source = SyntheticVideoSource(n_frames=FRAMES, seed=SEED)
    H, W = source.frame_shape
    host = _calibrated_tiler(params, source, STRIDE)
    positions = host.positions((H, W))
    routes = {
        "tiler": host,
        "sweep_composed": FcnSweep(stride=STRIDE, threshold=host.threshold,
                                   megakernel=False),
        "sweep_megakernel": FcnSweep(stride=STRIDE, threshold=host.threshold,
                                     megakernel=True),
    }

    ledger = {
        "config": {"frames": FRAMES, "seed": SEED, "stride": STRIDE,
                   "frame_shape": [H, W], "windows_per_frame": len(positions),
                   "fps_band": FPS_BAND},
        "context": {"deployed_us_per_image":
                    round(latency_table.smoke(params), 1)},
        "rows": {},
    }
    for name in BACKENDS:
        be = B.get_backend(name)
        ledger["rows"][name] = {}
        for route, tiler in routes.items():
            if route == "sweep_megakernel" and name not in MEGA_BACKENDS:
                continue   # no megakernel off the fixed substrates
            if route == "tiler":
                trunk, program = None, _tiler_launches(be, params,
                                                       len(positions))
            else:
                trunk, program = _launch_counts(
                    be, params, (H, W), positions,
                    route == "sweep_megakernel")
            eng = VisionEngine(params, backend=name, batch_size=64,
                               warmup=(route == "tiler"))
            s = _throughput(params, source, eng, tiler)
            ledger["rows"][name][route] = {
                "sustained_fps": round(s["sustained_fps"], 1),
                "latency_p50_ms": round(s.get("latency_p50_ms", 0.0), 2),
                "latency_p99_ms": round(s.get("latency_p99_ms", 0.0), 2),
                "drop_rate": round(s["drop_rate"], 3),
                "trunk_launches_per_frame": trunk,
                "program_launches_per_frame": program,
            }
    return ledger


def check(ledger: dict, fresh: dict) -> list[str]:
    """Regression gates: committed launch topology must match the fresh
    static counts EXACTLY — in BOTH directions: a fresh row missing from
    the ledger fails, and a committed row missing from the fresh sweep
    fails too (a backend or route silently dropped from the measurement is
    exactly the regression this gate exists to catch).  The in-run
    megakernel-vs-composed FPS ratio must hold the band.  (Committed FPS
    is a record, not a gate — absolute rates are machine-dependent.)"""
    failures = []
    if ledger.get("config") != fresh["config"]:
        failures.append(f"ledger config drifted: committed "
                        f"{ledger.get('config')} vs {fresh['config']}")
        return failures
    for name, routes in ledger.get("rows", {}).items():
        for route in routes:
            if fresh["rows"].get(name, {}).get(route) is None:
                failures.append(
                    f"committed row {name}/{route} vanished from the fresh "
                    f"measurement (backend/route dropped from the sweep?)")
    for name, routes in fresh["rows"].items():
        for route, row in routes.items():
            committed = ledger["rows"].get(name, {}).get(route)
            if committed is None:
                failures.append(f"ledger misses row {name}/{route}")
                continue
            for key in ("trunk_launches_per_frame",
                        "program_launches_per_frame"):
                if committed.get(key) != row[key]:
                    failures.append(
                        f"{name}/{route}: {key} changed "
                        f"{committed.get(key)} -> {row[key]} (commit a "
                        f"regenerated BENCH_6.json if intentional)")
        mega = routes.get("sweep_megakernel")
        if mega is not None:
            if mega["trunk_launches_per_frame"] != 1:
                failures.append(
                    f"{name}: megakernel trunk is "
                    f"{mega['trunk_launches_per_frame']} launches, not 1")
            composed_fps = routes["sweep_composed"]["sustained_fps"]
            if mega["sustained_fps"] < FPS_BAND * composed_fps:
                failures.append(
                    f"{name}: megakernel sweep regressed past the "
                    f"{FPS_BAND:.0%} band: {mega['sustained_fps']:.1f} vs "
                    f"composed {composed_fps:.1f} FPS")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=pathlib.Path, default=None,
                    help="measure and write the ledger JSON (commit it)")
    ap.add_argument("--check", action="store_true",
                    help="re-measure and gate against the committed ledger")
    args = ap.parse_args()

    fresh = measure()
    print("name,us_per_call,derived")
    for name, routes in fresh["rows"].items():
        for route, row in routes.items():
            print(f"perf_ledger/{name}_{route},,"
                  f"fps={row['sustained_fps']} "
                  f"p50={row['latency_p50_ms']}ms "
                  f"p99={row['latency_p99_ms']}ms "
                  f"drop_rate={row['drop_rate']} "
                  f"trunk_launches={row['trunk_launches_per_frame']} "
                  f"program_launches={row['program_launches_per_frame']}")

    failures = []
    if args.check:
        if not LEDGER.exists():
            failures.append(f"committed ledger {LEDGER} is missing")
        else:
            failures = check(json.loads(LEDGER.read_text()), fresh)
    if args.out is not None:
        args.out.write_text(json.dumps(fresh, indent=1) + "\n")
        print(f"perf_ledger/wrote,,{args.out}")

    for f in failures:
        print(f"perf_ledger/FAIL,,{f}")
    print(f"perf_ledger/result,,{'FAIL' if failures else 'OK'}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
