"""Paper Table (§IV-B): inference latency & speedup.

The paper compares PS-side CPU (560 ms) vs FPGA fabric (109 ms) = 5.1x.
Our analogue on this host: eager-ish float path vs the baked (constant-
folded, XLA-fused) deployment path — the software/deployed split the paper
measures — plus the TPU-roofline-derived estimate for the deployed path
(the real target hardware this framework compiles for).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import backends, deploy, smallnet
from repro.data import synth_mnist
from repro.launch.mesh import make_serving_mesh
from repro.serving.router import ReplicaRouter
from repro.serving.vision_engine import VisionEngine

# smallNet single-image inference cost (analytic)
_FLOPS = (28 * 28 * 4 * 2          # conv1 2x2 MACs
          + 14 * 14 * 4 * 2        # conv2
          + 49 * 10 * 2)           # dense
_BYTES = 28 * 28 * 4 + 510 * 4


def smoke(params, *, iters: int = 20) -> float:
    """Single-image deployed latency (µs) on the bit-faithful substrate:
    the baked fixed_pallas pipeline, measured quickly.  Context row for
    benchmarks/perf_ledger.py — the ledger's gates are FPS *ratios*, this
    absolute number just anchors them to a per-image cost."""
    x = jnp.zeros((1, 28, 28, 1), jnp.float32)
    qfix = smallnet.quantize_params_fixed(params)
    baked = deploy.bake(
        lambda q, xx: smallnet.apply(q, xx, backend="fixed_pallas"), qfix)
    baked(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        baked(x).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run(trained):
    rows = []
    params = trained.params

    # software path: un-jitted float inference (the paper's CPU side)
    x = jnp.zeros((1, 28, 28, 1), jnp.float32)
    with jax.disable_jit():
        smallnet.forward(params, x)
        t0 = time.perf_counter()
        for _ in range(10):
            smallnet.forward(params, x).block_until_ready()
        sw = (time.perf_counter() - t0) / 10
    rows.append(("latency/software_float_eager", sw * 1e6, "per image"))

    # deployed path: weights baked as constants, fused program
    baked = deploy.bake(smallnet.forward, params)
    baked(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(100):
        baked(x).block_until_ready()
    hw = (time.perf_counter() - t0) / 100
    rows.append(("latency/deployed_baked", hw * 1e6, "per image"))
    rows.append(("latency/speedup", None,
                 f"{sw / hw:.1f}x (paper: 5.1x)"))

    # int8 deployed path
    qp = smallnet.quantize_params_int8(params)
    baked8 = deploy.bake(smallnet.forward_int8, qp)
    baked8(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(100):
        baked8(x).block_until_ready()
    rows.append(("latency/deployed_int8", (time.perf_counter() - t0) / 100 * 1e6,
                 "per image"))

    # bit-faithful deployed path: Qm.n weights baked into the fused
    # fixed-point Pallas pipeline (the closest analogue of the paper's
    # 109 ms fabric number — same words the Verilog datapath would produce)
    qfix = smallnet.quantize_params_fixed(params)
    bakedfx = deploy.bake(
        lambda q, xx: smallnet.apply(q, xx, backend="fixed_pallas"), qfix)
    bakedfx(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(100):
        bakedfx(x).block_until_ready()
    rows.append(("latency/deployed_fixed_pallas",
                 (time.perf_counter() - t0) / 100 * 1e6, "per image"))

    # backend sweep through the streaming vision engine: every registered
    # substrate serves the same 128-request single-image workload in batched
    # jitted steps (the serving-path numbers, queue wait included)
    reqs = synth_mnist.make_dataset(128, seed=5)[0]
    for name in backends.list_backends():
        eng = VisionEngine(params, backend=name, batch_size=32)
        eng.serve(list(reqs))
        s = eng.stats()
        rows.append((f"latency/engine_{name}", s["latency_mean_ms"] * 1e3,
                     f"p50={s['latency_p50_ms']:.2f}ms p95={s['latency_p95_ms']:.2f}ms "
                     f"qps={s['throughput_qps']:.0f} n={s['n']} batch={s['batch_size']} "
                     f"occupancy={s['batch_occupancy']:.2f}"))

    # serving-topology sweep: the same 128-request workload through (a) one
    # engine, (b) one engine whose jitted step shards the batch across the
    # serving mesh (degenerate on 1 device, batch-DP on a pod slice), and
    # (c) a least-loaded router over two replicas drained concurrently —
    # engine -> mesh -> fleet, the three rungs of the scaling ladder
    mesh = make_serving_mesh()
    topo = {
        "single": lambda: VisionEngine(params, backend="pallas", batch_size=32),
        "sharded": lambda: VisionEngine(params, backend="pallas", batch_size=32,
                                        mesh=mesh),
        "routed_x2": lambda: ReplicaRouter.from_backends(
            params, ["pallas", "pallas"], batch_size=32, mesh=mesh),
    }
    for label, build in topo.items():
        srv = build()
        srv.serve(list(reqs))
        s = srv.stats()
        extra = (f"mesh_devices={s['mesh_devices']}" if "mesh_devices" in s
                 else f"replicas={s['replicas']} served_by={s['served_by']}")
        rows.append((f"latency/topology_{label}", s["latency_mean_ms"] * 1e3,
                     f"p95={s['latency_p95_ms']:.2f}ms "
                     f"qps={s['throughput_qps']:.0f} {extra}"))

    # TPU v5e roofline estimate for the deployed conv pipeline
    comp = _FLOPS / 197e12
    mem = _BYTES / 819e9
    rows.append(("latency/tpu_roofline_estimate", max(comp, mem) * 1e6,
                 f"compute={comp*1e9:.1f}ns mem={mem*1e9:.1f}ns (bandwidth-bound)"))
    return rows
