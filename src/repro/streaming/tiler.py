"""Sliding-window tiler: full frames -> 28x28 patches -> detections.

The classifier only ever sees 28x28; a frame is swept by a window at a
configurable stride, every patch is scored in ONE batched `smallnet.apply`
call on any registered backend, and per-patch scores aggregate into a
confidence grid from which thresholded, deduplicated detections with frame
coordinates are extracted.

Patch extraction here is host-side numpy, which re-convolves overlapping
pixels up to 4x — the baseline path.  `streaming/fcn_sweep.FcnSweep` is the
drop-in fully-convolutional alternative that runs the conv trunk ONCE over
the whole frame on device and scores every window from the pooled feature
map, word-exact with this tiler on the fixed substrates (the former ROADMAP
follow-up, landed).

Determinism contract: for integer-scored backends ("fixed"/"fixed_pallas")
the int32 Qm.n words flow through `from_fixed` — identical words give
identical floats give identical detections, so the two fixed substrates are
detection-bit-exact on a frozen clip (asserted in tests).
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import backends as B
from repro.core import fixed_point as fxp
from repro.core import smallnet
from repro.streaming.sources import Frame


@dataclasses.dataclass(frozen=True)
class Detection:
    """One deduplicated hit: class label + the winning patch's frame coords."""
    label: int
    score: float
    y: int                           # top-left of the 28x28 patch
    x: int
    size: int = 28

    @property
    def center(self) -> tuple[float, float]:
        return (self.y + self.size / 2, self.x + self.size / 2)


def tile_positions(frame_shape: tuple[int, int], patch: int,
                   stride: int) -> list[tuple[int, int]]:
    """Top-left (y, x) of every window; the last row/col is clamped to the
    frame edge so coverage is complete even when stride doesn't divide."""
    H, W = frame_shape
    if H < patch or W < patch:
        raise ValueError(f"frame {frame_shape} smaller than patch {patch}")
    ys = list(range(0, H - patch, stride)) + [H - patch]
    xs = list(range(0, W - patch, stride)) + [W - patch]
    return [(y, x) for y in ys for x in xs]


@dataclasses.dataclass(frozen=True)
class Tiler:
    """Window extraction + score aggregation for one (patch, stride) sweep.

    `threshold` is on the backend's sigmoid confidence in (0, 1);
    `min_dist` is the Chebyshev distance (px) at or under which two hits are
    the same object (defaults to one stride — adjacent windows over one
    digit collapse to the strongest).  `min_mass` > 0 additionally gates
    windows on mean pixel intensity: the classifier never saw empty
    background in training, so without the gate it happily "detects" digits
    in noise — mass is a pure function of the (identical) float tiles, so
    the gate preserves cross-substrate detection bit-exactness.  Off by
    default.
    """
    patch: int = 28
    stride: int = 14
    threshold: float = 0.9
    min_dist: int = 14
    min_mass: float = 0.0
    cfg: fxp.FixedPointConfig = fxp.Q16_16   # word format of integer scores

    # subclasses that score from a full-frame sweep instead of host-extracted
    # patches (streaming/fcn_sweep.FcnSweep) flip this; the pipeline routes
    # the per-frame device call accordingly
    sweep: ClassVar[bool] = False

    def positions(self, frame_shape: tuple[int, int]) -> list[tuple[int, int]]:
        return tile_positions(frame_shape, self.patch, self.stride)

    def extract(self, frame: Frame | np.ndarray) -> tuple[np.ndarray,
                                                          list[tuple[int, int]]]:
        """Frame -> (N, patch, patch, 1) float32 tile batch + positions."""
        px = frame.pixels if isinstance(frame, Frame) else np.asarray(frame)
        if px.ndim == 2:
            px = px[..., None]
        pos = self.positions(px.shape[:2])
        p = self.patch
        tiles = np.stack([px[y:y + p, x:x + p] for y, x in pos])
        return np.ascontiguousarray(tiles, np.float32), pos

    def score(self, params: Any, tiles: np.ndarray, *,
              backend: str | B.Backend = "ref") -> np.ndarray:
        """One batched forward over every tile: (N, patch, patch, 1) ->
        (N, 10) backend-native class scores."""
        return np.asarray(smallnet.apply(params, jnp.asarray(tiles),
                                         backend=backend))

    def _confidences(self, scores: np.ndarray) -> np.ndarray:
        """Backend-native (N, 10) scores -> float sigmoid confidences."""
        scores = np.asarray(scores)
        if np.issubdtype(scores.dtype, np.integer):
            scores = np.asarray(fxp.from_fixed(jnp.asarray(scores), self.cfg))
        return scores

    def confidence_grid(self, scores: np.ndarray,
                        positions: Sequence[tuple[int, int]]) -> np.ndarray:
        """(N, 10) scores -> (n_rows, n_cols) map of per-window max
        confidence, in sweep order (the detector's heatmap view).

        The grid is only well-defined for a full rectangular sweep: the
        column count is derived from the distinct x positions and checked
        against the row count, so a non-product position list (e.g. a
        future foreground-gated sparse sweep) fails loudly instead of
        silently reshaping into a garbled heatmap."""
        conf = self._confidences(scores).max(axis=-1)
        n_rows = len({y for y, _ in positions})
        n_cols = len({x for _, x in positions})
        if n_rows * n_cols != len(positions):
            raise ValueError(
                f"confidence_grid needs a full rectangular position grid: "
                f"{len(positions)} positions cannot tile "
                f"{n_rows} rows x {n_cols} cols")
        return conf.reshape(n_rows, n_cols)

    def _masses(self, tiles: np.ndarray,
                positions: Sequence[tuple[int, int]]) -> np.ndarray:
        """Per-window mean pixel intensity for the `min_mass` gate.  Here
        `tiles` is the (N, patch, patch, 1) batch; the FCN sweep overrides
        this to compute the same means from the frame itself."""
        return np.asarray(tiles, np.float32).reshape(len(tiles), -1).mean(1)

    def aggregate(self, scores: np.ndarray,
                  positions: Sequence[tuple[int, int]],
                  tiles: np.ndarray | None = None) -> list[Detection]:
        """Threshold + greedy dedup: strongest window wins, any window whose
        top-left is within `min_dist` (Chebyshev, INCLUSIVE — adjacent
        windows at the default stride collapse) of an accepted detection is
        suppressed regardless of label.  Ties break on (y, x) so the result
        is a pure function of the score words.  Pass `tiles` to apply the
        `min_mass` foreground gate."""
        conf = self._confidences(scores)
        labels = conf.argmax(axis=-1)
        best = conf.max(axis=-1)
        if self.min_mass > 0.0 and tiles is not None:
            mass = self._masses(tiles, positions)
            best = np.where(mass >= self.min_mass, best, -1.0)
        hits = [(float(best[i]), positions[i][0], positions[i][1],
                 int(labels[i]))
                for i in range(len(positions)) if best[i] >= self.threshold]
        hits.sort(key=lambda h: (-h[0], h[1], h[2]))
        out: list[Detection] = []
        for s, y, x, lab in hits:
            if any(max(abs(y - d.y), abs(x - d.x)) <= self.min_dist
                   for d in out):
                continue
            out.append(Detection(label=lab, score=s, y=y, x=x,
                                 size=self.patch))
        return out

    def detect(self, params: Any, frame: Frame | np.ndarray, *,
               backend: str | B.Backend = "ref") -> list[Detection]:
        """The offline (non-pipelined) path: extract -> score -> aggregate.
        The pipeline must produce exactly this for every frame it serves."""
        tiles, pos = self.extract(frame)
        return self.aggregate(self.score(params, tiles, backend=backend),
                              pos, tiles)
