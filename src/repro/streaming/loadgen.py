"""Open-loop traffic generation: seeded arrival processes over N streams.

The paper's deployment discipline is open-loop — the camera emits pixels on
ITS clock, not the fabric's, and a slow stage costs frames.  Production
serving is the same game at fleet scale: requests arrive on the users'
clock regardless of server state, so a harness that waits for the server
(closed-loop) can never expose overload behavior.  `LoadGen` models that
load: N concurrent synthetic request streams, each an independent seeded
arrival process, merged into one deterministic schedule.

Arrival processes (per stream, aggregate rate `rate_qps` split evenly):

  poisson   homogeneous Poisson — i.i.d. exponential inter-arrival gaps;
            the memoryless baseline every queueing result assumes.
  bursty    Markov-modulated on/off (interrupted Poisson): each stream
            alternates exponential ON bursts (mean `burst_on_s`) firing at
            `rate / duty` and silent OFF gaps (mean `burst_off_s`).  The
            duty-cycle normalization keeps the AVERAGE rate equal to the
            Poisson case — same offered load, far spikier, so it stresses
            admission control where the mean-rate process would not.
  diurnal   inhomogeneous Poisson whose rate ramps sinusoidally between
            `diurnal_floor * peak` and `peak` over `duration_s` (one
            trough->peak->trough "day"), realized by thinning a
            peak-rate Poisson process — the textbook exact sampler.

Determinism contract (the `SyntheticVideoSource` idiom): every draw comes
from `np.random.default_rng` seeded by (seed, stream, role), so
`schedule()` and `images()` are pure functions of the constructor
arguments — two LoadGens with equal args emit byte-identical workloads,
regardless of wall clock, interleaving, or how often you call them.

`schedule()` returns the merged, time-sorted arrivals; `replay()` plays
them against a `submit` callback in real time (chunked ticks: wake every
~2 ms and submit EVERYTHING due, so a fast batched server can be driven at
rates far beyond one Python call per request).  Open-loop stamping: pass
each arrival's SCHEDULED time as the submit timestamp so latency and
deadlines measure from intended arrival, not generator lag.

Usage:

    gen = LoadGen(process="bursty", rate_qps=500, duration_s=4,
                  n_streams=8, seed=7)
    eng.start()
    t0 = time.perf_counter()
    gen.replay(lambda a, t: eng.submit(gen.image(a), t_submit=t))
    eng.stop()
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from repro.data.synth_mnist import _glyph_array, _smooth

PROCESSES = ("poisson", "bursty", "diurnal")


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One scheduled request: WHEN it arrives (seconds from epoch start),
    which stream emitted it, and what the image will contain."""
    uid: int                      # schedule-order index, ties broken by stream
    stream: int
    t: float                      # offset from replay start, seconds
    label: int                    # digit the rendered image contains


class LoadGen:
    """Deterministic open-loop arrival-process generator over N streams."""

    def __init__(self, *, process: str = "poisson", rate_qps: float = 100.0,
                 duration_s: float | None = None, n_requests: int | None = None,
                 n_streams: int = 4, seed: int = 0,
                 burst_on_s: float = 0.25, burst_off_s: float = 0.75,
                 diurnal_floor: float = 0.1):
        if process not in PROCESSES:
            raise ValueError(f"unknown process {process!r}; one of {PROCESSES}")
        if (duration_s is None) == (n_requests is None):
            raise ValueError("give exactly one of duration_s / n_requests")
        if rate_qps <= 0:
            raise ValueError("rate_qps must be > 0")
        if n_streams < 1:
            raise ValueError("n_streams must be >= 1")
        if not (0.0 < diurnal_floor <= 1.0):
            raise ValueError("diurnal_floor must be in (0, 1]")
        self.process = process
        self.rate_qps = float(rate_qps)
        # fixed-count mode sizes the window so MEAN load is rate-invariant:
        # n requests at rate r occupy n/r seconds — a 2x-capacity overload
        # run takes the same wall time as a half-capacity one
        self.duration_s = (float(duration_s) if duration_s is not None
                           else n_requests / self.rate_qps)
        self.n_streams = int(n_streams)
        self.seed = int(seed)
        self.burst_on_s = float(burst_on_s)
        self.burst_off_s = float(burst_off_s)
        self.diurnal_floor = float(diurnal_floor)
        self._schedule: list[Arrival] | None = None

    # -- arrival processes (one stream each) --------------------------------

    def _times_poisson(self, rng, rate: float) -> list[float]:
        out, t = [], 0.0
        while True:
            t += rng.exponential(1.0 / rate)
            if t >= self.duration_s:
                return out
            out.append(t)

    def _times_bursty(self, rng, rate: float) -> list[float]:
        # interrupted Poisson: ON windows (mean burst_on_s) fire at
        # rate/duty, OFF windows (mean burst_off_s) are silent; duty
        # normalization keeps the long-run average at `rate`
        duty = self.burst_on_s / (self.burst_on_s + self.burst_off_s)
        rate_on = rate / duty
        out, t = [], 0.0
        # initial phase drawn from the STATIONARY distribution (P[on] =
        # duty); with exponential windows that makes the process stationary
        # from t=0, so the realized mean rate is unbiased even over short
        # schedules
        on = bool(rng.uniform() < duty)
        while t < self.duration_s:
            win = rng.exponential(self.burst_on_s if on else self.burst_off_s)
            if on:
                s = t + rng.exponential(1.0 / rate_on)
                while s < min(t + win, self.duration_s):
                    out.append(s)
                    s += rng.exponential(1.0 / rate_on)
            t += win
            on = not on
        return out

    def _times_diurnal(self, rng, rate: float) -> list[float]:
        # `rate` is the MEAN; the instantaneous rate ramps sinusoidally
        # between floor*peak and peak across the window (one "day":
        # trough -> peak at duration/2 -> trough).  Exact sampling by
        # thinning a peak-rate Poisson stream.
        f = self.diurnal_floor
        peak = rate * 2.0 / (1.0 + f)      # mean of the ramp == rate
        out = []
        for t in self._times_poisson(rng, peak):
            x = np.sin(np.pi * t / self.duration_s)       # 0 -> 1 -> 0
            lam = peak * (f + (1.0 - f) * x)
            if rng.uniform() < lam / peak:
                out.append(t)
        return out

    # -- schedule -----------------------------------------------------------

    def schedule(self) -> list[Arrival]:
        """The full merged workload, time-sorted, uids in time order.
        Pure function of the constructor args (memoized)."""
        if self._schedule is not None:
            return self._schedule
        per_stream = self.rate_qps / self.n_streams
        sampler = getattr(self, f"_times_{self.process}")
        merged: list[tuple[float, int]] = []
        for s in range(self.n_streams):
            rng = np.random.default_rng([self.seed, s, 0xA221])
            merged.extend((t, s) for t in sampler(rng, per_stream))
        merged.sort()                      # ties broken by stream index
        label_rng = np.random.default_rng([self.seed, 0xD161])
        labels = label_rng.integers(0, 10, size=len(merged))
        self._schedule = [Arrival(uid=i, stream=s, t=t, label=int(labels[i]))
                          for i, (t, s) in enumerate(merged)]
        return self._schedule

    def __len__(self) -> int:
        return len(self.schedule())

    @property
    def offered_qps(self) -> float:
        """Realized (not nominal) offered load of this seed's schedule."""
        return len(self.schedule()) / self.duration_s

    # -- payloads -----------------------------------------------------------

    def image(self, arrival: Arrival) -> np.ndarray:
        """Render the arrival's 28x28x1 digit — deterministic per (seed,
        uid): same glyph pipeline as the training data (kron upscale,
        jitter, smooth, noise), so served predictions are meaningful."""
        rng = np.random.default_rng([self.seed, 0x1A6E, arrival.uid])
        g = _glyph_array(arrival.label)
        sy = rng.integers(3, 4)
        sx = rng.integers(3, 5)
        big = np.kron(g, np.ones((sy, sx), np.float32))
        h, w = big.shape
        big = big * rng.uniform(0.8, 1.0)
        dy = rng.integers(0, 28 - h + 1)
        dx = rng.integers(0, 28 - w + 1)
        canvas = np.zeros((28, 28), np.float32)
        canvas[dy:dy + h, dx:dx + w] = big
        canvas = _smooth(canvas)
        canvas += rng.normal(0, 0.03, (28, 28)).astype(np.float32)
        return np.clip(canvas, 0.0, 1.0)[..., None]

    def images(self) -> np.ndarray:
        """Every payload, schedule-ordered: (n, 28, 28, 1) float32."""
        return np.stack([self.image(a) for a in self.schedule()])

    # -- replay -------------------------------------------------------------

    def replay(self, submit: Callable[[Arrival, float], object], *,
               speed: float = 1.0, tick_s: float = 0.002) -> int:
        """Play the schedule open-loop against `submit(arrival, t_submit)`.

        Chunked-tick clocking: sleep until the next due arrival (at most
        `tick_s`), then submit EVERY arrival now due in one burst — the
        generator never falls behind a server faster than Python's
        per-call overhead, and never waits for a slow one (that's the
        point).  `t_submit` passed to the callback is the arrival's
        SCHEDULED wall-clock time (epoch + t/speed) so downstream latency
        accounting measures from intended arrival.  `speed > 1` replays
        the same schedule compressed (2.0 = double the offered rate with
        identical arrival structure).  Returns #submitted."""
        sched = self.schedule()
        t0 = time.perf_counter()
        n = 0
        for a in sched:
            due = t0 + a.t / speed
            while True:
                now = time.perf_counter()
                if now >= due:
                    break
                time.sleep(min(tick_s, due - now))
            submit(a, due)
            n += 1
        return n

    def describe(self) -> dict:
        sched = self.schedule()
        per_stream = [0] * self.n_streams
        for a in sched:
            per_stream[a.stream] += 1
        return {
            "process": self.process,
            "rate_qps": self.rate_qps,
            "offered_qps": self.offered_qps,
            "duration_s": self.duration_s,
            "n": len(sched),
            "n_streams": self.n_streams,
            "per_stream": per_stream,
            "seed": self.seed,
        }


def arrival_cv(gen: LoadGen) -> float:
    """Coefficient of variation of inter-arrival gaps of the MERGED stream
    (1.0 for Poisson; >1 means burstier) — the knob the overload tests
    use to confirm `bursty` really is."""
    ts = np.asarray([a.t for a in gen.schedule()])
    gaps = np.diff(ts)
    if gaps.size < 2 or gaps.mean() == 0:
        return 0.0
    return float(gaps.std() / gaps.mean())


def sweep_processes(rate_qps: float, *, n_requests: int, n_streams: int = 4,
                    seed: int = 0) -> "Sequence[LoadGen]":
    """One LoadGen per arrival process at the same offered load — the
    goodput table's row axis."""
    return [LoadGen(process=p, rate_qps=rate_qps, n_requests=n_requests,
                    n_streams=n_streams, seed=seed) for p in PROCESSES]
