"""Real-time streaming vision: frame sources -> sliding-window tiler ->
deadline-scheduled inference pipeline over the serving stack.

The paper targets "real time, resource-constrained embedded applications" —
pixels stream from the PS into the fabric at frame rate, not as pre-cropped
batches.  This package is that workload: synthetic video sources with
ground-truth tracks (`sources`), a sliding-window 28x28 tiler that turns the
classifier into a full-frame detector (`tiler`), a fully-convolutional frame
sweep that runs the conv trunk once per frame on device and scores every
window from the pooled feature map (`fcn_sweep`, tiler-word-exact on the
fixed substrates), and an asyncio pipeline
with bounded queues, backpressure, and per-frame deadlines (`pipeline`) that
infers through any `VisionEngine` / `ReplicaRouter` topology.
"""
from repro.streaming.fcn_sweep import FcnSweep  # noqa: F401
from repro.streaming.pipeline import StreamConfig, StreamingPipeline  # noqa: F401
from repro.streaming.sources import (Frame, PacedPlayer,  # noqa: F401
                                     SyntheticVideoSource)
from repro.streaming.tiler import Detection, Tiler  # noqa: F401
