"""Deadline-scheduled asyncio pipeline: ingest -> tile -> infer -> aggregate.

The paper's deployment is a free-running pipeline: the camera does not wait
for the fabric, so a slow stage means dropped frames, not unbounded queues.
This module reproduces that discipline over the serving stack:

  ingest     pulls frames from a source (a `PacedPlayer` for real-time, any
             `FrameSource` for max-throughput runs) and admits them to a
             BOUNDED queue.  Real-time mode never blocks the camera: a full
             queue triggers the explicit drop policy ("newest" refuses the
             arriving frame, "oldest" evicts the stalest queued frame).
             Throughput mode blocks instead — backpressure propagates to
             the source and nothing drops.
  tile       sliding-window extraction (`streaming/tiler.py`), or — when the
             tiler is a full-frame sweep (`streaming/fcn_sweep.FcnSweep`,
             `tiler.sweep` is True) — just the window-position bookkeeping:
             the frame itself rides the queue as a single "tile".
  infer      one batched wave through a `VisionEngine` or `ReplicaRouter`
             (any object with `serve()`/`stats()`), run in a worker thread
             so the event loop keeps ingesting on schedule.  In sweep mode
             the wave is instead ONE jitted full-frame trunk call via
             `FcnSweep.score` on the engine's params/backend (the engine's
             per-request batching machinery never sees the frame, so its
             occupancy stats stay empty — the pipeline stats still carry
             the full frame accounting).
  aggregate  confidence thresholding + dedup -> `FrameResult` (identical
             code path for both tilers: scores in, Detections out).

Every frame's age is checked against the per-frame deadline at each stage
boundary; a miss is COUNTED (reason + stage), never silently lost — the
accounting invariant `frames_in == served + dropped` is part of `stats()`
and asserted by the CI smoke.

Observability (`repro/obs/`): the per-stage latency distributions and drop
counters live in the process-wide metrics registry as bounded histograms /
counters (memory O(1) in clip length — the old per-frame python lists grew
forever), and with tracing enabled (`obs.trace.enable()`, or `--trace` on
the benchmarks) every frame carries a root span `frame-<index>` with
tile/infer/aggregate child spans and EXACTLY one terminal status — "served"
or "dropped:<stage>/<reason>" — matching the drop ledger, so a shed frame
carries the span where it died and a served detection explains itself as a
waterfall.  A deadline miss or a broken ledger trips the flight recorder.
"""
from __future__ import annotations

import asyncio
import dataclasses
import inspect
import time
from typing import Any

import numpy as np

from repro.obs import metrics as M
from repro.obs import trace as T
from repro.streaming.sources import Frame, PacedPlayer
from repro.streaming.tiler import Detection, Tiler

_SENTINEL = None

# bucket ladder for the per-stage histograms: stages run 0.1 ms (tile
# bookkeeping in sweep mode) .. seconds (interpret-mode megakernel frames)
_STAGES = ("tile", "infer", "aggregate")


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Pipeline scheduling knobs.

    `deadline_ms=None` disables deadline drops (sensible for throughput
    runs); `realtime=None` auto-detects — a `PacedPlayer` with a target FPS
    streams in real time (drop policy active), anything else is a
    throughput run (ingest blocks, backpressure reaches the source).
    """
    deadline_ms: float | None = None
    queue_size: int = 4
    drop_policy: str = "newest"            # or "oldest"
    realtime: bool | None = None

    def __post_init__(self):
        if self.drop_policy not in ("newest", "oldest"):
            raise ValueError(f"unknown drop_policy {self.drop_policy!r}")
        if self.queue_size < 1:
            raise ValueError("queue_size must be >= 1")


@dataclasses.dataclass
class _Item:
    frame: Frame
    t_ingest: float
    tiles: np.ndarray | None = None
    positions: list | None = None
    scores: np.ndarray | None = None
    stage_s: dict = dataclasses.field(default_factory=dict)
    span: "T.Span | None" = None           # root "frame" span when traced


@dataclasses.dataclass
class FrameResult:
    """One served frame as the pipeline's client sees it."""
    index: int
    detections: list[Detection]
    t_source: float
    t_ingest: float
    t_done: float
    stage_s: dict

    @property
    def latency_s(self) -> float:
        """Ingest-to-detections wall clock (what the consumer observes)."""
        return self.t_done - self.t_ingest


class StreamingPipeline:
    """Frames -> detections through bounded, deadline-checked stages."""

    def __init__(self, source: Any, engine: Any, tiler: Tiler | None = None,
                 *, config: StreamConfig = StreamConfig()):
        self.source = source
        self.engine = engine
        self.tiler = tiler if tiler is not None else Tiler()
        self.config = config
        self.sweep = bool(getattr(self.tiler, "sweep", False))
        if self.sweep and not (hasattr(engine, "params")
                               and hasattr(engine, "backend")):
            raise TypeError(
                "sweep mode scores whole frames through the engine's model, "
                f"but {type(engine).__name__} exposes no params/backend "
                "(use a VisionEngine, or any object with .params/.backend)")
        # disaggregated engines (serving/disagg.DisaggServer) score whole
        # frames through their own trunk/head pools instead of the tiler's
        # monolithic sweep program; they also compile both halves at
        # construction, so the pipeline-side warmup is theirs to skip
        self._disagg = self.sweep and callable(getattr(engine,
                                                       "score_frame", None))
        if self.sweep and not self._disagg \
                and hasattr(source, "frame_shape"):
            # compile the whole-frame sweep program BEFORE the clip starts
            # (the VisionEngine warmup idiom): a multi-second first-frame
            # trace would otherwise blow every deadline in realtime mode
            H, W = source.frame_shape
            self.tiler.score(engine.params,
                             np.zeros((1, H, W, 1), np.float32),
                             backend=engine.backend)
        # duck-typed engines (tests stub serve(tiles)) may not accept the
        # trace-context kwarg; detect once instead of try/except per wave
        serve = getattr(engine, "serve", None)
        self._serve_takes_span = bool(
            serve is not None
            and "parent_span" in inspect.signature(serve).parameters)
        if config.realtime is not None:
            self.realtime = bool(config.realtime)
        else:
            self.realtime = bool(isinstance(source, PacedPlayer)
                                 and source.fps)
        self.results: list[FrameResult] = []
        # -- registry-backed accounting: counters/gauges/histograms in the
        # process-wide registry (bounded memory; `stats()` reads them back,
        # the Prometheus dump exports them).  One unique instance label per
        # pipeline so concurrent benchmark rows coexist.
        self._id = M.instance_label("pipe")
        reg = M.REGISTRY
        self._m_frames_in = reg.counter("stream_frames_in", pipe=self._id)
        self._m_served = reg.counter("stream_frames_served", pipe=self._id)
        self._m_drops: dict[str, M.Counter] = {}   # "stage/reason" -> Counter
        self._stage_hist = {k: reg.histogram("stream_stage_seconds",
                                             stage=k, pipe=self._id)
                            for k in _STAGES}
        self._lat_hist = reg.histogram("stream_frame_latency_seconds",
                                       pipe=self._id)
        self._m_fps = reg.gauge("stream_achieved_fps", pipe=self._id)
        self._queue_gauges: dict[str, M.Gauge] = {}
        self._t_first: float | None = None
        self._t_last: float | None = None

    # -- accounting ---------------------------------------------------------

    def _drop(self, stage: str, reason: str,
              item: "_Item | None" = None) -> None:
        key = f"{stage}/{reason}"
        c = self._m_drops.get(key)
        if c is None:
            c = M.REGISTRY.counter("stream_frames_dropped", stage=stage,
                                   reason=reason, pipe=self._id)
            self._m_drops[key] = c
        c.inc()
        if item is not None and item.span is not None:
            tr = T.get()
            if tr is not None:
                tr.end(item.span, f"dropped:{key}")
                if reason == "deadline":
                    tr.recorder.trip(
                        "slo_violation",
                        f"frame {item.frame.index} missed its "
                        f"{self.config.deadline_ms} ms deadline at {stage}")
                item.span = None

    def _expired(self, item: _Item, stage: str) -> bool:
        dl = self.config.deadline_ms
        if dl is None:
            return False
        if (time.perf_counter() - item.t_ingest) * 1e3 <= dl:
            return False
        self._drop(stage, "deadline", item)
        return True

    async def _admit(self, q: asyncio.Queue, name: str, item: _Item) -> None:
        """Bounded-queue admission: block in throughput mode, apply the drop
        policy in real-time mode (the camera never waits)."""
        if not self.realtime:
            await q.put(item)
        else:
            try:
                q.put_nowait(item)
            except asyncio.QueueFull:
                if self.config.drop_policy == "oldest":
                    evicted = q.get_nowait()           # evict the stalest
                    q.task_done()
                    self._drop(name, "queue_full", evicted)
                    q.put_nowait(item)
                else:
                    self._drop(name, "queue_full", item)
                    return
        g = self._queue_gauges.get(name)
        if g is None:
            g = M.REGISTRY.gauge("stream_queue_depth", queue=name,
                                 pipe=self._id)
            self._queue_gauges[name] = g
        g.set(q.qsize())

    # -- stages -------------------------------------------------------------

    async def _ingest(self, q_tile: asyncio.Queue) -> None:
        if hasattr(self.source, "__aiter__"):
            async for frame in self.source:
                await self._take(q_tile, frame)
        else:
            for frame in self.source:
                await self._take(q_tile, frame)
                await asyncio.sleep(0)             # let stages run
        await q_tile.put(_SENTINEL)

    async def _take(self, q_tile: asyncio.Queue, frame: Frame) -> None:
        now = time.perf_counter()
        if self._t_first is None:
            self._t_first = now
        self._m_frames_in.inc()
        tr = T.get()
        span = (tr.start("frame", f"frame-{frame.index}",
                         index=frame.index, pipe=self._id)
                if tr is not None else None)
        await self._admit(q_tile, "ingest",
                          _Item(frame=frame, t_ingest=now, span=span))

    async def _tile_stage(self, q_tile: asyncio.Queue,
                          q_infer: asyncio.Queue) -> None:
        tr = T.get()
        while True:
            item = await q_tile.get()
            if item is _SENTINEL:
                await q_infer.put(_SENTINEL)
                return
            if self._expired(item, "tile"):
                continue
            t0 = time.perf_counter()
            child = (tr.start("tile", item.span.trace_id, parent=item.span)
                     if tr is not None and item.span is not None else None)
            item.tiles, item.positions = self.tiler.extract(item.frame)
            if child is not None:
                tr.end(child, n_tiles=len(item.tiles))
            item.stage_s["tile"] = time.perf_counter() - t0
            self._stage_hist["tile"].observe(item.stage_s["tile"])
            await self._admit(q_infer, "tile", item)

    def _serve_wave(self, item: _Item) -> "np.ndarray | None":
        """One batched wave through the engine/router (worker thread); in
        sweep mode, one jitted full-frame trunk call instead.  The engine's
        intake stays open across waves (continuous batching) and `serve()`
        pops its own results, so the engine's resident state stays O(batch)
        over an unbounded clip.  Returns None when the engine shed any of
        the frame's tiles — a partially-scored frame is a dropped frame."""
        eng = self.engine
        if self._disagg:
            try:
                if item.span is not None:
                    return eng.score_frame(item.tiles, parent_span=item.span)
                return eng.score_frame(item.tiles)
            except Exception as e:    # noqa: BLE001 — sheds carry .reason
                # a DisaggShedError (queue_depth / deadline / fault after
                # failover) is the fleet declining the frame, not a bug:
                # surface it as a dropped frame like an engine shed
                if hasattr(e, "reason"):
                    return None
                raise
        if self.sweep:
            return self.tiler.score(eng.params, item.tiles,
                                    backend=eng.backend)
        if self._serve_takes_span and item.span is not None:
            res = eng.serve(list(item.tiles), parent_span=item.span)
        else:
            res = eng.serve(list(item.tiles))
        if any(r is None for r in res):
            return None
        return np.stack([r.scores for r in res])

    async def _infer_stage(self, q_infer: asyncio.Queue,
                           q_agg: asyncio.Queue) -> None:
        loop = asyncio.get_running_loop()
        tr = T.get()
        while True:
            item = await q_infer.get()
            if item is _SENTINEL:
                await q_agg.put(_SENTINEL)
                return
            if self._expired(item, "infer"):
                continue
            t0 = time.perf_counter()
            child = (tr.start("infer", item.span.trace_id, parent=item.span,
                              route=("disagg" if self._disagg
                                     else "sweep" if self.sweep
                                     else "engine"))
                     if tr is not None and item.span is not None else None)
            item.scores = await loop.run_in_executor(
                None, self._serve_wave, item)
            if child is not None:
                tr.end(child,
                       "ok" if item.scores is not None else "shed")
            item.stage_s["infer"] = time.perf_counter() - t0
            self._stage_hist["infer"].observe(item.stage_s["infer"])
            if item.scores is None:
                self._drop("infer", "shed", item)  # engine shed >=1 tile
                continue
            await self._admit(q_agg, "infer", item)

    async def _agg_stage(self, q_agg: asyncio.Queue) -> None:
        tr = T.get()
        while True:
            item = await q_agg.get()
            if item is _SENTINEL:
                return
            if self._expired(item, "aggregate"):
                continue
            t0 = time.perf_counter()
            child = (tr.start("aggregate", item.span.trace_id,
                              parent=item.span)
                     if tr is not None and item.span is not None else None)
            dets = self.tiler.aggregate(item.scores, item.positions,
                                        item.tiles)
            if child is not None:
                tr.end(child, n_detections=len(dets))
            t_done = time.perf_counter()
            item.stage_s["aggregate"] = t_done - t0
            self._stage_hist["aggregate"].observe(item.stage_s["aggregate"])
            self._t_last = t_done
            self._m_served.inc()
            self._lat_hist.observe(t_done - item.t_ingest)
            if item.span is not None and tr is not None:
                tr.end(item.span, "served", n_detections=len(dets))
                item.span = None
            self.results.append(FrameResult(
                index=item.frame.index, detections=dets,
                t_source=item.frame.t_source, t_ingest=item.t_ingest,
                t_done=t_done, stage_s=dict(item.stage_s)))

    # -- driving ------------------------------------------------------------

    async def arun(self) -> list[FrameResult]:
        qs = self.config.queue_size
        q_tile, q_infer, q_agg = (asyncio.Queue(maxsize=qs) for _ in range(3))
        await asyncio.gather(self._ingest(q_tile),
                             self._tile_stage(q_tile, q_infer),
                             self._infer_stage(q_infer, q_agg),
                             self._agg_stage(q_agg))
        return self.results

    def run(self) -> list[FrameResult]:
        """Synchronous convenience: drive the whole clip to completion."""
        return asyncio.run(self.arun())

    # -- reporting ----------------------------------------------------------

    def stats(self) -> dict:
        served = self._m_served.value
        frames_in = self._m_frames_in.value
        drops = {k: c.value for k, c in sorted(self._m_drops.items())}
        dropped = sum(drops.values())
        wall = ((self._t_last or 0.0) - (self._t_first or 0.0)
                if served else 0.0)
        by_reason: dict[str, int] = {}
        for key, n in drops.items():
            reason = key.split("/", 1)[1]
            by_reason[reason] = by_reason.get(reason, 0) + n
        accounted = frames_in == served + dropped
        fps = served / wall if wall > 0 else 0.0
        self._m_fps.set(fps)
        lat = self._lat_hist.summary_ms()
        out = {
            "mode": "realtime" if self.realtime else "throughput",
            "frames_in": frames_in,
            "frames_served": served,
            "frames_dropped": dropped,
            "drop_rate": dropped / frames_in if frames_in else 0.0,
            "drops_by_stage": drops,
            "drops_by_reason": by_reason,
            # the no-silent-loss invariant; CI smoke asserts it
            "accounted": accounted,
            "sustained_fps": fps,
            "detections_total": sum(len(r.detections) for r in self.results),
            "queue_hwm": {k: int(g.hwm)
                          for k, g in self._queue_gauges.items()},
            "stage": {k: h.summary_ms()
                      for k, h in self._stage_hist.items()},
            **{f"latency_{k}": v for k, v in lat.items() if k != "n"},
        }
        if not accounted:
            tr = T.get()
            if tr is not None:
                tr.recorder.trip(
                    "ledger_invariant",
                    f"pipeline {self._id}: frames_in={frames_in} != "
                    f"served={served} + dropped={dropped}")
        if hasattr(self.engine, "stats"):
            es = self.engine.stats()
            out["engine"] = es
            if "batch_occupancy" in es:
                out["batch_occupancy"] = es["batch_occupancy"]
            elif "per_replica" in es:
                # exact fleet occupancy: total real images / total slots
                # (NOT a mean of per-replica ratios, which overweights
                # busy replicas)
                slots = sum(r["batches"] * r["batch_size"]
                            for r in es["per_replica"] if "batches" in r)
                padded = sum(r["padded_slots"] for r in es["per_replica"]
                             if "padded_slots" in r)
                if slots:
                    out["batch_occupancy"] = (slots - padded) / slots
        return out
