"""Frame sources: the streaming analogue of `data/synth_mnist`.

The paper's deployment streams pixels from a camera over the PS at frame
rate; the container has no camera (or network), so the live feed is
procedural: `SyntheticVideoSource` renders the synth_mnist digit glyphs
drifting, scaling, and bouncing across an HxW canvas (112x112 by default —
16x the classifier's input area), with the ground-truth track of every
object recorded per frame.  `PacedPlayer` replays any source at a target
FPS on the asyncio clock, which is what makes deadline misses and queue
drops in the pipeline REAL rather than simulated.

Determinism contract: a source is seeded and every iteration replays the
identical clip (fresh rng per `__iter__`), so a "frozen clip" is just a
(source, seed) pair — the bit-exactness tests lean on this.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import Iterator, Protocol, runtime_checkable

import numpy as np

from repro.data.synth_mnist import _glyph_array, _smooth

# glyph cell grid is 7 rows x 5 cols; cell scales cycle through this ladder
# (kron upscale factors), giving digit heights 14..28 px — every scale fits
# inside one 28x28 classifier patch
_SCALE_LADDER = (2, 3, 4, 3)


@dataclasses.dataclass
class TrackBox:
    """Ground truth for one object in one frame: label + pixel bbox."""
    label: int
    y: int                         # top-left corner, frame coords
    x: int
    h: int
    w: int

    @property
    def center(self) -> tuple[float, float]:
        return (self.y + self.h / 2, self.x + self.w / 2)


@dataclasses.dataclass
class Frame:
    index: int
    pixels: np.ndarray             # (H, W, 1) float32 in [0, 1]
    truth: list[TrackBox]
    t_source: float = 0.0          # perf_counter at player emit (0 = unpaced)


@runtime_checkable
class FrameSource(Protocol):
    """Anything that replays a finite clip of `Frame`s deterministically."""

    frame_shape: tuple[int, int]

    def __iter__(self) -> Iterator[Frame]: ...

    def __len__(self) -> int: ...


@dataclasses.dataclass
class _Object:
    label: int
    y: float
    x: float
    vy: float
    vx: float
    intensity: float
    scale_phase: int
    scale_period: int


class SyntheticVideoSource:
    """Seeded procedural video: digits drifting/scaling over a noisy canvas.

    Each object is a synth_mnist glyph with a constant-velocity track that
    reflects off the frame edges and a kron-upscale factor cycling through
    `_SCALE_LADDER` (the "approaching/receding" motion).  Per-frame ground
    truth (`Frame.truth`) carries every object's label and bbox, so
    detection quality is measurable, not just eyeballed.
    """

    def __init__(self, *, n_frames: int = 50, frame_shape=(112, 112),
                 n_objects: int = 2, seed: int = 0, noise: float = 0.03,
                 max_speed: float = 3.0):
        if min(frame_shape) < 7 * max(_SCALE_LADDER):
            raise ValueError(f"frame_shape {frame_shape} cannot hold a digit "
                             f"at max scale {max(_SCALE_LADDER)}")
        self.n_frames = int(n_frames)
        self.frame_shape = (int(frame_shape[0]), int(frame_shape[1]))
        self.n_objects = int(n_objects)
        self.seed = int(seed)
        self.noise = float(noise)
        self.max_speed = float(max_speed)

    def __len__(self) -> int:
        return self.n_frames

    def _spawn(self, rng: np.random.Generator) -> list[_Object]:
        H, W = self.frame_shape
        objs = []
        for _ in range(self.n_objects):
            hmax, wmax = 7 * max(_SCALE_LADDER), 5 * max(_SCALE_LADDER)
            objs.append(_Object(
                label=int(rng.integers(0, 10)),
                y=float(rng.uniform(0, H - hmax)),
                x=float(rng.uniform(0, W - wmax)),
                vy=float(rng.uniform(-self.max_speed, self.max_speed)),
                vx=float(rng.uniform(-self.max_speed, self.max_speed)),
                intensity=float(rng.uniform(0.8, 1.0)),
                scale_phase=int(rng.integers(0, len(_SCALE_LADDER))),
                scale_period=int(rng.integers(6, 12)),
            ))
        return objs

    def __iter__(self) -> Iterator[Frame]:
        rng = np.random.default_rng(self.seed)     # fresh rng: replayable clip
        objs = self._spawn(rng)
        H, W = self.frame_shape
        for t in range(self.n_frames):
            canvas = np.zeros((H, W), np.float32)
            truth: list[TrackBox] = []
            for o in objs:
                s = _SCALE_LADDER[(o.scale_phase + t // o.scale_period)
                                  % len(_SCALE_LADDER)]
                glyph = np.kron(_glyph_array(o.label),
                                np.ones((s, s), np.float32)) * o.intensity
                gh, gw = glyph.shape
                # reflect the track off the edges for THIS scale
                y = int(round(min(max(o.y, 0.0), H - gh)))
                x = int(round(min(max(o.x, 0.0), W - gw)))
                canvas[y:y + gh, x:x + gw] = np.maximum(
                    canvas[y:y + gh, x:x + gw], glyph)
                truth.append(TrackBox(label=o.label, y=y, x=x, h=gh, w=gw))
                o.y += o.vy
                o.x += o.vx
                if o.y < 0 or o.y > H - gh:
                    o.vy = -o.vy
                    o.y = min(max(o.y, 0.0), float(H - gh))
                if o.x < 0 or o.x > W - gw:
                    o.vx = -o.vx
                    o.x = min(max(o.x, 0.0), float(W - gw))
            canvas = _smooth(canvas)
            canvas += rng.normal(0, self.noise, (H, W)).astype(np.float32)
            yield Frame(index=t,
                        pixels=np.clip(canvas, 0.0, 1.0)[..., None],
                        truth=truth)

    def frames(self) -> list[Frame]:
        """Materialize the whole clip (the frozen-clip view for tests)."""
        return list(self)


class RepeatedClipSource:
    """Query-repetition wrapper: every frame of the base clip is emitted
    `repeats` times in a row, with fresh frame indices.

    This is the workload shape the disaggregated serving path exists for —
    overlapping window queries, re-scores under new thresholds, fan-out to
    several consumers — where the SAME pixels are queried repeatedly.  The
    repeated emissions share the base frame's pixel array, so a
    content-keyed feature-map cache (serving/disagg.FeatureMapCache) sees
    `repeats - 1` hits per distinct frame; a monolithic sweep recomputes
    the trunk for every one of them.  The wrapper is itself a seeded
    `FrameSource` (determinism rides on the base clip's contract).
    """

    def __init__(self, source: FrameSource, *, repeats: int = 4):
        if repeats < 1:
            raise ValueError(f"repeats must be >= 1, got {repeats}")
        self.source = source
        self.repeats = int(repeats)
        self.frame_shape = source.frame_shape

    def __len__(self) -> int:
        return len(self.source) * self.repeats

    def __iter__(self) -> Iterator[Frame]:
        i = 0
        for frame in self.source:
            for _ in range(self.repeats):
                yield Frame(index=i, pixels=frame.pixels,
                            truth=frame.truth, t_source=frame.t_source)
                i += 1

    def frames(self) -> list[Frame]:
        return list(self)


class PacedPlayer:
    """Replay a `FrameSource` at a target FPS on the asyncio clock.

    `fps=None` (or 0) emits as fast as the consumer pulls — the
    "too-fast camera" mode the backpressure tests use.  Emission times are
    scheduled against the clip start (frame i at t0 + i/fps), so a slow
    consumer does NOT slow the camera down; frames just arrive late and the
    pipeline's deadline/drop machinery deals with them, exactly like a
    real sensor DMA.
    """

    def __init__(self, source: FrameSource, fps: float | None = None):
        self.source = source
        self.fps = float(fps) if fps else None
        self.frame_shape = source.frame_shape

    def __len__(self) -> int:
        return len(self.source)

    def __aiter__(self):
        return self._gen()

    async def _gen(self):
        t0 = time.perf_counter()
        for i, frame in enumerate(self.source):
            if self.fps is not None:
                delay = (t0 + i / self.fps) - time.perf_counter()
                if delay > 0:
                    await asyncio.sleep(delay)
            frame.t_source = time.perf_counter()
            yield frame
