"""Fully-convolutional frame sweep: the conv trunk ONCE per frame, on device.

`Tiler` re-convolves overlapping pixels up to 4x and extracts every 28x28
window with host-side numpy; this module instead runs smallNet's
conv->sigmoid->pool->conv->sigmoid->pool trunk over the WHOLE HxW frame in
one jitted device call per frame (any registered backend, including the
fused `fixed`/`fixed_pallas` stages), then scores every 28x28 window by
gathering its 7x7 block of the pooled feature map and applying the 49->10
dense head — a strided gather + one `fixed_dense`/matmul instead of N
host-extracted patches.  This is the ZynqNet/Solovyev-style "evaluate the
CNN once over the full frame" deployment the ROADMAP called for.

Exactness contract (the reason this file is mostly about padding):
Patch-wise scoring SAME-pads each 28x28 window (Keras even-kernel
convention: 0 before, 1 after), so a window's last-row/-col features are
computed against ZEROS even when the window sits mid-frame with real pixels
below/right of it.  A naive full-frame trunk uses those real pixels and
diverges from `Tiler` on 13 of every window's 49 features.  The sweep
therefore tracks FOUR role maps per stage ("quad cascade"):

    I  value at a patch position when it is interior (not last row/col)
    B  value when the position is in the patch's last ROW
    R  value when it is in the patch's last COLUMN
    C  value when it is the bottom-right corner

The edge maps are computed frame-wide through the backend's own conv
primitives with MASKED WEIGHTS — a zeroed tap contributes exactly 0 to the
MAC in every word domain, which is precisely what the patch's padding zeros
contribute — and maps that mix sources (e.g. a conv reading interior rows
above a last-row) are decomposed into per-source masked convs recombined
with `Backend.accumulate` (wraparound fixed-point addition is associative
mod 2**bits, so the recombined accumulator word is bit-identical to the
single-conv word).  Scoring a window then selects, per feature, the map
matching that feature's role.  Result: window scores are WORD-EXACT vs
`Tiler.extract`+`score` for the integer backends (interior AND border
windows alike) and float-tight (~1 ulp, XLA conv accumulation order) for
the float backends, so sweep-vs-tiler detection parity on a frozen clip is
a theorem, not a tuning outcome.

Edge/geometry contract (validated loudly, tested in tests/test_fcn_sweep.py):

  * window positions must sit on the pooled-map lattice: y % 4 == x % 4 == 0
    (two 2x2/2 pools -> stride-4 granularity).  `stride` must be a multiple
    of 4 and the frame must satisfy (H - patch) % 4 == 0 (equivalently
    H % 4 == 0 for patch 28) so the edge-clamped last window of
    `tile_positions` is gatherable; anything else raises ValueError.
  * `patch` must be a multiple of 4 (the deployed dense head fixes it at
    28: 49 pooled features).
  * saturating fixed-point configs are rejected (saturation is not
    associative, so the decomposed accumulation could drift); the
    registered `fixed`/`fixed_pallas` backends use the hardware-faithful
    wraparound mode, which is exact.

Launch topology: the composed cascade dispatches O(stages x role-maps)
kernel launches per frame on the Pallas substrates (4 single-source + 5
mixed-source convs at level 1, plus pools and PLAN units).  On the fixed
substrates the whole quad trunk now also exists as ONE tiled Pallas launch
(`kernels/frame_trunk`), reached through `Backend.frame_trunk`; the
`megakernel` knob below picks the route, and `benchmarks/perf_ledger.py`
pins launches-per-frame for both.

`FcnSweep` is `Tiler`-compatible: `positions` / `extract` / `score` /
`confidence_grid` / `aggregate` / `detect` have the same shapes and
semantics (`extract` returns the frame itself as a single "tile" batch),
so the streaming pipeline's confidence grid, dedup, and `Detection` output
run unchanged — `StreamingPipeline` just routes the per-frame device call
through `FcnSweep.score` instead of an engine wave when `tiler.sweep`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, ClassVar, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backends as B
from repro.core import runtime
from repro.core import smallnet
from repro.streaming.sources import Frame
from repro.streaming.tiler import Tiler, tile_positions

_POOL = 4          # two 2x2/2 pools: pooled-map granularity in frame pixels


def _mask(rows, cols) -> np.ndarray:
    """(2,2) 0/1 tap mask from per-axis keep flags."""
    return np.asarray(rows, np.int32)[:, None] * np.asarray(cols, np.int32)[None, :]

# tap masks: keep (row0|row1) x (col0|col1) of the 2x2 kernel
_TOP, _BOT = (1, 0), (0, 1)
_ALL = (1, 1)


def _pool_mix(even_rows, odd_rows):
    """2x2/2 pool whose even input rows come from `even_rows` and odd rows
    from `odd_rows` (maps are (B,H,W) fixed words or (B,H,W,1) float NHWC;
    pooling is over axes 1,2).  Pure comparisons — exact in every domain."""
    e, o = even_rows, odd_rows
    return jnp.maximum(jnp.maximum(e[:, ::2, ::2], e[:, ::2, 1::2]),
                       jnp.maximum(o[:, 1::2, ::2], o[:, 1::2, 1::2]))


def _pool_quadrants(tl, tr, bl, br):
    """2x2/2 pool with a distinct source map per window quadrant:
    (2r,2c) from tl, (2r,2c+1) from tr, (2r+1,2c) from bl, (2r+1,2c+1)
    from br."""
    return jnp.maximum(jnp.maximum(tl[:, ::2, ::2], tr[:, ::2, 1::2]),
                       jnp.maximum(bl[:, 1::2, ::2], br[:, 1::2, 1::2]))


def _sweep_stage(be: B.Backend, quad, w, b):
    """One conv->activation->pool stage over the role-map quad.

    Role bookkeeping: for a patch of side N at this stage, conv output row
    N-2 ("prelast") reads input rows N-2 (interior) and N-1 (last row ->
    the B map); conv output row N-1 ("last") reads input row N-1 (B map)
    and the patch's SAME-padding zeros, realized by masking the bottom
    taps.  The pooled last row then combines the prelast (even) and last
    (odd) conv rows.  Columns are symmetric with the R map; the corner
    walks the same lattice through C.
    """
    I, Bm, R, C = quad
    zb = jnp.zeros_like(b)
    w_top = be.mask_conv_weight(w, _mask(_TOP, _ALL))
    w_bot = be.mask_conv_weight(w, _mask(_BOT, _ALL))
    w_left = be.mask_conv_weight(w, _mask(_ALL, _TOP))
    w_right = be.mask_conv_weight(w, _mask(_ALL, _BOT))
    w_00 = be.mask_conv_weight(w, _mask(_TOP, _TOP))
    w_01 = be.mask_conv_weight(w, _mask(_TOP, _BOT))
    w_10 = be.mask_conv_weight(w, _mask(_BOT, _TOP))
    w_11 = be.mask_conv_weight(w, _mask(_BOT, _BOT))

    # single-source role maps: one fused conv+activation launch each
    s_ii = be.fused_conv_act(I, w, b)                    # all taps interior
    s_li = be.sigmoid(be.conv2x2_same(Bm, w_top, b))     # last row
    s_il = be.sigmoid(be.conv2x2_same(R, w_left, b))     # last col
    s_ll = be.sigmoid(be.conv2x2_same(C, w_00, b))       # corner
    if Bm is I and R is I and C is I:
        # level 0: pixels are role-independent, so every mixed-source map
        # collapses onto a single-source one (the masks partition the full
        # kernel over one source; for fixed words this is the associativity
        # argument again, for floats it IS the patch's single-conv sum) —
        # the full-resolution stage runs 4 conv launches instead of 13
        s_pi = s_ip = s_pp = s_ii
        s_pl, s_lp = s_il, s_li
    else:
        # mixed-source maps: masked partial convs recombined pre-activation
        s_pi = be.sigmoid(be.accumulate(                 # prelast row
            be.conv2x2_same(I, w_top, b), be.conv2x2_same(Bm, w_bot, zb)))
        s_ip = be.sigmoid(be.accumulate(                 # prelast col
            be.conv2x2_same(I, w_left, b), be.conv2x2_same(R, w_right, zb)))
        s_pp = be.sigmoid(be.accumulate(be.accumulate(be.accumulate(
            be.conv2x2_same(I, w_00, b),                 # prelast/prelast
            be.conv2x2_same(R, w_01, zb)),
            be.conv2x2_same(Bm, w_10, zb)),
            be.conv2x2_same(C, w_11, zb)))
        s_pl = be.sigmoid(be.accumulate(                 # prelast row, last col
            be.conv2x2_same(R, w_00, b), be.conv2x2_same(C, w_10, zb)))
        s_lp = be.sigmoid(be.accumulate(                 # last row, prelast col
            be.conv2x2_same(Bm, w_00, b), be.conv2x2_same(C, w_01, zb)))

    return (be.maxpool2x2(s_ii),                         # interior
            _pool_mix(s_pi, s_li),                       # last pooled row
            _pool_quadrants(s_ip, s_il, s_ip, s_il),     # last pooled col
            _pool_quadrants(s_pp, s_pl, s_lp, s_ll))     # pooled corner


def _squeeze_map(x):
    """(1,H,W) fixed words or (1,H,W,1) float NHWC -> (H,W)."""
    return x[0, ..., 0] if x.ndim == 4 else x[0]


def _trunk_quad(be: B.Backend, p: dict, frames, megakernel: bool | None = None):
    """Both conv stages of the sweep over one (1,H,W,1) float frame batch:
    the level-2 role-map quad (I, B, R, C), each (1, H/4, W/4[, 1]).  The
    single trunk definition shared by the jitted scorer and the
    golden-pinned `sweep_feature_maps` view.

    `megakernel` routes through the backend's whole-frame `frame_trunk`
    hook (kernels/frame_trunk: the entire quad trunk in ONE Pallas launch
    on the fixed substrates): None tries the hook and falls back to the
    composed per-stage path, True requires it (raising where no megakernel
    exists), False forces the composed path (what the megakernel's
    word-exactness gates compare against)."""
    if megakernel is None or megakernel:
        quad = be.frame_trunk(frames, p)
        if quad is not None:
            return quad
        if megakernel:
            raise NotImplementedError(
                f"backend {be.name!r} has no frame_trunk megakernel for "
                f"frames of shape {tuple(frames.shape)} (the one-launch "
                f"trunk exists on the fixed substrates, for single "
                f"multiple-of-4 frames)")
    x = be.ingest(frames)
    quad = (x, x, x, x)      # pixels are role-independent at level 0
    quad = _sweep_stage(be, quad, p["conv1"]["w"], p["conv1"]["b"])
    return _sweep_stage(be, quad, p["conv2"]["w"], p["conv2"]["b"])


def _check_saturation(be: B.Backend) -> None:
    cfg = getattr(be, "cfg", None)
    if cfg is not None and getattr(cfg, "saturate", False):
        raise NotImplementedError(
            "FcnSweep requires a wraparound fixed-point config: saturating "
            "addition is not associative, so the sweep's decomposed edge-map "
            "accumulation could drift from the patch-wise words.  The "
            "registered 'fixed'/'fixed_pallas' backends use wraparound mode.")


def _window_gather(patch: int, positions: tuple[tuple[int, int], ...]):
    """Static gather indices + role masks for scoring `positions` from a
    pooled role-map quad: (rows, cols, is_last_row, is_last_col), where
    rows/cols are (Nw, k, 1)/(Nw, 1, k) pooled-lattice indices and the
    masks flag each window feature's last pooled row/col (the role that
    decides which quad map supplies it)."""
    k = patch // _POOL
    gy = jnp.asarray([y // _POOL for y, _ in positions])
    gx = jnp.asarray([x // _POOL for _, x in positions])
    off = jnp.arange(k)
    rows = gy[:, None, None] + off[None, :, None]        # (Nw, k, 1)
    cols = gx[:, None, None] + off[None, None, :]        # (Nw, 1, k)
    is_last_row = (off == k - 1)[None, :, None]
    is_last_col = (off == k - 1)[None, None, :]
    return rows, cols, is_last_row, is_last_col


def _head_scores(be: B.Backend, p: dict, quad, gather, n_windows: int):
    """The sweep's dense-head half as traced code: role-map quad + static
    gather -> (Nw, 10) backend-native scores.  Shared verbatim by the
    monolithic `_sweep_fn` and the disaggregated head program
    (`make_head_fn`), so splitting the sweep across engine pools cannot
    change a single word on the integer substrates."""
    rows, cols, is_last_row, is_last_col = gather
    I2, B2, R2, C2 = (_squeeze_map(m) for m in quad)
    feats = jnp.where(
        is_last_row & is_last_col, C2[rows, cols],
        jnp.where(is_last_row, B2[rows, cols],
                  jnp.where(is_last_col, R2[rows, cols],
                            I2[rows, cols])))            # (Nw, k, k)
    return smallnet.dense_head(p, feats.reshape(n_windows, -1), backend=be)


@functools.lru_cache(maxsize=64)
def _sweep_fn(be: B.Backend, frame_shape: tuple[int, int], patch: int,
              positions: tuple[tuple[int, int], ...],
              megakernel: bool | None = None):
    """Jitted whole-sweep function for one (backend, geometry): params +
    (1,H,W,1) float frame -> (n_windows, 10) backend-native scores, ONE
    device call per frame."""
    gather = _window_gather(patch, positions)

    def run(params, frame):
        p = be.prepare_params(params)
        quad = _trunk_quad(be, p, frame, megakernel)
        return _head_scores(be, p, quad, gather, len(positions))

    return jax.jit(run)


@functools.lru_cache(maxsize=32)
def make_trunk_fn(backend: str, megakernel: bool | None = None):
    """Jitted TRUNK half of the sweep for a registered backend: (params,
    (1,H,W,1) float frame) -> the level-2 role-map quad (I, B, R, C) in
    the backend's native domain.  This is the heavy per-frame stage the
    disaggregated serving layer (`serving/disagg.py`) runs on its trunk
    pool and caches per frame digest; `make_head_fn` scores windows from
    the result.  `megakernel` routes as in `_trunk_quad`."""
    be = B.get_backend(backend)
    _check_saturation(be)

    def run(params, frames):
        p = be.prepare_params(params)
        return _trunk_quad(be, p, frames, megakernel)

    return jax.jit(run)


@functools.lru_cache(maxsize=64)
def make_head_fn(backend: str, patch: int,
                 positions: tuple[tuple[int, int], ...]):
    """Jitted HEAD half of the sweep: (params, role-map quad) -> (Nw, 10)
    backend-native window scores for a fixed window lattice.  Runs the
    SAME traced gather + dense head as the monolithic `_sweep_fn`
    (`_head_scores`), so head-pool scores from a cached feature quad are
    int32 word-exact vs the one-call sweep on the fixed substrates."""
    be = B.get_backend(backend)
    _check_saturation(be)
    gather = _window_gather(patch, positions)

    def run(params, quad):
        p = be.prepare_params(params)
        return _head_scores(be, p, quad, gather, len(positions))

    return jax.jit(run)


# flipping the process-wide interpret switch must drop programs compiled
# under the old mode (core/runtime.py documents the staleness hazard)
runtime.register_reset_hook(_sweep_fn.cache_clear)
runtime.register_reset_hook(make_trunk_fn.cache_clear)
runtime.register_reset_hook(make_head_fn.cache_clear)


def sweep_feature_maps(params: Any, frame: np.ndarray | jnp.ndarray, *,
                       backend: str | B.Backend = "ref",
                       megakernel: bool | None = None):
    """The level-2 role-map quad for one (H,W[,1]) frame: a dict of
    (H/4, W/4) pooled feature maps {"interior", "last_row", "last_col",
    "corner"} in the backend's native domain (Qm.n int32 words for the
    fixed substrates).  This is the sweep trunk without the dense head —
    what the golden vectors freeze.  `megakernel` as in `_trunk_quad`
    (False pins the composed per-stage path; the golden generators use it
    so frozen vectors keep pinning the decomposition itself)."""
    be = B.get_backend(backend)
    _check_saturation(be)
    f = jnp.asarray(np.asarray(frame, np.float32))
    if f.ndim == 2:
        f = f[..., None]
    quad = _trunk_quad(be, be.prepare_params(params), f[None], megakernel)
    names = ("interior", "last_row", "last_col", "corner")
    return {n: np.asarray(_squeeze_map(m)) for n, m in zip(names, quad)}


@dataclasses.dataclass(frozen=True)
class FcnSweep(Tiler):
    """Drop-in `Tiler` that scores windows from one full-frame trunk pass.

    Same knobs and aggregation semantics as `Tiler`; `stride` must be a
    multiple of 4 (pooled-map granularity) and defaults to 8 — finer than
    the host tiler's 14 because sweep windows are nearly free.  `extract`
    returns the frame itself as a (1,H,W,1) "tile" batch (the mass gate
    computes per-window means from it), and `score` runs the jitted sweep:
    one device call per frame on any registered backend.

    `megakernel` selects the trunk implementation inside that call:
    None (default) uses the backend's one-launch `frame_trunk` megakernel
    where it exists (the fixed substrates) and the composed role-map
    cascade elsewhere; False forces the composed cascade everywhere (the
    word-exactness baselines pin against this); True requires the
    megakernel and raises on backends without one.  All three produce
    identical words on the fixed substrates — the knob changes launches
    per frame, not scores.
    """
    stride: int = 8
    megakernel: bool | None = None
    sweep: ClassVar[bool] = True

    def __post_init__(self):
        if self.patch % _POOL:
            raise ValueError(
                f"FcnSweep patch must be a multiple of {_POOL} "
                f"(two 2x2/2 pools), got {self.patch}")
        if self.stride % _POOL:
            raise ValueError(
                f"FcnSweep stride must be a multiple of {_POOL}: window "
                f"positions live on the pooled-map lattice (got "
                f"{self.stride})")

    def positions(self, frame_shape: tuple[int, int]) -> list[tuple[int, int]]:
        H, W = frame_shape
        if (H - self.patch) % _POOL or (W - self.patch) % _POOL:
            raise ValueError(
                f"frame {frame_shape} breaks the sweep edge contract: the "
                f"edge-clamped last window at (H-{self.patch}, W-"
                f"{self.patch}) must sit on the stride-{_POOL} pooled "
                f"lattice, i.e. (H - patch) % {_POOL} == 0 on both axes "
                f"(pad or crop the frame to a multiple of {_POOL})")
        return tile_positions(frame_shape, self.patch, self.stride)

    def extract(self, frame: Frame | np.ndarray) -> tuple[np.ndarray,
                                                          list[tuple[int, int]]]:
        """Frame -> ((1, H, W, 1) float32 frame batch, window positions).
        No host-side patch materialization — that is the whole point."""
        px = frame.pixels if isinstance(frame, Frame) else np.asarray(frame)
        if px.ndim == 2:
            px = px[..., None]
        pos = self.positions(px.shape[:2])
        return np.ascontiguousarray(px[None], np.float32), pos

    def score(self, params: Any, frames: np.ndarray, *,
              backend: str | B.Backend = "ref") -> np.ndarray:
        """One jitted full-frame trunk pass + windowed dense head:
        (1, H, W, 1) frame -> (n_windows, 10) backend-native scores, in
        `positions` order."""
        be = B.get_backend(backend)
        _check_saturation(be)
        frames = np.asarray(frames, np.float32)
        if frames.ndim == 3:
            frames = frames[None]
        if frames.shape[0] != 1:
            raise ValueError(
                f"FcnSweep.score takes one frame per call (the sweep is a "
                f"per-frame device program), got batch {frames.shape[0]}")
        H, W = frames.shape[1], frames.shape[2]
        pos = tuple(self.positions((H, W)))
        fn = _sweep_fn(be, (H, W), self.patch, pos, self.megakernel)
        return np.asarray(fn(params, jnp.asarray(frames)))

    def _masses(self, tiles: np.ndarray,
                positions: Sequence[tuple[int, int]]) -> np.ndarray:
        """Per-window mean pixel intensity from the frame itself: one
        strided-view gather instead of a per-window host loop (same
        elements in the same row-major reduction order as `Tiler`'s
        per-tile means — asserted by the mass-gate parity test)."""
        frame = np.asarray(tiles, np.float32)[0, ..., 0]
        p = self.patch
        wins = np.lib.stride_tricks.sliding_window_view(frame, (p, p))
        ys = np.fromiter((y for y, _ in positions), np.intp)
        xs = np.fromiter((x for _, x in positions), np.intp)
        return wins[ys, xs].mean(axis=(-2, -1), dtype=np.float32)
