"""int8 gradient-compression all-reduce — the paper's quantization insight
applied as a distributed-optimization trick.

smallNet's thesis: match the numeric format to the transport/compute fabric
(32-bit words on Zynq, int8 on the MXU).  Here the transport is the
inter-pod ICI/DCN link: gradients are block-quantized to int8 (+f32 scale
per block), all-reduced in the compressed domain, dequantized after — a
~4x reduction of cross-pod gradient bytes with error feedback.

Implemented with shard_map + psum over an explicit axis so the collective
really is int8-sized on the wire (the f32 scales psum separately; their
bytes are 1/256th of the payload).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

BLOCK = 256


def _quantize_block(x: jnp.ndarray):
    """x (..., BLOCK) f32 -> (int8 values, f32 scale per block)."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """psum(x) with int8-on-the-wire compression.  Exactness: the SUM of
    int8 shards is carried in int32 (no overflow for <= 2^23 participants),
    scales are summed in f32; result = dequantized mean-preserving sum with
    per-block absmax error <= (n_peers * max|x| / 127)."""
    shape = x.shape
    n = x.size
    pad = (-n) % BLOCK
    xf = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, pad)).reshape(-1, BLOCK)
    q, scale = _quantize_block(xf)
    # carry values int32 so the reduction itself is exact
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)      # int payload
    ssum = jax.lax.psum(scale, axis_name)                    # f32, tiny
    npeers = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    out = qsum.astype(jnp.float32) * (ssum / npeers)
    return out.reshape(-1)[:n].reshape(shape)


def make_compressed_allreduce(mesh, axis: str = "pod"):
    """Tree-level compressed all-reduce over one mesh axis (e.g. cross-pod
    gradient averaging while FSDP handles intra-pod)."""
    def allreduce(tree):
        def one(g):
            spec = P(*([None] * g.ndim))
            fn = jax.shard_map(
                functools.partial(compressed_psum, axis_name=axis),
                mesh=mesh, in_specs=spec, out_specs=spec)
            return (fn(g) / mesh.shape[axis]).astype(g.dtype)
        return jax.tree_util.tree_map(one, tree)
    return allreduce


def compression_error_feedback(grads, residual):
    """Error-feedback accumulator (Seide et al.): add the previous round's
    quantization residual before compressing; return (to_send, new_residual)."""
    if residual is None:
        residual = jax.tree_util.tree_map(jnp.zeros_like, grads)
    to_send = jax.tree_util.tree_map(lambda g, r: g + r, grads, residual)

    def _resid(s):
        n = s.size
        pad = (-n) % BLOCK
        xf = jnp.pad(s.reshape(-1).astype(jnp.float32), (0, pad)).reshape(-1, BLOCK)
        q, scale = _quantize_block(xf)
        deq = (q.astype(jnp.float32) * scale).reshape(-1)[:n].reshape(s.shape)
        return (s - deq).astype(s.dtype)

    new_residual = jax.tree_util.tree_map(_resid, to_send)
    return to_send, new_residual
