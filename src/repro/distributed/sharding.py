"""Logical-axis sharding policy (MaxText-style rules -> PartitionSpecs).

Model code annotates activations/weights with *logical* axis names
("batch", "ffn", "heads", ...).  A `ShardingRules` context maps those to
physical mesh axes; outside any rules context every annotation is a no-op,
so the same model code runs on 1 CPU device (smoke tests) and on the
(2,16,16) production mesh (dry-run / launch).

Baseline policy (DESIGN.md §5):
  * DP: "batch" -> ("pod","data") when the batch divides, else unsharded
  * TP: flattened projection outputs ("qkv", "ffn", "vocab", "experts") -> "model"
  * FSDP/ZeRO-3: every weight's d_model dim ("fsdp") -> "data" (+"pod")
  * GQA: "heads" -> "model" only when n_heads % model_size == 0;
         decode KV caches shard "head_dim" -> "model" (always divisible here)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Mapping

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _rules() -> Mapping[str, Any] | None:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def sharding_rules(rules: Mapping[str, Any] | None):
    prev = _rules()
    _state.rules = dict(rules) if rules is not None else None
    try:
        yield
    finally:
        _state.rules = prev


def logical_spec(*names: str | None) -> P:
    rules = _rules() or {}
    return P(*[rules.get(n) for n in names])


def constrain(x: jnp.ndarray, *names: str | None) -> jnp.ndarray:
    """with_sharding_constraint by logical names; no-op without rules."""
    if _rules() is None:
        return x
    return jax.lax.with_sharding_constraint(x, logical_spec(*names))


def make_rules(*, mesh_axes: tuple[str, ...], global_batch: int,
               n_heads: int, n_kv_heads: int,
               decode: bool = False, seq_len: int = 0,
               family: str = "dense") -> dict[str, Any]:
    """Build the logical->physical mapping for one (arch, shape, mesh)."""
    has_pod = "pod" in mesh_axes
    data_axes = ("pod", "data") if has_pod else ("data",)
    # mesh sizes are fixed by make_production_mesh: pod=2, data=16, model=16
    data_size = 32 if has_pod else 16
    model_size = 16

    batch = data_axes if global_batch % data_size == 0 else (
        ("data",) if global_batch % 16 == 0 else None)
    heads = "model" if n_heads % model_size == 0 else None
    # Megatron-style sequence parallelism on the residual stream: shards the
    # per-layer remat stack over "model" (16x activation-memory win); GSPMD
    # inserts the all-gather before qkv/mlp and reduce-scatter after.
    # Time-recurrent blocks (rwkv/mamba) must pin their scan operands and
    # outputs seq-UNsharded (see rwkv6._wkv_scan) or the while loop
    # re-gathers the whole stack every timestep; with those pins in place,
    # SP measured strictly better than no-SP for the ssm family too
    # (2.31 s vs 3.11 s collective on rwkv6 train_4k).
    res_seq = "model" if (not decode and seq_len % model_size == 0) else None
    rules = {
        # activations
        "batch": batch,
        "res_seq": res_seq,
        "seq": None,
        "embed": None,
        "heads": heads,
        "kv_heads": None,                       # kv_heads < 16 for all archs
        # context-parallel fallback when heads % 16 != 0 (qwen2.5's 40H,
        # whisper's 6H): shard K/V over SEQUENCE in the attention core —
        # scores stay T-sharded, softmax stats + output partial-sums
        # all-reduce.  Without this GSPMD replicates the attention einsums
        # (measured useful_ratio 0.05 on qwen2.5 prefill_32k).
        "kv_seq": ("model" if heads is None and not decode
                   and seq_len % model_size == 0 else None),
        "head_dim": None,
        "qkv": "model",                         # flattened H*hd projections
        "ffn": "model",
        "vocab": "model",
        "experts": "model",
        "expert_group": batch,
        "cache_batch": batch,
        "cache_head_dim": "model",              # decode state TP dim (ssm)
        # flash-decoding layout: KV cache sharded over SEQUENCE; scores stay
        # T-sharded, softmax stats all-reduce is (B,1,H) — tiny.  The token
        # write is a masked elementwise update (no cross-shard DUS).
        # Baseline hd-sharding measured 126 GiB/token of cache all-gathers
        # on llama3-405b decode_32k.
        "cache_seq": ("model" if decode and seq_len % model_size == 0
                      else None),
        # weights — ZeRO-3 dim on every weight; spans the pod axis too on the
        # multi-pod mesh (halves optimizer-state HBM; costs cross-pod
        # all-gathers — the documented memory/bandwidth trade at 405B scale)
        "fsdp": data_axes,
        "w_model": "model",
        "layers": None,
    }
    return rules


def vision_batch_axes(mesh) -> tuple[str, ...]:
    """The mesh axes the vision serving path shards its batch over: every
    data-parallel axis present ("pod"/"data"), else the first mesh axis (a
    bare single-axis serving mesh still gets batch DP)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if axes else (mesh.axis_names[0],)


def vision_batch_multiple(mesh) -> int:
    """Per-step batch sizes must be a multiple of this (the product of the
    batch mesh axes) so every device gets equal full shards."""
    mult = 1
    for a in vision_batch_axes(mesh):
        mult *= mesh.shape[a]
    return mult


def make_vision_rules(mesh) -> dict[str, Any]:
    """Vision-serving preset: shard ONLY the batch axis over the mesh's
    data-parallel axes and replicate everything else.

    smallNet carries 510 parameters (~2 KB) — replicating weights is free,
    so the whole scaling story is batch DP: one jitted step whose inputs /
    activations / outputs are split along "batch" across the mesh (the JAX
    analogue of replicating the paper's fabric pipeline per compute unit
    and partitioning the DMA stream).  Degenerates to a no-op on a 1-device
    mesh, so the same engine code runs in smoke tests and at scale.
    """
    axes = vision_batch_axes(mesh)
    batch = axes if len(axes) > 1 else axes[0]
    return {
        "batch": batch,
        # spatial / feature / class dims stay replicated
        "height": None, "width": None, "channels": None,
        "features": None, "classes": None,
    }


# ---------------------------------------------------------------------------
# Weight PartitionSpecs: map each param leaf's logical axes to a spec.
# Models attach logical axis names to params via init metadata (a parallel
# pytree of tuples produced by the init functions).
# ---------------------------------------------------------------------------

def specs_from_axes(axes_tree: Any) -> Any:
    """Logical-axes pytree (tuples of names) -> PartitionSpec pytree."""
    return jax.tree_util.tree_map(
        lambda axes: logical_spec(*axes),
        axes_tree, is_leaf=lambda x: isinstance(x, tuple))
