"""Training launcher.

Single-host (this container):
    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --steps 100 --preset smoke

Fleet posture: on a real multi-pod slice each host runs this same entrypoint
under the cluster scheduler with jax.distributed.initialize() (env-driven);
`make_production_mesh()` builds the (pod, data, model) mesh over the global
device set, data loading is host-indexed (data/lm_data.py), checkpoints are
written per-host shards, and `run_with_restarts` + the scheduler's
reschedule-on-failure give crash-consistent training.  Everything below the
mesh construction is identical in both modes.
"""
from __future__ import annotations

import argparse

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--distributed", action="store_true",
                    help="multi-host: jax.distributed.initialize() from env")
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    from repro.configs.base import get_config
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.preset == "smoke":
        cfg = cfg.smoke()
    t = Trainer(cfg, TrainerConfig(
        total_steps=args.steps, seq_len=args.seq_len,
        global_batch=args.global_batch, lr=args.lr,
        warmup_steps=max(5, args.steps // 20),
        ckpt_dir=args.ckpt_dir, ckpt_every=25, log_every=10))
    state, history = t.run(on_metrics=lambda s, m: print(
        f"step {s:5d} loss {m['loss']:.4f}", flush=True))
    print(f"done: loss {history[0]:.4f} -> {history[-1]:.4f}")


if __name__ == "__main__":
    main()
