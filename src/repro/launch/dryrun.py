import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # CPU-backend-only fix: XLA's while-loop invariant code motion hoists the
    # per-layer bf16->f32 convert of the remat'd residual stack into a whole
    # -stack f32 copy (verified absent at jaxpr level; TPU backend schedules
    # this differently).  Disabling keeps memory_analysis faithful.
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion,"
    "while-loop-expensive-invariant-code-motion")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first statements in this file: jax locks
the device count at first init, and the dry-run needs 512 placeholder
devices for the production meshes.  Nothing else in the repo sets this flag
(smoke tests and benches see 1 device).

Usage:
    python -m repro.launch.dryrun                      # full sweep, JSON cache
    python -m repro.launch.dryrun --arch llama3-405b --shape train_4k --mesh single_pod
    python -m repro.launch.dryrun --hlo-dir /tmp/hlo   # also dump HLO text

Per-cell results append to benchmarks/dryrun_results.json (idempotent:
already-recorded OK cells are skipped unless --force).
"""
import argparse
import gc
import json
import pathlib
import sys
import time
import traceback

import jax

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.launch.lowering import lower_cell, cell_report
from repro.launch.mesh import make_production_mesh

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "dryrun_results.json"


def load_results() -> dict:
    if RESULTS.exists():
        return json.loads(RESULTS.read_text())
    return {}


def save_results(res: dict) -> None:
    tmp = RESULTS.with_suffix(".tmp")
    tmp.write_text(json.dumps(res, indent=1, sort_keys=True))
    tmp.replace(RESULTS)


def cell_key(arch: str, shape: str, mesh_kind: str) -> str:
    return f"{arch}|{shape}|{mesh_kind}"


def iter_cells(mesh_kinds):
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for s in SHAPES.values():
            if s.name == "long_500k" and not cfg.supports_long_context():
                continue  # documented skip: quadratic attention at 512k
            for mk in mesh_kinds:
                yield arch, s.name, mk


def run_cell(arch: str, shape: str, mesh_kind: str, hlo_dir: str | None = None) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi_pod"))
    t0 = time.time()
    art = lower_cell(arch, shape, mesh)
    rep = cell_report(art)
    rep["compile_seconds"] = round(time.time() - t0, 1)
    if hlo_dir:
        p = pathlib.Path(hlo_dir)
        p.mkdir(parents=True, exist_ok=True)
        (p / f"{arch}__{shape}__{mesh_kind}.hlo.txt").write_text(
            art.compiled.as_text())
    del art
    gc.collect()
    return rep


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single_pod", "multi_pod"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--hlo-dir", default=None)
    args = ap.parse_args()

    assert len(jax.devices()) == 512, "dry-run expects 512 placeholder devices"
    mesh_kinds = [args.mesh] if args.mesh else ["single_pod", "multi_pod"]
    results = load_results()
    failures = 0
    for arch, shape, mk in iter_cells(mesh_kinds):
        if args.arch and arch != args.arch:
            continue
        if args.shape and shape != args.shape:
            continue
        key = cell_key(arch, shape, mk)
        if not args.force and results.get(key, {}).get("ok"):
            continue
        print(f"[dryrun] {key} ...", flush=True)
        try:
            rep = run_cell(arch, shape, mk, args.hlo_dir)
            print(f"[dryrun] {key} OK {rep['compile_seconds']}s "
                  f"peak={rep.get('memory', {}).get('peak_estimate_per_device', 0)/2**30:.2f} GiB",
                  flush=True)
        except Exception as e:
            failures += 1
            rep = {"arch": arch, "shape": shape, "mesh": mk, "ok": False,
                   "error": f"{type(e).__name__}: {e}"}
            print(f"[dryrun] {key} FAIL: {rep['error']}", flush=True)
            traceback.print_exc(limit=3)
        results[key] = rep
        save_results(results)
    print(f"[dryrun] done; {failures} failures; results -> {RESULTS}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
