"""Cell lowering: (arch x shape x mesh) -> lowered/compiled artifacts + analysis.

Shared by launch/dryrun.py (the deliverable), analysis/roofline.py and
benchmarks/.  Never sets XLA flags itself — the caller controls device count.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, SHAPES, ShapeSpec, get_config
from repro.distributed import sharding as shd
from repro.models import model as M
from repro.models import transformer
from repro.optim import AdamConfig, adam_init
from repro.runtime.steps import make_train_step


def rules_for(cfg: ArchConfig, shape: ShapeSpec, mesh) -> dict:
    return shd.make_rules(
        mesh_axes=tuple(mesh.axis_names), global_batch=shape.global_batch,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        decode=(shape.kind == "decode"), seq_len=shape.seq_len,
        family=cfg.family)


def batch_pspecs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """PartitionSpecs for the input batch (under an active rules context)."""
    sp = lambda *names: shd.logical_spec(*names)
    if shape.kind == "decode":
        return {"token": sp("batch", None), "pos": sp(), "cache": cache_pspecs(cfg)}
    specs = {"tokens": sp("batch", None)}
    if shape.kind == "train":
        specs["labels"] = sp("batch", None)
    if cfg.family == "audio":
        specs["frames"] = sp("batch", None, None)
    if cfg.family == "vlm":
        specs["vision"] = sp("batch", None, None)
    return specs


def cache_pspecs(cfg: ArchConfig):
    """Decode-cache PartitionSpecs (structure matches init_cache_shape)."""
    sp = shd.logical_spec
    fam = cfg.family
    kv_k = sp(None, "cache_batch", "cache_seq", None, None)
    if fam in ("dense", "moe", "vlm"):
        return {"k": kv_k, "v": kv_k}
    if fam == "ssm":
        return {"wkv": sp(None, "cache_batch", None, "cache_head_dim", None),
                "x_tm": sp(None, "cache_batch", None),
                "x_cm": sp(None, "cache_batch", None)}
    if fam == "hybrid":
        return {"k": kv_k, "v": kv_k,
                "mamba_conv": sp(None, None, "cache_batch", None, "ffn"),
                "mamba_ssm": sp(None, None, "cache_batch", "ffn", None)}
    if fam == "audio":
        # cross-attention cache has frames=1500 (not 16-divisible): hd-shard
        cross = sp(None, "cache_batch", None, None, "cache_head_dim")
        return {"k": kv_k, "v": kv_k, "cross_k": cross, "cross_v": cross}
    raise ValueError(fam)


def opt_pspecs(param_specs):
    from repro.optim.adam import AdamState
    return AdamState(step=P(), mu=param_specs, nu=param_specs)


@dataclasses.dataclass
class CellArtifacts:
    arch: str
    shape: str
    mesh_kind: str
    lowered: Any
    compiled: Any
    n_devices: int


def lower_cell(arch: str, shape_name: str, mesh, *, do_compile: bool = True,
               cfg_override: ArchConfig | None = None,
               int8_serving: bool = False) -> CellArtifacts:
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    model = M.build(cfg)
    rules = rules_for(cfg, shape, mesh)
    box = {}

    def _abstract_init():
        p, a = transformer.init_params(cfg, jax.random.key(0))
        box["axes"] = a          # static side-channel: axes are strings
        return p

    params_abs = jax.eval_shape(_abstract_init)
    axes = box["axes"]
    if int8_serving:
        # the paper's baked-quantized deployment: int8 weights + f32 scales
        # (serving shapes only; training keeps float master weights)
        from repro.core import ptq
        assert shape.kind in ("decode", "prefill"), "int8_serving is a serving mode"
        axes = ptq.quantize_axes(params_abs, axes)
        params_abs = ptq.abstract_quantize_tree(params_abs)

    with jax.set_mesh(mesh), shd.sharding_rules(rules):
        pspecs = shd.specs_from_axes(axes)
        bspecs = batch_pspecs(cfg, shape)
        inputs = M.input_specs(cfg, shape)

        if shape.kind == "train":
            ocfg = AdamConfig(moment_dtype=cfg.param_dtype)
            opt_abs = jax.eval_shape(lambda p: adam_init(p, ocfg), params_abs)
            step = make_train_step(model, ocfg)
            ospecs = opt_pspecs(pspecs)
            metric_specs = {"loss": P(), "grad_norm": P()}
            jitted = jax.jit(step,
                             in_shardings=(pspecs, ospecs, bspecs),
                             out_shardings=(pspecs, ospecs, metric_specs),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, inputs)
        elif shape.kind == "prefill":
            cspecs = cache_pspecs(cfg)
            jitted = jax.jit(model.prefill,
                             in_shardings=(pspecs, bspecs),
                             out_shardings=(shd.logical_spec("batch", "vocab"), cspecs))
            lowered = jitted.lower(params_abs, inputs)
        else:  # decode
            jitted = jax.jit(model.decode_step,
                             in_shardings=(pspecs, bspecs["cache"],
                                           bspecs["token"], bspecs["pos"]),
                             out_shardings=(shd.logical_spec("batch", "vocab"),
                                            bspecs["cache"]),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_abs, inputs["cache"], inputs["token"],
                                   inputs["pos"])
        compiled = lowered.compile() if do_compile else None
    return CellArtifacts(arch, shape_name, mesh_kind="multi_pod" if "pod" in mesh.axis_names
                         else "single_pod", lowered=lowered, compiled=compiled,
                         n_devices=mesh.devices.size)


def cell_report(art: CellArtifacts) -> dict:
    """JSON-serializable summary of one compiled cell."""
    out = {"arch": art.arch, "shape": art.shape, "mesh": art.mesh_kind,
           "devices": art.n_devices, "ok": art.compiled is not None}
    if art.compiled is None:
        return out
    ma = art.compiled.memory_analysis()
    if ma is not None:
        out["memory"] = {
            "argument_bytes_per_device": int(ma.argument_size_in_bytes),
            "output_bytes_per_device": int(ma.output_size_in_bytes),
            "temp_bytes_per_device": int(ma.temp_size_in_bytes),
            "alias_bytes_per_device": int(ma.alias_size_in_bytes),
            "peak_estimate_per_device": int(ma.argument_size_in_bytes
                                            + ma.output_size_in_bytes
                                            + ma.temp_size_in_bytes
                                            - ma.alias_size_in_bytes),
        }
    ca = art.compiled.cost_analysis()
    if ca:
        out["cost"] = {k: float(ca[k]) for k in ("flops", "bytes accessed")
                       if k in ca}
    return out
