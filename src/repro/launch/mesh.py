"""Production meshes.  A function, not a constant: importing this module
never touches jax device state."""
from __future__ import annotations

import jax


def _make_mesh(shape, axes, devices=None):
    """jax.make_mesh across jax versions: `axis_types` landed after 0.4.x;
    pass it where it exists (Auto on every axis, the behaviour the sharded
    paths assume), plain call where it doesn't."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    kwargs = {} if axis_type is None else {
        "axis_types": (axis_type.Auto,) * len(axes)}
    return jax.make_mesh(shape, axes, devices=devices, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) ("data","model") single pod = 256 chips;
    multi_pod -> (2,16,16) ("pod","data","model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = n // model_axis
    return _make_mesh((data, model_axis), ("data", "model"))


def make_serving_mesh(n_devices: int | None = None):
    """Pure data-parallel serving mesh: all (or the first `n_devices`)
    local devices on one "data" axis — the vision engine's batch DP mesh.
    Works degenerate on 1 CPU device and scales to a full host of chips."""
    devs = jax.devices()
    if n_devices is not None:
        devs = devs[:n_devices]
    return _make_mesh((len(devs),), ("data",), devices=devs)
