"""Production meshes.  A function, not a constant: importing this module
never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) ("data","model") single pod = 256 chips;
    multi_pod -> (2,16,16) ("pod","data","model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
