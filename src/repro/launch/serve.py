"""Serving launcher: continuous-batching engine over a selected arch.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
        --requests 16 --batch 4 [--int8]

`--int8` applies the paper's deployment flow (PTQ int8 baked weights) before
serving.  Fleet posture mirrors launch/train.py: per-host engines behind a
router, decode jits compiled against the production mesh (see
launch/lowering.py decode path and EXPERIMENTS.md §Perf cell 3).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--int8", action="store_true")
    args = ap.parse_args()

    from repro.configs.base import get_config
    from repro.core import ptq
    from repro.models import model as M
    from repro.serving.engine import Engine, Request

    cfg = get_config(args.arch).smoke()
    model = M.build(cfg)
    params, _ = model.init(jax.random.key(0))
    if args.int8:
        params = ptq.dequantize_tree(ptq.quantize_tree(params))
        print("serving int8-quantized weights (PTQ, per-channel)")
    eng = Engine(cfg, params, batch_size=args.batch, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(1, cfg.vocab, 6).astype(np.int32),
                    max_new_tokens=args.max_new) for i in range(args.requests)]
    t0 = time.perf_counter()
    done = eng.submit_and_run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    print(f"{len(done)} requests, {toks} tokens, {dt:.2f}s ({toks/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
