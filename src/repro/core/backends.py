"""Backend dispatch for smallNet — one network graph, swappable substrates.

The paper's whole point is one datapath (windowing -> parallel MAC -> bias ->
PLAN sigmoid -> maxpool) realized on different substrates: a Keras float
model on the PS-side CPU and a fixed-point Verilog pipeline in the fabric.
This module makes that explicit: the network graph lives once in
`smallnet.apply`, and a *backend* supplies the five layer primitives

    conv2x2_same(x, w, b)   pre-activation 2x2 SAME conv
    maxpool2x2(x)           2x2/2 max pool
    dense(x, w, b)          pre-activation fully-connected layer
    sigmoid(x)              the activation unit
    quantize_params(params) float pytree -> backend-native parameters

plus optional layout hooks (`ingest`, `flatten`, `fused_conv_act`) for
substrates whose tensor format differs from NHWC float (the fixed-point
path carries (B, H, W) int32 words, exactly the Verilog BRAM layout).

Registered backends (mirroring TinyCNN/ZynqNet-style swappable layer
engines over one fixed graph):

    ref          float32 XLA ops, exact sigmoid — the Keras counterpart
    plan         float32 XLA ops, PLAN piecewise-linear sigmoid
    pallas       Pallas TPU kernels (conv2d with fused-sigmoid epilogue,
                 maxpool2d comparator tree), exact sigmoid — matches `ref`
    pallas_plan  Pallas kernels with the fused conv+PLAN epilogue and the
                 sigmoid_pla VPU kernel — matches `plan`
    fixed        bit-faithful Qm.n two's-complement datapath (§III-B),
                 emulated with jnp int ops
    fixed_pallas the same Qm.n words through the FUSED kernels/fixed_conv
                 Pallas pipeline (windowing+limb-MAC+bias+PLAN+maxpool in
                 one launch) + the fixed_dense MAC launch — int32 bit-exact
                 with "fixed"
    int8         TPU-native PTQ: int8 dense MAC through the quant_matmul
                 MXU kernel, dequant-on-use convs, PLAN sigmoid

Usage:

    from repro.core import smallnet
    scores = smallnet.apply(params, images, backend="pallas")

`apply` accepts float params for every backend (they are quantized on the
way in, idempotently), or pre-quantized params produced by the backend's
own `quantize_params`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fxp
from repro.core import ptq
from repro.core import runtime
from repro.kernels.conv2d.ops import conv2d
from repro.kernels.fixed_conv.ops import (fixed_conv2d, fixed_maxpool2x2,
                                          fixed_sigmoid)
from repro.kernels.frame_trunk.ops import frame_trunk_quad
from repro.kernels.maxpool2d.ops import maxpool2d
from repro.kernels.quant_matmul.ops import fixed_dense, quant_matmul
from repro.kernels.sigmoid_pla.ops import sigmoid_pla

# the process-wide interpret/real-device switch, re-exported here because
# the backend registry is where callers already look for substrate knobs
set_interpret = runtime.set_interpret
interpret_default = runtime.interpret_default


# ---------------------------------------------------------------------------
# Shared float primitives (the XLA reference datapath)
# ---------------------------------------------------------------------------

def conv_same_2x2(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """2x2 SAME conv, NHWC/HWIO. Keras pads SAME for even kernels as
    (0 before, 1 after) on each spatial dim."""
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding=((0, 1), (0, 1)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def maxpool_2x2(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


# ---------------------------------------------------------------------------
# Fixed-point primitives (the Verilog datapath, emulated bit-exactly)
# ---------------------------------------------------------------------------

def windows_2x2_same(x: jnp.ndarray) -> jnp.ndarray:
    """The windowing module: (B,H,W) -> (B,H,W,4) of 2x2 patches with SAME
    (0 before, 1 after) zero padding. Mirrors the Verilog line-buffer."""
    xp = jnp.pad(x, ((0, 0), (0, 1), (0, 1)))
    return jnp.stack([xp[:, :-1, :-1], xp[:, :-1, 1:],
                      xp[:, 1:, :-1], xp[:, 1:, 1:]], axis=-1)


def conv_fixed(x: jnp.ndarray, w4: jnp.ndarray, b: jnp.ndarray,
               cfg: fxp.FixedPointConfig) -> jnp.ndarray:
    """Fixed-point conv: 4 parallel MACs per output pixel + bias add.
    x (B,H,W) int32 fixed; w4 (4,) int32 fixed; b () int32 fixed."""
    win = windows_2x2_same(x)                             # (B,H,W,4)
    prods = fxp.fixed_mul(win, w4.reshape(1, 1, 1, 4), cfg)
    acc = jnp.sum(prods, axis=-1, dtype=jnp.int32)        # MAC accumulate
    return fxp.fixed_add(acc, b, cfg)


def maxpool_fixed(x: jnp.ndarray) -> jnp.ndarray:
    """(B,H,W) int32 -> (B,H/2,W/2): comparator tree, exact in any format."""
    return jnp.maximum(jnp.maximum(x[:, ::2, ::2], x[:, ::2, 1::2]),
                       jnp.maximum(x[:, 1::2, ::2], x[:, 1::2, 1::2]))


# ---------------------------------------------------------------------------
# Backend base class + registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Backend:
    """Float32 XLA reference backend ("ref"); base class for all others.

    Subclasses override the five primitives; the layout hooks have sane
    float/NHWC defaults.  Instances are immutable so they can be closed
    over by jit without hashing surprises.
    """
    name: str = "ref"
    sigmoid_fn: Callable[[jnp.ndarray], jnp.ndarray] = jax.nn.sigmoid

    # -- the five primitives ------------------------------------------------
    def quantize_params(self, params):
        """Float param pytree -> backend-native params (identity here)."""
        return params

    def conv2x2_same(self, x, w, b):
        return conv_same_2x2(x, w, b)

    def maxpool2x2(self, x):
        return maxpool_2x2(x)

    def dense(self, x, w, b):
        return x @ w + b

    def sigmoid(self, x):
        return self.sigmoid_fn(x)

    # -- layout hooks -------------------------------------------------------
    def params_native(self, params) -> bool:
        """True if `params` are already in this backend's native format."""
        return True

    def prepare_params(self, params):
        """Idempotent: quantize float params, pass native params through."""
        return params if self.params_native(params) else self.quantize_params(params)

    def ingest(self, images):
        """(B,28,28,1) float images -> backend activation tensor."""
        return images

    def flatten(self, x):
        return x.reshape(x.shape[0], -1)

    def fused_conv_act(self, x, w, b):
        """conv + activation; backends with a fused epilogue override this."""
        return self.sigmoid(self.conv2x2_same(x, w, b))

    def accumulate(self, a, b):
        """Add two PRE-ACTIVATION conv partial sums in this backend's word
        domain.  The FCN frame sweep (streaming/fcn_sweep.py) decomposes a
        conv whose taps read from different feature maps into per-map
        masked-weight convs and sums them; for the default float domain
        that's plain `+`, while fixed-point backends override with
        `fixed_add` so the running sum re-enters the Qm.n word width after
        every step (wraparound addition is associative mod 2**bits, which is
        what makes the decomposition bit-exact)."""
        return a + b

    def mask_conv_weight(self, w, mask):
        """Zero out conv taps: w (2,2,1,1) backend-native, mask (2,2) of
        0/1.  Tap-masking is how the sweep reproduces a patch's SAME-padding
        zeros mid-frame (a zeroed tap contributes exactly 0 to the MAC in
        every word domain).  Backends whose weights aren't plain arrays
        (int8 QuantTensor) override."""
        return w * jnp.asarray(mask, w.dtype).reshape(2, 2, 1, 1)

    def fused_conv_act_pool(self, x, w, b):
        """conv + activation + 2x2 maxpool — the full paper pipeline stage.
        Default composes the two hooks; backends whose kernel fuses the pool
        into the same launch (fixed_pallas) override this."""
        return self.maxpool2x2(self.fused_conv_act(x, w, b))

    def frame_trunk(self, frames, p):
        """Whole-frame trunk fast path: (1, H, W, 1) float frames + native
        params -> the level-2 role-map quad (I, B, R, C), each (1, H/4,
        W/4) in the backend's layout — or None when this backend has no
        megakernel (or the geometry doesn't qualify), in which case callers
        fall back to the composed per-stage path.  The fixed substrates
        override this with the `kernels/frame_trunk` one-launch megakernel;
        `smallnet.conv_trunk` and `FcnSweep` route through it."""
        return None


_REGISTRY: dict[str, Backend] = {}


def register_backend(name: str, backend: Backend | None = None):
    """Register a backend instance under `name`.

    Usable directly — ``register_backend("ref", Backend())`` — or as a
    class decorator::

        @register_backend("mine")
        @dataclasses.dataclass(frozen=True)
        class MyBackend(Backend): ...
    """
    if backend is not None:
        _REGISTRY[name] = backend
        return backend

    def deco(cls):
        _REGISTRY[name] = cls() if isinstance(cls, type) else cls
        return cls
    return deco


def get_backend(backend: str | Backend) -> Backend:
    if isinstance(backend, Backend):
        return backend
    try:
        return _REGISTRY[backend]
    except KeyError:
        raise KeyError(
            f"unknown backend {backend!r}; registered: {list_backends()}") from None


def list_backends() -> list[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Float backends: ref / plan
# ---------------------------------------------------------------------------

register_backend("ref", Backend())
register_backend("plan", Backend(name="plan", sigmoid_fn=fxp.sigmoid_plan_f32))


# ---------------------------------------------------------------------------
# Pallas backends: the kernels/ wrappers wired into the model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PallasBackend(Backend):
    """Runs convs + pools through the Pallas TPU kernels.

    `activation` selects the fused conv epilogue: "sigmoid" (exact, matches
    "ref") or "plan" (the PLAN piecewise-linear epilogue, matches "plan");
    the standalone activation after the dense layer uses the matching
    implementation (sigmoid_pla VPU kernel for "plan").
    `interpret=None` follows the process-wide `core.runtime` switch
    (interpreter on CPU hosts by default; `runtime.set_interpret(False)` —
    or a benchmark's `--real-device` — compiles for real TPUs); an explicit
    bool pins this instance regardless of the switch.
    """
    name: str = "pallas"
    activation: str = "sigmoid"
    interpret: bool | None = None

    def conv2x2_same(self, x, w, b):
        return conv2d(x, w, b, padding="SAME",
                                interpret=self.interpret)

    def fused_conv_act(self, x, w, b):
        # the fused epilogue: bias + activation inside the conv kernel
        return conv2d(x, w, b, padding="SAME",
                                activation=self.activation,
                                interpret=self.interpret)

    def maxpool2x2(self, x):
        return maxpool2d(x, interpret=self.interpret)

    def sigmoid(self, x):
        if self.activation == "plan":
            return sigmoid_pla(x, interpret=self.interpret)
        return jax.nn.sigmoid(x)


register_backend("pallas", PallasBackend())
register_backend("pallas_plan", PallasBackend(name="pallas_plan",
                                              activation="plan"))


# ---------------------------------------------------------------------------
# Fixed-point backend: the paper's Verilog datapath
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FixedBackend(Backend):
    """Bit-faithful Qm.n two's-complement path (paper §III-B, Fig. 4).

    Activations are (B, H, W) int32 words (no channel dim — the fabric
    streams one feature map); images are quantized at the input port, and
    the returned class scores are fixed-point int32.
    """
    name: str = "fixed"
    cfg: fxp.FixedPointConfig = fxp.Q16_16

    def quantize_params(self, params):
        """The paper's §III-B weight extraction: float Keras weights ->
        two's-complement fixed point (int32 pytree)."""
        return jax.tree_util.tree_map(lambda p: fxp.to_fixed(p, self.cfg), params)

    def params_native(self, params) -> bool:
        leaves = jax.tree_util.tree_leaves(params)
        return bool(leaves) and all(
            jnp.issubdtype(l.dtype, jnp.integer) for l in leaves)

    def ingest(self, images):
        # the paper streams 8-bit pixels via DMA; quantize at the port
        return fxp.to_fixed(images[..., 0], self.cfg)     # (B,28,28)

    def conv2x2_same(self, x, w, b):
        # w (2,2,1,1) int32 -> the 4 MAC taps; b (1,) -> scalar bias word
        return conv_fixed(x, w.reshape(4), b[0], self.cfg)

    def maxpool2x2(self, x):
        return maxpool_fixed(x)

    def dense(self, x, w, b):
        y = fxp.fixed_matmul(x, w, self.cfg)
        return fxp.fixed_add(y, b.reshape(1, -1), self.cfg)

    def sigmoid(self, x):
        return fxp.fixed_sigmoid_plan(x, self.cfg)

    def accumulate(self, a, b):
        # wraparound fixed add is associative mod 2**total_bits, so partial
        # conv sums recombine to exactly the single-conv accumulator word
        # (saturate mode is NOT associative; the sweep rejects it up front)
        return fxp.fixed_add(a, b, self.cfg)

    def frame_trunk(self, frames, p):
        # ONE Pallas launch for the whole trunk + quad role maps (the
        # kernels/frame_trunk megakernel) — inherited by fixed_pallas, so
        # both fixed substrates share the identical launch.  Word-exact
        # with the composed path; geometry that can't tile (batch > 1,
        # non-multiple-of-4 extents, saturating cfg) falls back by
        # returning None.
        B_, H, W = frames.shape[0], frames.shape[1], frames.shape[2]
        if B_ != 1 or H % 4 or W % 4 or H < 4 or W < 4 or self.cfg.saturate:
            return None
        x = self.ingest(frames)                      # (1, H, W) int32 words
        quad = frame_trunk_quad(
            x[0], p["conv1"]["w"], p["conv1"]["b"],
            p["conv2"]["w"], p["conv2"]["b"], cfg=self.cfg,
            interpret=getattr(self, "interpret", None))
        # the barrier pins the (4, H/4, W/4) kernel output before it is
        # split into per-role maps: without it, inlining this call into a
        # larger traced program lets XLA fuse the slices into the
        # interpret-mode pallas emulation, which corrupts the corner map's
        # lane-remainder columns (last W/4 % 8 output cols) whenever the
        # kernel operands are intermediates rather than program parameters
        quad = jax.lax.optimization_barrier(quad)
        return tuple(quad[k][None] for k in range(4))


register_backend("fixed", FixedBackend())


@dataclasses.dataclass(frozen=True)
class FixedPallasBackend(FixedBackend):
    """The bit-faithful Qm.n datapath as FUSED Pallas launches.

    Same arithmetic contract as "fixed" (it reuses `FixedBackend
    .quantize_params` and the `fixed_point` word semantics), but each
    pipeline stage is one kernel launch from kernels/fixed_conv — and the
    conv+PLAN+maxpool stage is a SINGLE launch via `fused_conv_act_pool`,
    the TPU analogue of the paper's fully fused fabric pipeline.  Output
    words are int32-identical to the emulated "fixed" backend (asserted by
    the golden-vector and hypothesis batteries in tests/).  `interpret=None`
    follows the process-wide `core.runtime` switch.
    """
    name: str = "fixed_pallas"
    interpret: bool | None = None

    def _w4(self, w):
        # (2,2,1,1) int32 weight -> the 4 MAC taps, row-major like the
        # emulated path's `w.reshape(4)`
        return w.reshape(4)

    def conv2x2_same(self, x, w, b):
        return fixed_conv2d(x, self._w4(w), b, cfg=self.cfg,
                            interpret=self.interpret)

    def fused_conv_act(self, x, w, b):
        return fixed_conv2d(x, self._w4(w), b, cfg=self.cfg,
                            activation="plan", interpret=self.interpret)

    def fused_conv_act_pool(self, x, w, b):
        # windowing -> limb MAC -> bias -> PLAN -> maxpool, one launch
        return fixed_conv2d(x, self._w4(w), b, cfg=self.cfg,
                            activation="plan", pool=True,
                            interpret=self.interpret)

    def maxpool2x2(self, x):
        return fixed_maxpool2x2(x, interpret=self.interpret)

    def dense(self, x, w, b):
        return fixed_dense(x, w, b, cfg=self.cfg, interpret=self.interpret)

    def sigmoid(self, x):
        return fixed_sigmoid(x, cfg=self.cfg, interpret=self.interpret)


register_backend("fixed_pallas", FixedPallasBackend())


# ---------------------------------------------------------------------------
# int8 backend: TPU-native PTQ with the quant_matmul MXU kernel
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Int8Backend(Backend):
    """int8 weights: dequant-on-use for the (tiny) convs, true int8 MAC for
    the dense layer through the kernels/quant_matmul Pallas wrapper —
    activations are quantized per-tensor on the fly, weights carry
    per-channel scales, accumulation is exact int32 with a fused dequant
    epilogue (the MXU analogue of the paper's DSP MAC array).
    `interpret=None` follows the process-wide `core.runtime` switch."""
    name: str = "int8"
    qcfg: ptq.QuantConfig = ptq.QuantConfig()
    interpret: bool | None = None

    def quantize_params(self, params):
        return ptq.quantize_tree(params, self.qcfg)

    def params_native(self, params) -> bool:
        return any(isinstance(l, ptq.QuantTensor)
                   for l in jax.tree_util.tree_leaves(
                       params, is_leaf=lambda x: isinstance(x, ptq.QuantTensor)))

    def conv2x2_same(self, x, w, b):
        w = w.dequantize() if isinstance(w, ptq.QuantTensor) else w
        return conv_same_2x2(x, w, b)

    def mask_conv_weight(self, w, mask):
        # conv weights are dequant-on-use anyway, so mask the float view
        # (conv2x2_same passes plain arrays straight through)
        w = w.dequantize() if isinstance(w, ptq.QuantTensor) else w
        return w * jnp.asarray(mask, w.dtype).reshape(2, 2, 1, 1)

    def dense(self, x, w, b):
        if not isinstance(w, ptq.QuantTensor):           # float fallback
            return x @ w + b
        xq = ptq.quantize(x, dataclasses.replace(self.qcfg, per_channel=False))
        y = quant_matmul(xq.q, w.q, xq.scale.reshape(()),
                            w.scale.reshape(-1), interpret=self.interpret)
        return y + b

    def sigmoid(self, x):
        return fxp.sigmoid_plan_f32(x)


register_backend("int8", Int8Backend())
