"""Qm.n two's-complement fixed-point arithmetic, emulated bit-exactly in JAX.

This is the paper's numerical substrate: smallNet stores weights/activations
as 32-bit two's-complement fixed point ("aligning with the native word size
of the Zynq architecture").  We emulate the same semantics on TPU/CPU:

  * storage: int32, value = stored / 2**frac_bits
  * multiply: full 32x32 -> 64-bit product computed via 16-bit limb
    decomposition (JAX's default int is 32-bit; x64 is never enabled), then
    an arithmetic right shift by frac_bits.  Overflow wraps (two's
    complement), exactly like the FPGA datapath; optional saturation mode
    mirrors DSP-slice saturating accumulators.
  * add/sub: native int32, which wraps in XLA (defined two's-complement).

The emulation is *bit-exact* for wraparound mode: every intermediate fits the
documented limb ranges (proved in tests against a numpy int64 oracle).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FixedPointConfig:
    """Qm.n format: total_bits = 1 + m + n (sign + integer + fraction)."""
    total_bits: int = 32
    frac_bits: int = 16
    saturate: bool = False          # False = wraparound (paper's 2's complement)
    round_nearest: bool = True      # False = truncate (pure >> shift)

    @property
    def int_bits(self) -> int:
        return self.total_bits - 1 - self.frac_bits

    @property
    def scale(self) -> float:
        return float(2 ** self.frac_bits)

    @property
    def max_int(self) -> int:
        return 2 ** (self.total_bits - 1) - 1

    @property
    def min_int(self) -> int:
        return -(2 ** (self.total_bits - 1))


Q16_16 = FixedPointConfig(32, 16)
Q8_8 = FixedPointConfig(16, 8)

# The canonical format x mode matrix for the bit-exactness contract: every
# battery (golden-vector generator, kernel parity tests, hypothesis props)
# sweeps THIS dict, so adding a new format/mode here propagates everywhere.
STANDARD_CONFIGS = {
    "q16_16": Q16_16,
    "q16_16_sat": FixedPointConfig(32, 16, saturate=True),
    "q16_16_trunc": FixedPointConfig(32, 16, round_nearest=False),
    "q8_8": Q8_8,
    "q8_8_sat": FixedPointConfig(16, 8, saturate=True),
}


def _wrap_to_bits(x: jnp.ndarray, total_bits: int) -> jnp.ndarray:
    """Truncate an int32 value to `total_bits` with sign extension (2's comp)."""
    if total_bits == 32:
        return x
    shift = 32 - total_bits
    return (x << shift) >> shift  # arithmetic shift sign-extends


def to_fixed(x: jnp.ndarray, cfg: FixedPointConfig = Q16_16) -> jnp.ndarray:
    """Float -> fixed. Out-of-range reals always saturate (ADC-style)."""
    scaled = jnp.round(jnp.asarray(x, jnp.float32) * cfg.scale)
    scaled = jnp.clip(scaled, float(cfg.min_int), float(cfg.max_int))
    return _wrap_to_bits(scaled.astype(jnp.int32), cfg.total_bits)


def from_fixed(x: jnp.ndarray, cfg: FixedPointConfig = Q16_16) -> jnp.ndarray:
    return x.astype(jnp.float32) / cfg.scale


def fixed_add(a: jnp.ndarray, b: jnp.ndarray, cfg: FixedPointConfig = Q16_16) -> jnp.ndarray:
    s = a + b  # int32 wraps (two's complement) in XLA
    if cfg.saturate:
        # overflow iff operands share sign and result sign differs
        ovf = (jnp.sign(a) == jnp.sign(b)) & (jnp.sign(s) != jnp.sign(a)) & (a != 0)
        sat = jnp.where(a > 0, cfg.max_int, cfg.min_int).astype(jnp.int32)
        s = jnp.where(ovf, sat, s)
    return _wrap_to_bits(s, cfg.total_bits)


def _full_mul_shift(a: jnp.ndarray, b: jnp.ndarray, shift: int,
                    round_nearest: bool) -> jnp.ndarray:
    """(a * b) >> shift on int32 inputs, exact, via 16-bit limb decomposition.

    a*b = ah*bh*2^32 + (ah*bl + al*bh)*2^16 + al*bl, with
      al, bl in [0, 2^16)  (unsigned low limbs)
      ah, bh in [-2^15, 2^15)  (signed high limbs)
    All partial products fit comfortably in (u)int32:
      |ah*bl| <= 2^15 * (2^16-1) < 2^31,  al*bl < 2^32 (held in uint32).
    The result is reduced mod 2^32 (wraparound), matching hardware.
    Only shift == 16 is needed for Qx.16; generic shifts split into
    (>>16 via limbs) then a final arithmetic shift.
    """
    assert 0 <= shift <= 31
    au = jax.lax.bitcast_convert_type(a, jnp.uint32)
    bu = jax.lax.bitcast_convert_type(b, jnp.uint32)
    al = au & jnp.uint32(0xFFFF)
    bl = bu & jnp.uint32(0xFFFF)
    ah = a >> 16  # arithmetic: signed high limb
    bh = b >> 16
    lo = al * bl                                    # uint32, exact
    # cross terms: signed, fit in int32
    cross = ah * jax.lax.bitcast_convert_type(bl, jnp.int32) \
        + jax.lax.bitcast_convert_type(al, jnp.int32) * bh
    # (a*b) >> 16, mod 2^32:
    hi16 = jax.lax.bitcast_convert_type(lo >> 16, jnp.int32)
    p16 = hi16 + cross + ((ah * bh) << 16)          # wraps mod 2^32 as intended
    if shift == 16 and not round_nearest:
        return p16
    if round_nearest:
        # rounding bit = bit (shift-1) of the full product
        if shift >= 17:
            rbit = (p16 >> (shift - 17)) & 1
            return (p16 >> (shift - 16)) + rbit
        elif shift == 16:
            rbit = jax.lax.bitcast_convert_type((lo >> 15) & jnp.uint32(1), jnp.int32)
            return p16 + rbit
        else:  # shift < 16: recompute from limbs with smaller shift
            # full product low 32 bits, mod 2^32
            p0 = jax.lax.bitcast_convert_type(
                lo + (jax.lax.bitcast_convert_type(cross, jnp.uint32) << 16), jnp.int32)
            if shift == 0:
                return p0
            ubits = jax.lax.bitcast_convert_type(p0, jnp.uint32) >> shift
            top = p16 << (16 - shift)               # bits from >>16 result
            val = jax.lax.bitcast_convert_type(ubits, jnp.int32) | top
            rbit = (p0 >> (shift - 1)) & 1
            return val + rbit
    else:
        if shift > 16:
            return p16 >> (shift - 16)
        # shift < 16
        p0 = jax.lax.bitcast_convert_type(
            lo + (jax.lax.bitcast_convert_type(cross, jnp.uint32) << 16), jnp.int32)
        if shift == 0:
            return p0
        ubits = jax.lax.bitcast_convert_type(p0, jnp.uint32) >> shift
        top = p16 << (16 - shift)
        return jax.lax.bitcast_convert_type(ubits, jnp.int32) | top


def fixed_mul(a: jnp.ndarray, b: jnp.ndarray, cfg: FixedPointConfig = Q16_16) -> jnp.ndarray:
    p = _full_mul_shift(a, b, cfg.frac_bits, cfg.round_nearest)
    if cfg.saturate:
        # f32 magnitude heuristic for the saturation decision (documented:
        # exact wraparound is the default hardware-faithful mode).
        approx = a.astype(jnp.float32) * b.astype(jnp.float32) / cfg.scale
        p = jnp.where(approx > cfg.max_int, cfg.max_int,
                      jnp.where(approx < cfg.min_int, cfg.min_int, p)).astype(jnp.int32)
    return _wrap_to_bits(p, cfg.total_bits)


def fixed_matmul(x: jnp.ndarray, w: jnp.ndarray, cfg: FixedPointConfig = Q16_16) -> jnp.ndarray:
    """Fixed-point (B, K) @ (K, N): per-element fixed mul, int32 accumulate.

    Mirrors the paper's MAC array: each product is shifted back to Qm.n then
    accumulated in the same word width (wraparound on overflow).
    """
    prods = fixed_mul(x[:, :, None], w[None, :, :], cfg)   # (B, K, N)
    return _wrap_to_bits(jnp.sum(prods, axis=1, dtype=jnp.int32), cfg.total_bits)


def shift_right_round(x: jnp.ndarray, k: int, round_nearest: bool) -> jnp.ndarray:
    """Arithmetic right shift with the config's rounding rule.

    The single definition of ">> with rounding" shared by the emulated path
    and the Pallas fixed kernels: truncate mode is the pure hardware shifter
    (`x >> k`); round-nearest adds bit (k-1) of x, exactly the rule
    `fixed_mul` applies to its full product.  Keeping one helper guarantees
    both substrates use the same shift semantics (this was a latent
    divergence: the PLAN sigmoid used to truncate unconditionally while
    `fixed_mul` honoured `round_nearest`).
    """
    if k == 0 or not round_nearest:
        return x >> k
    return (x >> k) + ((x >> (k - 1)) & 1)


def fixed_sigmoid_plan(x: jnp.ndarray, cfg: FixedPointConfig = Q16_16) -> jnp.ndarray:
    """PLAN (piecewise-linear approximation) sigmoid in fixed point.

    The standard hardware sigmoid (Amin, Curtis & Hayes-Gill 1997), computable
    with shifts and adds only:
        |x| >= 5          -> 1
        2.375 <= |x| < 5  -> 0.03125*|x| + 0.84375
        1 <= |x| < 2.375  -> 0.125 *|x| + 0.625
        0 <= |x| < 1      -> 0.25  *|x| + 0.5
    and sigmoid(-x) = 1 - sigmoid(x).

    The power-of-two slope multiplies are realized by `shift_right_round`,
    so they follow `cfg.round_nearest` just like `fixed_mul` (truncate mode
    is the pure shifter the PLAN hardware uses).
    """
    ax = jnp.abs(x)
    c5 = to_fixed(5.0, cfg)
    c2375 = to_fixed(2.375, cfg)
    c1 = to_fixed(1.0, cfg)
    rn = cfg.round_nearest
    y = jnp.where(
        ax >= c5, to_fixed(1.0, cfg) if cfg.int_bits >= 1 else cfg.max_int,
        jnp.where(
            ax >= c2375, shift_right_round(ax, 5, rn) + to_fixed(0.84375, cfg),
            jnp.where(ax >= c1, shift_right_round(ax, 3, rn) + to_fixed(0.625, cfg),
                      shift_right_round(ax, 2, rn) + to_fixed(0.5, cfg))))
    one = to_fixed(1.0, cfg) if cfg.int_bits >= 1 else cfg.max_int
    return jnp.where(x < 0, one - y, y).astype(jnp.int32)


def sigmoid_plan_f32(x: jnp.ndarray) -> jnp.ndarray:
    """Float reference of the PLAN sigmoid (same breakpoints)."""
    ax = jnp.abs(x)
    y = jnp.where(ax >= 5.0, 1.0,
                  jnp.where(ax >= 2.375, 0.03125 * ax + 0.84375,
                            jnp.where(ax >= 1.0, 0.125 * ax + 0.625,
                                      0.25 * ax + 0.5)))
    return jnp.where(x < 0, 1.0 - y, y)
