"""The paper's end-to-end flow: train float -> extract -> quantize -> bake -> serve.

`bake()` mirrors 'weights ... hardcoded into the hardware': parameters are
closed over as Python constants so XLA constant-folds them into the compiled
program (the TPU analogue of baking into fabric — no weight arguments at all
in the executable's signature).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import smallnet
from repro.data import synth_mnist
from repro.optim import AdamConfig, adam_init, adam_update


@dataclasses.dataclass
class TrainResult:
    params: dict
    history: list
    train_acc: float
    test_acc: float


def train_smallnet(n_train: int = 8000, n_test: int = 2000, epochs: int = 8,
                   batch_size: int = 64, lr: float = 2e-2, seed: int = 0) -> TrainResult:
    """Paper §III-A: Adam, batch 64, 8 epochs.

    lr 2e-2 (not Keras' 1e-3 default): the 510-parameter net's features move
    glacially at small steps (see smallnet.loss_fn); 2e-2 trains to >= 0.80
    on the MNIST proxy across seeds where 5e-3 sat at chance for epochs."""
    xtr, ytr = synth_mnist.make_dataset(n_train, seed=seed)
    xte, yte = synth_mnist.make_dataset(n_test, seed=seed + 1)
    params = smallnet.init_params(jax.random.key(seed))
    cfg = AdamConfig(lr=lr, clip_norm=None)
    state = adam_init(params, cfg)

    @jax.jit
    def step(params, state, xb, yb):
        loss, grads = jax.value_and_grad(smallnet.loss_fn)(params, xb, yb)
        params, state, _ = adam_update(grads, state, params, cfg)
        return params, state, loss

    history = []
    for xb, yb in synth_mnist.batches(xtr, ytr, batch_size, seed=seed, epochs=epochs):
        params, state, loss = step(params, state, jnp.asarray(xb), jnp.asarray(yb))
        history.append(float(loss))
    fwd = jax.jit(smallnet.forward)
    train_acc = smallnet.accuracy(fwd, params, jnp.asarray(xtr), jnp.asarray(ytr))
    test_acc = smallnet.accuracy(fwd, params, jnp.asarray(xte), jnp.asarray(yte))
    return TrainResult(params, history, train_acc, test_acc)


def bake(apply_fn: Callable, params: Any) -> Callable:
    """Bake weights as compile-time constants (paper: hardcoded into fabric)."""
    return jax.jit(lambda x: apply_fn(params, x))


def evaluate_all_paths(params: dict, n_test: int = 2000, seed: int = 1) -> dict:
    """The paper's accuracy table: float (CPU) vs PLAN-sigmoid vs fixed-point
    'post-synthesis simulation' vs int8, on the same test set."""
    xte, yte = synth_mnist.make_dataset(n_test, seed=seed)
    xte = jnp.asarray(xte); yte = jnp.asarray(yte)
    qfix = smallnet.quantize_params_fixed(params)
    qint8 = smallnet.quantize_params_int8(params)
    paths = {
        "float32": jax.jit(smallnet.forward),
        "float32_plan_sigmoid": jax.jit(smallnet.forward_plan),
        "fixed_q16_16": jax.jit(lambda q, x: smallnet.forward_fixed(q, x)),
        "int8_ptq": jax.jit(smallnet.forward_int8),
    }
    out = {}
    for name, fn in paths.items():
        p = {"float32": params, "float32_plan_sigmoid": params,
             "fixed_q16_16": qfix, "int8_ptq": qint8}[name]
        out[name] = smallnet.accuracy(fn, p, xte, yte)
    return out


def measure_latency(apply_fn: Callable, params: Any, batch: int = 1,
                    iters: int = 50) -> float:
    """Wall-clock per-inference latency (seconds) on this host (the paper's
    'software inference time' analogue; its HW number maps to the TPU
    roofline estimate in benchmarks/latency_table.py)."""
    x = jnp.zeros((batch, 28, 28, 1), jnp.float32)
    fn = jax.jit(lambda p, x: apply_fn(p, x))
    fn(params, x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(params, x).block_until_ready()
    return (time.perf_counter() - t0) / iters
