"""smallNet — the paper's model over swappable inference backends.

Architecture (paper §III-A, Fig. 2):
    conv 1 filter 2x2, stride 1, SAME, sigmoid
    maxpool 2x2
    conv 1 filter 2x2, SAME, sigmoid
    maxpool 2x2
    flatten (7*7 = 49)
    dense 10, sigmoid
    Max Finder (argmax)
Parameter count: (2*2*1*1 + 1) * 2 + 49*10 + 10 = 510 — matches the paper's
"no more than 510 trainable parameters".

The network graph lives ONCE in `apply(params, images, backend=...)`; a
backend (core/backends.py) supplies the layer primitives.  Registered
backends: "ref" (float32, the Keras counterpart), "plan" (float32 + PLAN
hardware sigmoid), "pallas" / "pallas_plan" (the Pallas TPU kernels with
fused conv epilogues), "fixed" (bit-faithful Qm.n two's-complement — exactly
the paper's Verilog datapath, §III-B Fig. 4), "fixed_pallas" (the same Qm.n
words as ONE fused Pallas launch per pipeline stage, int32 bit-exact with
"fixed"), "int8" (TPU-native PTQ with the quant_matmul MXU kernel).

`forward` / `forward_plan` / `forward_fixed` / `forward_int8` remain as thin
wrappers over `apply` for existing callers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import backends as B
from repro.core import fixed_point as fxp
from repro.core import ptq
from repro.distributed import sharding as shd


def init_params(key: jax.Array) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    glorot = jax.nn.initializers.glorot_uniform()
    return {
        "conv1": {"w": glorot(k1, (2, 2, 1, 1), jnp.float32), "b": jnp.zeros((1,), jnp.float32)},
        "conv2": {"w": glorot(k2, (2, 2, 1, 1), jnp.float32), "b": jnp.zeros((1,), jnp.float32)},
        "dense": {"w": glorot(k3, (49, 10), jnp.float32), "b": jnp.zeros((10,), jnp.float32)},
    }


def param_count(params: dict) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))


def seeded_params(seed: int = 0, noise: float = 0.1) -> dict:
    """Deterministic params with every leaf nonzero (`init_params` zeroes
    the biases, which would flatten any confidence landscape): the
    no-training stand-in shared by the streaming benchmarks, the golden
    generators, and the frozen-clip test batteries — ONE definition, so a
    recipe tweak cannot silently desynchronize what those gates pin."""
    params = init_params(jax.random.key(seed))
    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(jax.random.key(seed + 1), len(leaves))
    return jax.tree_util.tree_unflatten(treedef, [
        l + noise * jax.random.normal(k, l.shape, l.dtype)
        for l, k in zip(leaves, keys)])


def _constrain_batch(x: jnp.ndarray) -> jnp.ndarray:
    """Pin dim 0 to the "batch" logical axis, replicate the rest.

    Activations change rank across backends ((B,H,W,C) float NHWC,
    (B,H,W) fixed-point words, (B,F) after flatten), so the spec is built
    from the rank.  Outside a `sharding_rules` context this is a no-op —
    the unsharded single-device path is byte-identical to before.
    """
    return shd.constrain(x, "batch", *(None,) * (x.ndim - 1))


def _conv_stages(be: B.Backend, p: dict, images: jnp.ndarray) -> jnp.ndarray:
    """Ingest + both conv->act->pool stages: images -> pooled feature maps
    ((B,7,7,1) float NHWC or (B,7,7) fixed words for 28x28 inputs; any
    spatial extent divides through as H/4 x W/4)."""
    x = _constrain_batch(be.ingest(images))
    # conv+act+pool goes through one hook so backends with a fully fused
    # stage (fixed_pallas: windowing+MAC+bias+PLAN+maxpool in ONE Pallas
    # launch) keep the paper's pipeline structure; the default composes
    # fused_conv_act and maxpool2x2 exactly as before.
    x = _constrain_batch(be.fused_conv_act_pool(x, p["conv1"]["w"], p["conv1"]["b"]))
    x = _constrain_batch(be.fused_conv_act_pool(x, p["conv2"]["w"], p["conv2"]["b"]))
    return x


def _dense_preact(be: B.Backend, p: dict, feats: jnp.ndarray) -> jnp.ndarray:
    """Pooled feature maps -> PRE-activation class scores (B, 10)."""
    x = be.flatten(feats)                                # (B, 49)
    return be.dense(x, p["dense"]["w"], p["dense"]["b"])


def _trunk(be: B.Backend, p: dict, images: jnp.ndarray) -> jnp.ndarray:
    """The network up to (and including) the dense layer, PRE-activation —
    the single definition of the paper's pipeline that `apply` (deployed,
    + output sigmoid) and `forward_logits` (training view) both run."""
    return _dense_preact(be, p, _conv_stages(be, p, images))


def conv_trunk(params: dict, images: jnp.ndarray, *,
               backend: str | B.Backend = "ref") -> jnp.ndarray:
    """The conv half of the pipeline as a separately callable stage:
    images (B,H,W,1) -> pooled feature maps (B,H/4,W/4[,1] by layout).

    This is the device-resident part of the paper's fabric (windowing ->
    MAC -> bias -> PLAN -> pool, twice); `dense_head` is the 49->10
    classifier that follows.  `apply(params, x) ==
    dense_head(params, conv_trunk(params, x))` on every backend — the
    FCN frame sweep (streaming/fcn_sweep.py) leans on this split to run
    the trunk ONCE per frame and re-use the feature map for every window.

    Single-frame calls on backends with a whole-frame megakernel (the
    fixed substrates' `frame_trunk` hook, kernels/frame_trunk) take the
    one-launch fast path; its interior map is word-identical to the
    composed stages, so the hook changes launches, not values.
    """
    be = B.get_backend(backend)
    p = be.prepare_params(params)
    x = jnp.asarray(images)
    if x.ndim == 4 and x.shape[0] == 1:
        quad = be.frame_trunk(x, p)
        if quad is not None:
            return quad[0]                     # interior == the plain trunk
    return _conv_stages(be, p, images)


def dense_head(params: dict, feats: jnp.ndarray, *,
               backend: str | B.Backend = "ref") -> jnp.ndarray:
    """The 49->10 dense classifier + output sigmoid over pooled feature
    maps ((B,7,7[,1]) backend layout, or already-flat (B,49))."""
    be = B.get_backend(backend)
    p = be.prepare_params(params)
    return _constrain_batch(be.sigmoid(_dense_preact(be, p, feats)))


def apply(params: dict, images: jnp.ndarray, *,
          backend: str | B.Backend = "ref") -> jnp.ndarray:
    """Single entry point: images (B,28,28,1) -> class scores (B,10).

    `params` may be float (quantizing backends convert them on the way in,
    idempotently) or already backend-native (e.g. the int32 pytree from
    `quantize_params_fixed`).  Scores are float in (0,1) for float-valued
    backends and Qm.n int32 words for "fixed" — `predict` handles both.

    Under `distributed.sharding.sharding_rules` (e.g. the vision-serving
    preset `make_vision_rules(mesh)`), every activation is constrained to
    shard its batch dim across the mesh — per-example compute is
    independent, so GSPMD splits the whole pipeline with zero collectives.
    """
    be = B.get_backend(backend)
    p = be.prepare_params(params)
    return _constrain_batch(be.sigmoid(_trunk(be, p, images)))


# ---------------------------------------------------------------------------
# Thin wrappers (the historical per-path entry points)
# ---------------------------------------------------------------------------

def forward(params: dict, images: jnp.ndarray, *, sigmoid=jax.nn.sigmoid) -> jnp.ndarray:
    """images (B,28,28,1) -> class scores (B,10). Float32 reference path."""
    if sigmoid is jax.nn.sigmoid:
        return apply(params, images, backend="ref")
    if sigmoid is fxp.sigmoid_plan_f32:
        return apply(params, images, backend="plan")
    return apply(params, images, backend=B.Backend(name="custom", sigmoid_fn=sigmoid))


def forward_plan(params: dict, images: jnp.ndarray) -> jnp.ndarray:
    return apply(params, images, backend="plan")


def forward_fixed(qparams: dict, images: jnp.ndarray,
                  cfg: fxp.FixedPointConfig = fxp.Q16_16) -> jnp.ndarray:
    """Bit-faithful fixed-point inference. images float in [0,1] are
    quantized at the input port (the paper streams 8-bit pixels via DMA);
    returns fixed-point class scores (B,10) int32."""
    be = B.get_backend("fixed") if cfg == fxp.Q16_16 else B.FixedBackend(cfg=cfg)
    return apply(qparams, images, backend=be)


def forward_int8(qparams: dict, images: jnp.ndarray) -> jnp.ndarray:
    """int8 weights (dequant-on-use for conv; int8 MAC dense through the
    quant_matmul Pallas kernel)."""
    return apply(qparams, images, backend="int8")


def quantize_params_fixed(params: dict, cfg: fxp.FixedPointConfig = fxp.Q16_16) -> dict:
    """The paper's §III-B weight extraction: float Keras weights ->
    two's-complement fixed point, 'hardcoded' (returned as int32 pytree)."""
    return B.FixedBackend(cfg=cfg).quantize_params(params)


def quantize_params_int8(params: dict, cfg: ptq.QuantConfig = ptq.QuantConfig()) -> dict:
    return ptq.quantize_tree(params, cfg)


# ---------------------------------------------------------------------------
# Prediction / training objective
# ---------------------------------------------------------------------------

def predict(scores: jnp.ndarray) -> jnp.ndarray:
    """The paper's 'Max Finder' module (argmax is monotone, so it works on
    float scores and fixed-point int32 words alike)."""
    return jnp.argmax(scores, axis=-1)


def forward_logits(params: dict, images: jnp.ndarray) -> jnp.ndarray:
    """Pre-sigmoid class scores (B,10) on the float reference path.

    sigmoid is monotone, so argmax over these logits equals the deployed
    network's Max Finder over sigmoid scores — this is the training-side
    view of the SAME network (`_trunk` is shared with `apply`), not a
    different one."""
    be = B.get_backend("ref")
    return _trunk(be, be.prepare_params(params), images)


def loss_fn(params: dict, images: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Categorical crossentropy (paper §III-A) over the class scores.

    Training-only adaptation (documented in DESIGN.md): CCE through the
    output sigmoid has vanishing, seed-fragile gradients at this tiny width
    (two cascaded single-filter sigmoid convs start with near-constant
    features, and the earlier temperature-sharpened-scores variant stayed at
    chance for whole epochs on some seeds).  We apply CCE to the PRE-sigmoid
    logits instead: log_softmax is shift-invariant and sigmoid is monotone,
    so the *deployed* network (sigmoid + Max Finder argmax) is bit-identical
    to the paper's — only the training signal changes.
    """
    logp = jax.nn.log_softmax(forward_logits(params, images))
    onehot = jax.nn.one_hot(labels, 10)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def accuracy(apply_fn, params, images, labels, batch: int = 256) -> float:
    hits, n = 0, 0
    for s in range(0, images.shape[0], batch):
        scores = apply_fn(params, images[s:s + batch])
        hits += int(jnp.sum(predict(scores) == labels[s:s + batch]))
        n += int(labels[s:s + batch].shape[0])
    return hits / max(n, 1)
