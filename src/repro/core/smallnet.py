"""smallNet — the paper's model, with float / fixed-point / int8 inference paths.

Architecture (paper §III-A, Fig. 2):
    conv 1 filter 2x2, stride 1, SAME, sigmoid
    maxpool 2x2
    conv 1 filter 2x2, SAME, sigmoid
    maxpool 2x2
    flatten (7*7 = 49)
    dense 10, sigmoid
    Max Finder (argmax)
Parameter count: (2*2*1*1 + 1) * 2 + 49*10 + 10 = 510 — matches the paper's
"no more than 510 trainable parameters".

Paths:
  * forward()        — float32 reference (the paper's Keras counterpart)
  * forward_plan()   — float32 but with the PLAN hardware sigmoid (isolates
                       the activation-approximation part of the accuracy gap)
  * forward_fixed()  — bit-faithful Qm.n two's-complement path: explicit
                       windowing + MAC accumulate, PLAN sigmoid, exactly the
                       paper's Verilog datapath (§III-B, Fig. 4)
  * forward_int8()   — TPU-native int8 path (per-channel PTQ weights)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fxp
from repro.core import ptq


def init_params(key: jax.Array) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    glorot = jax.nn.initializers.glorot_uniform()
    return {
        "conv1": {"w": glorot(k1, (2, 2, 1, 1), jnp.float32), "b": jnp.zeros((1,), jnp.float32)},
        "conv2": {"w": glorot(k2, (2, 2, 1, 1), jnp.float32), "b": jnp.zeros((1,), jnp.float32)},
        "dense": {"w": glorot(k3, (49, 10), jnp.float32), "b": jnp.zeros((10,), jnp.float32)},
    }


def param_count(params: dict) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))


def _conv_same_2x2(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """2x2 SAME conv, NHWC/HWIO. Keras pads SAME for even kernels as
    (0 before, 1 after) on each spatial dim."""
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding=((0, 1), (0, 1)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _maxpool_2x2(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def forward(params: dict, images: jnp.ndarray, *, sigmoid=jax.nn.sigmoid) -> jnp.ndarray:
    """images (B,28,28,1) -> class scores (B,10)."""
    x = sigmoid(_conv_same_2x2(images, params["conv1"]["w"], params["conv1"]["b"]))
    x = _maxpool_2x2(x)
    x = sigmoid(_conv_same_2x2(x, params["conv2"]["w"], params["conv2"]["b"]))
    x = _maxpool_2x2(x)
    x = x.reshape(x.shape[0], -1)                       # (B, 49)
    return sigmoid(x @ params["dense"]["w"] + params["dense"]["b"])


def forward_plan(params: dict, images: jnp.ndarray) -> jnp.ndarray:
    return forward(params, images, sigmoid=fxp.sigmoid_plan_f32)


def predict(scores: jnp.ndarray) -> jnp.ndarray:
    """The paper's 'Max Finder' module."""
    return jnp.argmax(scores, axis=-1)


def loss_fn(params: dict, images: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Categorical crossentropy (paper §III-A) over the sigmoid class scores.

    Training-only adaptation (documented in DESIGN.md): the raw normalized-CCE
    over sigmoid outputs has vanishing gradients at this tiny width; we apply
    CCE to temperature-sharpened scores instead.  log_softmax is monotone in
    the scores, so the *deployed* network (sigmoid + Max Finder argmax) is
    bit-identical to the paper's — only the training signal changes.
    """
    scores = forward(params, images)                    # sigmoid scores in (0,1)
    logp = jax.nn.log_softmax(8.0 * (scores - 0.5))
    onehot = jax.nn.one_hot(labels, 10)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


# ---------------------------------------------------------------------------
# Fixed-point path — the hardware datapath (windowing + MAC + PLAN sigmoid)
# ---------------------------------------------------------------------------

def quantize_params_fixed(params: dict, cfg: fxp.FixedPointConfig = fxp.Q16_16) -> dict:
    """The paper's §III-B weight extraction: float Keras weights ->
    two's-complement fixed point, 'hardcoded' (returned as int32 pytree)."""
    return jax.tree_util.tree_map(lambda p: fxp.to_fixed(p, cfg), params)


def _windows_2x2_same(x: jnp.ndarray) -> jnp.ndarray:
    """The windowing module: (B,H,W) -> (B,H,W,4) of 2x2 patches with SAME
    (0 before, 1 after) zero padding. Mirrors the Verilog line-buffer."""
    xp = jnp.pad(x, ((0, 0), (0, 1), (0, 1)))
    return jnp.stack([xp[:, :-1, :-1], xp[:, :-1, 1:],
                      xp[:, 1:, :-1], xp[:, 1:, 1:]], axis=-1)


def _conv_fixed(x: jnp.ndarray, w4: jnp.ndarray, b: jnp.ndarray,
                cfg: fxp.FixedPointConfig) -> jnp.ndarray:
    """Fixed-point conv: 4 parallel MACs per output pixel + bias add.
    x (B,H,W) int32 fixed; w4 (4,) int32 fixed; b () int32 fixed."""
    win = _windows_2x2_same(x)                            # (B,H,W,4)
    prods = fxp.fixed_mul(win, w4.reshape(1, 1, 1, 4), cfg)
    acc = jnp.sum(prods, axis=-1, dtype=jnp.int32)        # MAC accumulate
    return fxp.fixed_add(acc, b, cfg)


def _maxpool_fixed(x: jnp.ndarray) -> jnp.ndarray:
    """(B,H,W) int32 -> (B,H/2,W/2): comparator tree, exact in any format."""
    return jnp.maximum(jnp.maximum(x[:, ::2, ::2], x[:, ::2, 1::2]),
                       jnp.maximum(x[:, 1::2, ::2], x[:, 1::2, 1::2]))


def forward_fixed(qparams: dict, images: jnp.ndarray,
                  cfg: fxp.FixedPointConfig = fxp.Q16_16) -> jnp.ndarray:
    """Bit-faithful fixed-point inference. images float in [0,1] are
    quantized at the input port (the paper streams 8-bit pixels via DMA);
    returns fixed-point class scores (B,10) int32."""
    x = fxp.to_fixed(images[..., 0], cfg)                 # (B,28,28)
    w1 = qparams["conv1"]["w"].reshape(4)
    x = _conv_fixed(x, w1, qparams["conv1"]["b"][0], cfg)
    x = fxp.fixed_sigmoid_plan(x, cfg)
    x = _maxpool_fixed(x)                                  # (B,14,14)
    w2 = qparams["conv2"]["w"].reshape(4)
    x = _conv_fixed(x, w2, qparams["conv2"]["b"][0], cfg)
    x = fxp.fixed_sigmoid_plan(x, cfg)
    x = _maxpool_fixed(x)                                  # (B,7,7)
    x = x.reshape(x.shape[0], 49)
    x = fxp.fixed_matmul(x, qparams["dense"]["w"], cfg)
    x = fxp.fixed_add(x, qparams["dense"]["b"].reshape(1, 10), cfg)
    return fxp.fixed_sigmoid_plan(x, cfg)


# ---------------------------------------------------------------------------
# int8 path — TPU-native quantized inference
# ---------------------------------------------------------------------------

def quantize_params_int8(params: dict, cfg: ptq.QuantConfig = ptq.QuantConfig()) -> dict:
    return ptq.quantize_tree(params, cfg)


def forward_int8(qparams: dict, images: jnp.ndarray) -> jnp.ndarray:
    """int8 weights (dequant-on-use for conv; int8 MAC for dense)."""
    deq = ptq.dequantize_tree(qparams)
    x = fxp.sigmoid_plan_f32(_conv_same_2x2(images, deq["conv1"]["w"], deq["conv1"]["b"]))
    x = _maxpool_2x2(x)
    x = fxp.sigmoid_plan_f32(_conv_same_2x2(x, deq["conv2"]["w"], deq["conv2"]["b"]))
    x = _maxpool_2x2(x)
    x = x.reshape(x.shape[0], -1)
    # int8 MAC dense layer via the quantized-matmul path
    xq = ptq.quantize(x, ptq.QuantConfig(per_channel=False))
    wq = qparams["dense"]["w"]
    y = ptq.quantized_matmul_ref(xq, ptq.QuantTensor(wq.q, wq.scale.reshape(-1)))
    return fxp.sigmoid_plan_f32(y + deq["dense"]["b"])


def accuracy(apply_fn, params, images, labels, batch: int = 256) -> float:
    hits, n = 0, 0
    for s in range(0, images.shape[0], batch):
        scores = apply_fn(params, images[s:s + batch])
        hits += int(jnp.sum(predict(scores) == labels[s:s + batch]))
        n += int(labels[s:s + batch].shape[0])
    return hits / max(n, 1)
