"""Process-wide kernel-execution switches — ONE place to flip real-device mode.

Every Pallas wrapper in kernels/*/ops.py takes `interpret: bool | None`
and resolves `None` against this module's default, so the whole stack
(backend registry -> FcnSweep -> StreamingPipeline -> benchmarks) moves
between the CPU interpreter and compiled TPU kernels with a single call:

    from repro.core import runtime
    runtime.set_interpret(False)        # real-device run from here on

The default is True (interpreter): CI and every test battery run on CPU
hosts, and for this repo's integer kernels interpret mode is bit-identical
to compiled mode (see kernels/fixed_conv/kernel.py).  Benchmarks expose the
switch as `--real-device`.

Why a module-level flag instead of threading a kwarg through every layer:
the flag is resolved in each wrapper's THIN UN-JITTED entry point, before
`jax.jit` ever sees it, so a changed default cannot be baked stale into a
compiled executable.  `set_interpret` still clears jit caches (and any
registered model-level caches, e.g. the FCN sweep's per-geometry program
cache) so previously compiled programs from the old mode are dropped.
"""
from __future__ import annotations

from typing import Callable

_INTERPRET: bool = True
_RESET_HOOKS: list[Callable[[], None]] = []


def interpret_default() -> bool:
    """The current process-wide interpret default."""
    return _INTERPRET


def resolve_interpret(interpret: bool | None) -> bool:
    """What the ops wrappers call: explicit flag wins, None follows the
    process default."""
    return _INTERPRET if interpret is None else bool(interpret)


def register_reset_hook(fn: Callable[[], None]) -> None:
    """Register a cache-clearing callback to run on `set_interpret` (for
    caches that close over compiled programs, like `fcn_sweep._sweep_fn`)."""
    if fn not in _RESET_HOOKS:
        _RESET_HOOKS.append(fn)


def set_interpret(flag: bool) -> None:
    """Flip the process between Pallas interpret (CPU) and compiled (TPU)
    execution.  Clears jit caches + registered model caches so nothing
    compiled under the old mode survives."""
    global _INTERPRET
    flag = bool(flag)
    if flag == _INTERPRET:
        return
    _INTERPRET = flag
    import jax
    jax.clear_caches()
    for hook in _RESET_HOOKS:
        hook()
