"""Post-training quantization — the paper's train→extract→bake flow, generalized.

smallNet trains in float (Keras), extracts weights, converts them to
two's-complement fixed point, and bakes them into the fabric.  On TPU the
native cheap multiplier is int8 (MXU int8 matmuls run at 2x the bf16 rate),
so the framework's production path is symmetric int8 with per-channel weight
scales and int32 accumulation; the Qm.n path in `fixed_point.py` remains the
paper-faithful 32-bit mode.

Supports:
  * per-tensor / per-channel symmetric weight quantization (absmax or
    percentile calibration)
  * static activation calibration from a calibration batch
  * whole-pytree quantization of any model's linear weights (`quantize_tree`)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    bits: int = 8
    per_channel: bool = True       # scale per output channel (last weight dim)
    percentile: float = 100.0      # 100 = absmax; <100 clips outliers
    symmetric: bool = True         # symmetric (2's complement) only, like the paper

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantTensor:
    """int values + float scale; value = q * scale."""
    q: jnp.ndarray           # int8 (or int32 for the fixed-point path)
    scale: jnp.ndarray       # f32, broadcastable against q

    def dequantize(self) -> jnp.ndarray:
        return self.q.astype(jnp.float32) * self.scale

    def tree_flatten(self):
        return (self.q, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def _calib_scale(x: jnp.ndarray, cfg: QuantConfig, axis) -> jnp.ndarray:
    ax = jnp.abs(x.astype(jnp.float32))
    if cfg.percentile >= 100.0:
        m = jnp.max(ax, axis=axis, keepdims=True)
    else:
        m = jnp.percentile(ax, cfg.percentile, axis=axis, keepdims=True)
    return jnp.maximum(m, 1e-8) / cfg.qmax


def quantize(x: jnp.ndarray, cfg: QuantConfig = QuantConfig()) -> QuantTensor:
    """Symmetric quantization. Per-channel scales are over the LAST dim."""
    if cfg.per_channel and x.ndim >= 2:
        axis = tuple(range(x.ndim - 1))
    else:
        axis = tuple(range(x.ndim))
    scale = _calib_scale(x, cfg, axis)
    q = jnp.clip(jnp.round(x / scale), -cfg.qmax - 1, cfg.qmax).astype(jnp.int8)
    return QuantTensor(q, scale)


def quantize_activation(x: jnp.ndarray, scale: jnp.ndarray, cfg: QuantConfig = QuantConfig()):
    """Quantize with a pre-calibrated (static) scale."""
    q = jnp.clip(jnp.round(x / scale), -cfg.qmax - 1, cfg.qmax).astype(jnp.int8)
    return QuantTensor(q, scale)


def calibrate_activation_scale(samples: jnp.ndarray, cfg: QuantConfig = QuantConfig()):
    """Per-tensor activation scale from a calibration batch."""
    return _calib_scale(samples, dataclasses.replace(cfg, per_channel=False),
                        tuple(range(samples.ndim)))


def quantized_matmul_ref(xq: QuantTensor, wq: QuantTensor) -> jnp.ndarray:
    """int8 x int8 -> int32 accumulate -> dequantized f32. Pure-jnp oracle;
    the Pallas MXU kernel lives in kernels/quant_matmul."""
    acc = jax.lax.dot_general(
        xq.q, wq.q, (((xq.q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * xq.scale * wq.scale.reshape(1, -1)


def _default_predicate(path, x) -> bool:
    """Quantize matrix weights only: rank>=3 (stacked-layer weights) or
    top-level rank-2 matrices (embed/lm_head).  Rank-2 leaves inside stacked
    blocks are norms/biases stacked over layers — they stay float (biases add
    post-MAC at accumulator precision, exactly like the paper)."""
    if not (hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)):
        return False
    pathstr = jax.tree_util.keystr(path)
    if x.ndim >= 3:
        return True
    return x.ndim == 2 and "blocks" not in pathstr and "norm" not in pathstr \
        and "pos" not in pathstr


def quantize_tree(params: Any, cfg: QuantConfig = QuantConfig(),
                  predicate: Callable[[tuple, jnp.ndarray], bool] | None = None):
    """Quantize every >=2-D float leaf (linear/embedding weights) of a pytree.

    Returns a pytree with QuantTensor leaves where quantized; biases and
    norms (1-D) stay float, mirroring the paper (biases are added post-MAC
    at accumulator precision).
    """
    if predicate is None:
        predicate = _default_predicate
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    out = []
    for path, leaf in flat:
        if predicate(path, leaf):
            # rank>=3 leaves are stacked-layer weights: per-(layer, channel)
            # scales — better calibration AND keeps the leading dim scannable
            if cfg.per_channel:
                axis = tuple(range(1 if leaf.ndim >= 3 else 0, leaf.ndim - 1))
            else:
                axis = tuple(range(leaf.ndim))
            scale = _calib_scale(leaf.astype(jnp.float32), cfg, axis)
            q = jnp.clip(jnp.round(leaf / scale), -cfg.qmax - 1,
                         cfg.qmax).astype(jnp.int8)
            out.append(QuantTensor(q, scale))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def quantize_axes(params: Any, axes: Any,
                  predicate: Callable[[tuple, Any], bool] | None = None) -> Any:
    """Transform a logical-axes pytree in lockstep with quantize_tree: a
    weight leaf's axes tuple becomes {"q": axes, "scale": (None,...,last)}
    so sharding specs keep following the quantized structure."""
    if predicate is None:
        predicate = _default_predicate
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_a = jax.tree_util.tree_leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    tdef = jax.tree_util.tree_structure(axes, is_leaf=lambda x: isinstance(x, tuple))
    out = []
    for (path, leaf), ax in zip(flat_p, flat_a):
        if predicate(path, leaf):
            # QuantTensor node so the axes tree keeps the params structure;
            # rank>=3 scales keep the stacked-layer leading axis
            if leaf.ndim >= 3:
                sax = (ax[0],) + (None,) * (len(ax) - 2) + (ax[-1],)
            else:
                sax = (None,) * (len(ax) - 1) + (ax[-1],)
            out.append(QuantTensor(ax, sax))
        else:
            out.append(ax)
    return jax.tree_util.tree_unflatten(tdef, out)


def abstract_quantize_tree(params_abs: Any, cfg: QuantConfig = QuantConfig()) -> Any:
    """quantize_tree over ShapeDtypeStructs (no allocation) — dry-run path."""
    return jax.eval_shape(lambda p: quantize_tree(p, cfg), params_abs)


def dequantize_tree(qparams: Any) -> Any:
    """Inverse of quantize_tree (for accuracy-gap analysis)."""
    return jax.tree_util.tree_map(
        lambda x: x.dequantize() if isinstance(x, QuantTensor) else x,
        qparams, is_leaf=lambda x: isinstance(x, QuantTensor))


def quantization_error(params: Any, qparams: Any) -> dict:
    """Per-leaf relative L2 error of quantization — the paper's §III-B
    'limitations of numerical representations' analysis, as a tool."""
    errs = {}
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_q = jax.tree_util.tree_leaves(
        qparams, is_leaf=lambda x: isinstance(x, QuantTensor))
    for (path, p), q in zip(flat_p, flat_q):
        if isinstance(q, QuantTensor):
            d = q.dequantize()
            errs[jax.tree_util.keystr(path)] = float(
                jnp.linalg.norm(p - d) / (jnp.linalg.norm(p) + 1e-12))
    return errs
