"""Fault-tolerant checkpointing: sharded npz + manifest, atomic, async, elastic.

Design (1000+-node posture, DESIGN.md §5):
  * layout: <dir>/step_<N>/shard_<host>.npz + manifest.json
    - each host writes only the leaf-shards it owns (here: single host writes
      all, but the addressable-shard enumeration is the multi-host code path)
  * atomicity: write to step_<N>.tmp/, fsync, rename -> step_<N>; a crashed
    writer never corrupts the latest complete checkpoint
  * integrity: manifest records per-array {shape, dtype, crc32}; restore
    verifies before handing params to the trainer
  * async: a background thread serializes device-to-host copies so the train
    loop overlaps the next step with I/O
  * elastic restore: arrays are saved UNSHARDED per leaf (host gathers its
    addressable shards); restore re-shards onto whatever mesh/device count
    the new job has -> checkpoint works across mesh changes (elastic scaling)
"""
from __future__ import annotations

import json
import pathlib
import re
import threading
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(p): np.asarray(v) for p, v in flat}


def _to_savable(a: np.ndarray) -> np.ndarray:
    """npz can't hold ml_dtypes (bf16 etc.): store the raw bits; the
    manifest dtype restores the view."""
    if a.dtype.kind == "V" or str(a.dtype) in ("bfloat16", "float8_e4m3fn",
                                               "float8_e5m2"):
        return a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8)
    return a


def _from_savable(a: np.ndarray, dtype_str: str) -> np.ndarray:
    if str(a.dtype) != dtype_str:
        import ml_dtypes
        return a.view(np.dtype(getattr(ml_dtypes, dtype_str, dtype_str)))
    return a


def _crc(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a).tobytes())


def save_checkpoint(dirpath: str | pathlib.Path, step: int, tree: Any,
                    *, host_id: int = 0) -> pathlib.Path:
    d = pathlib.Path(dirpath)
    tmp = d / f"step_{step}.tmp"
    final = d / f"step_{step}"
    tmp.mkdir(parents=True, exist_ok=True)
    arrays = _flatten(tree)
    savable = {k: _to_savable(v) for k, v in arrays.items()}
    np.savez(tmp / f"shard_{host_id}.npz", **savable)
    manifest = {
        "step": step,
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                       "crc32": _crc(savable[k])} for k, v in arrays.items()},
        "hosts": 1,
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        import shutil
        shutil.rmtree(final)
    tmp.rename(final)                       # atomic publish
    return final


def latest_step(dirpath: str | pathlib.Path) -> int | None:
    d = pathlib.Path(dirpath)
    if not d.exists():
        return None
    steps = [int(m.group(1)) for p in d.iterdir()
             if (m := re.fullmatch(r"step_(\d+)", p.name))]
    return max(steps) if steps else None


def restore_checkpoint(dirpath: str | pathlib.Path, tree_like: Any,
                       step: int | None = None, *, shardings: Any = None) -> Any:
    """Restore into the structure of `tree_like`.  `shardings` (optional
    pytree of NamedSharding/PartitionSpec) re-shards onto the current mesh —
    the elastic-scaling path: a checkpoint saved on mesh A restores on any
    mesh B."""
    d = pathlib.Path(dirpath)
    if step is None:
        step = latest_step(d)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {d}")
    cdir = d / f"step_{step}"
    manifest = json.loads((cdir / "manifest.json").read_text())
    arrays: dict[str, np.ndarray] = {}
    for shard in sorted(cdir.glob("shard_*.npz")):
        with np.load(shard) as z:
            for k in z.files:
                arrays[k] = z[k]
    for k, meta in manifest["arrays"].items():
        if _crc(arrays[k]) != meta["crc32"]:
            raise IOError(f"checkpoint corruption in {k} at step {step}")
        arrays[k] = _from_savable(arrays[k], meta["dtype"])
    flat, tdef = jax.tree_util.tree_flatten_with_path(tree_like)
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat))
    out = []
    for (path, like), shd in zip(flat, shard_flat):
        a = arrays[jax.tree_util.keystr(path)]
        if shd is not None:
            out.append(jax.device_put(a.astype(like.dtype), shd))
        else:
            out.append(jnp.asarray(a, like.dtype))
    return jax.tree_util.tree_unflatten(tdef, out)


class CheckpointManager:
    """Async checkpointing + retention + auto-resume."""

    def __init__(self, dirpath: str | pathlib.Path, keep: int = 3):
        self.dir = pathlib.Path(dirpath)
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save_async(self, step: int, tree: Any) -> None:
        # device->host copy happens here (blocking, consistent snapshot);
        # serialization/fsync happens on the writer thread
        host_tree = jax.tree_util.tree_map(np.asarray, tree)
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree), daemon=True)
        self._thread.start()

    def _write(self, step: int, host_tree: Any) -> None:
        save_checkpoint(self.dir, step, host_tree)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(int(m.group(1)) for p in self.dir.iterdir()
                       if (m := re.fullmatch(r"step_(\d+)", p.name)))
        for s in steps[:-self.keep]:
            import shutil
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    def restore_latest(self, tree_like: Any, shardings: Any = None) -> tuple[Any, int] | None:
        step = latest_step(self.dir)
        if step is None:
            return None
        return restore_checkpoint(self.dir, tree_like, step,
                                  shardings=shardings), step
