"""Tiled big-frame trunk megakernel: smallNet's whole conv trunk, one launch."""
from repro.kernels.frame_trunk.ops import (HALO, choose_tile,
                                           frame_trunk_quad,
                                           frame_trunk_vmem_bytes)

__all__ = ["HALO", "choose_tile", "frame_trunk_quad",
           "frame_trunk_vmem_bytes"]
