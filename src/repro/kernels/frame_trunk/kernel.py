"""Tiled whole-frame trunk megakernel: smallNet's conv->PLAN->pool->conv->
PLAN->pool pipeline over a big frame in ONE Pallas launch.

The paper's headline is a single hand-fused hardware stage that never
leaves the datapath; the PR-5 frame sweep reproduced its arithmetic but
still dispatched O(stages x role-maps) separate launches per frame (4
single-source + 5 mixed-source conv launches at level 1, plus pools).
This kernel is the ZynqNet/Solovyev-style whole-frame tiled dataflow: the
grid walks spatial frame tiles, each program instance

  DMA            copies its input tile PLUS a `HALO`-wide apron of rows/
                 cols from the (zero-padded) frame in HBM/ANY into a VMEM
                 scratch block — overlapping reads are inexpressible as a
                 blocked `BlockSpec`, so the halo load is an explicit
                 `pltpu.make_async_copy` with element offsets
  level 0        4 masked-tap conv+PLAN maps over the tile extent + 2
                 (interior / last-row / last-col / corner, the quad-role
                 cascade of streaming/fcn_sweep.py), pooled 2x2/2 into the
                 level-1 quad WITH one halo row/col kept, then frame-edge
                 rows/cols zeroed (they realize level 1's SAME padding)
  level 1        the 9 role maps (4 single-source + 5 mixed-source masked
                 convs recombined with wraparound `fixed_add`, in exactly
                 `_sweep_stage`'s association order), PLAN, pooled into the
                 (4, th/4, tw/4) output quad tile

entirely in int32 Qm.n words, reusing the SAME `core/fixed_point` helpers
as `kernels/fixed_conv` (16-bit-limb MAC, wraparound adds,
`shift_right_round`, PLAN shift-add) — so the megakernel cannot drift from
the per-stage kernels it replaces.  Word-exactness vs the composed sweep is
an associativity argument, not a tolerance: every masked partial conv wraps
its accumulator into the Qm.n word exactly where `backends.conv_fixed`
does, and wraparound addition is associative mod 2**total_bits (saturating
configs are rejected by ops.py for exactly this reason).

Why the halo is 3: level-0 convs at the tile's last row read 1 row down
(2x2 kernel), the level-1 quad keeps 1 pooled halo row (= 2 more level-0
conv rows, i.e. input rows), and level-1 convs read 1 pooled row down —
3 input rows/cols past the tile on the bottom/right, 0 on the top/left
(the SAME convention is 0-before/1-after, so tiles never look up-left).

Interpret mode is bit-identical to compiled mode for the same reason as
kernels/fixed_conv: every op is integer with exactly one defined result.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import fixed_point as fxp

HALO = 3                      # input rows/cols of bottom/right apron per tile

_TAPS = ((0, 0), (0, 1), (1, 0), (1, 1))   # (dh, dw), row-major like w.reshape(4)

# tap-index subsets of the 2x2 kernel, mirroring fcn_sweep's weight masks
_T_ALL = (0, 1, 2, 3)
_T_TOP = (0, 1)               # keep kernel row 0   (w_top)
_T_BOT = (2, 3)               # keep kernel row 1   (w_bot)
_T_LEFT = (0, 2)              # keep kernel col 0   (w_left)
_T_RIGHT = (1, 3)             # keep kernel col 1   (w_right)
_T_00, _T_01, _T_10, _T_11 = (0,), (1,), (2,), (3,)


def _conv(x, w_ref, taps, bias, cfg, Ho, Wo):
    """Masked-tap fixed conv over a local block: per-tap limb MAC with
    plain int32 accumulation, then ONE `fixed_add` folding in the bias (or
    a zero word) — the exact accumulator structure of `backends.conv_fixed`
    / `kernels/fixed_conv`, so each partial conv lands on the same Qm.n
    word the composed sweep computes.  Skipped taps contribute exactly what
    a zeroed weight would (fixed_mul(x, 0) == 0 in every format)."""
    acc = jnp.zeros((Ho, Wo), jnp.int32)
    for t in taps:
        dh, dw = _TAPS[t]
        win = x[dh:dh + Ho, dw:dw + Wo]
        acc = acc + fxp.fixed_mul(win, w_ref[t], cfg)
    return fxp.fixed_add(acc, bias, cfg)


def _pool_mix(e, o):
    """2D sibling of fcn_sweep._pool_mix: even output rows pool conv rows
    from `e`, odd rows from `o`."""
    return jnp.maximum(jnp.maximum(e[::2, ::2], e[::2, 1::2]),
                       jnp.maximum(o[1::2, ::2], o[1::2, 1::2]))


def _pool_quadrants(tl, tr, bl, br):
    """2D sibling of fcn_sweep._pool_quadrants: one source per window
    quadrant."""
    return jnp.maximum(jnp.maximum(tl[::2, ::2], tr[::2, 1::2]),
                       jnp.maximum(bl[1::2, ::2], br[1::2, 1::2]))


def _frame_trunk_kernel(x_hbm, w1_ref, b1_ref, w2_ref, b2_ref, o_ref,
                        xt_ref, sem, *, cfg: fxp.FixedPointConfig,
                        th: int, tw: int, H: int, W: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    # -- halo DMA: (th+HALO, tw+HALO) block of the zero-padded frame -------
    dma = pltpu.make_async_copy(
        x_hbm.at[pl.ds(i * th, th + HALO), pl.ds(j * tw, tw + HALO)],
        xt_ref, sem)
    dma.start()
    dma.wait()
    x = xt_ref[...]

    def plan(y):
        return fxp.fixed_sigmoid_plan(y, cfg)

    def add(a, b):
        return fxp.fixed_add(a, b, cfg)

    b1 = b1_ref[0]
    b2 = b2_ref[0]
    zero = jnp.int32(0)

    # -- level 0: pixels are role-independent, so the quad collapses onto
    # 4 masked-tap maps (fcn_sweep's level-0 collapse), computed over the
    # tile extent + 2 so the level-1 quad keeps one pooled halo row/col
    h0, w0 = th + HALO - 1, tw + HALO - 1
    s_ii = plan(_conv(x, w1_ref, _T_ALL, b1, cfg, h0, w0))
    s_li = plan(_conv(x, w1_ref, _T_TOP, b1, cfg, h0, w0))
    s_il = plan(_conv(x, w1_ref, _T_LEFT, b1, cfg, h0, w0))
    s_ll = plan(_conv(x, w1_ref, _T_00, b1, cfg, h0, w0))

    I1 = _pool_mix(s_ii, s_ii)                       # interior
    B1 = _pool_mix(s_ii, s_li)                       # last row
    R1 = _pool_quadrants(s_ii, s_il, s_ii, s_il)     # last col
    C1 = _pool_quadrants(s_ii, s_il, s_li, s_ll)     # corner

    # -- frame-edge masking: a level-1 position at global row H/2 / col W/2
    # exists only as this tile's halo over the frame's zero padding; its
    # conv words are bias+PLAN garbage, but semantically it IS level 1's
    # SAME zero padding — so zero it.  Interior tiles' halos hold their
    # right/down neighbor's real values and pass through untouched.
    h1, w1 = th // 2 + 1, tw // 2 + 1
    rows = i * (th // 2) + jax.lax.broadcasted_iota(jnp.int32, (h1, w1), 0)
    cols = j * (tw // 2) + jax.lax.broadcasted_iota(jnp.int32, (h1, w1), 1)
    keep = (rows < H // 2) & (cols < W // 2)
    I1, B1, R1, C1 = (jnp.where(keep, m, zero) for m in (I1, B1, R1, C1))

    # -- level 1: the full 9-map mixed-source stage, masked partial convs
    # recombined with wraparound adds in _sweep_stage's association order
    h2, w2 = th // 2, tw // 2
    c = functools.partial(_conv, cfg=cfg, Ho=h2, Wo=w2)
    s_ii2 = plan(c(I1, w2_ref, _T_ALL, b2))
    s_li2 = plan(c(B1, w2_ref, _T_TOP, b2))
    s_il2 = plan(c(R1, w2_ref, _T_LEFT, b2))
    s_ll2 = plan(c(C1, w2_ref, _T_00, b2))
    s_pi2 = plan(add(c(I1, w2_ref, _T_TOP, b2), c(B1, w2_ref, _T_BOT, zero)))
    s_ip2 = plan(add(c(I1, w2_ref, _T_LEFT, b2),
                     c(R1, w2_ref, _T_RIGHT, zero)))
    s_pp2 = plan(add(add(add(c(I1, w2_ref, _T_00, b2),
                             c(R1, w2_ref, _T_01, zero)),
                         c(B1, w2_ref, _T_10, zero)),
                     c(C1, w2_ref, _T_11, zero)))
    s_pl2 = plan(add(c(R1, w2_ref, _T_00, b2), c(C1, w2_ref, _T_10, zero)))
    s_lp2 = plan(add(c(B1, w2_ref, _T_00, b2), c(C1, w2_ref, _T_01, zero)))

    o_ref[...] = jnp.stack([
        _pool_mix(s_ii2, s_ii2),                         # interior
        _pool_mix(s_pi2, s_li2),                         # last row
        _pool_quadrants(s_ip2, s_il2, s_ip2, s_il2),     # last col
        _pool_quadrants(s_pp2, s_pl2, s_lp2, s_ll2),     # corner
    ])


def frame_trunk_pallas(xp: jnp.ndarray, w1: jnp.ndarray, b1: jnp.ndarray,
                       w2: jnp.ndarray, b2: jnp.ndarray, *,
                       cfg: fxp.FixedPointConfig = fxp.Q16_16,
                       th: int, tw: int,
                       interpret: bool = True) -> jnp.ndarray:
    """xp (H+HALO, W+HALO) int32 frame pre-padded with HALO zero rows/cols
    bottom+right; w1/w2 (4,) int32 taps; b1/b2 (1,) int32 bias words;
    (th, tw) the tile extent (each divides H/W, multiples of 4).  Returns
    the (4, H/4, W/4) int32 level-2 role-map quad
    [interior, last_row, last_col, corner] in ONE launch."""
    H, W = xp.shape[0] - HALO, xp.shape[1] - HALO
    kern = functools.partial(_frame_trunk_kernel, cfg=cfg, th=th, tw=tw,
                             H=H, W=W)
    return pl.pallas_call(
        kern,
        grid=(H // th, W // tw),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),        # manual halo DMA
            pl.BlockSpec((4,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((4,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((4, th // 4, tw // 4), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((4, H // 4, W // 4), jnp.int32),
        scratch_shapes=[pltpu.VMEM((th + HALO, tw + HALO), jnp.int32),
                        pltpu.SemaphoreType.DMA],
        interpret=interpret,
    )(xp, w1, b1, w2, b2)
