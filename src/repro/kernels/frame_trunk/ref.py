"""Numpy int64 oracle for the trunk megakernel.

Composes the `kernels/fixed_conv/ref.py` primitives (full-64-bit products,
explicit wraps — no limb tricks) into the quad-role trunk exactly as
`streaming/fcn_sweep._sweep_stage` structures it: level 0 collapses onto 4
masked-tap maps, level 1 runs the full 9-map mixed-source stage with
masked partial convs recombined by wraparound `fixed_add_ref` in the same
association order.  The Pallas megakernel, the composed sweep, and this
module are three independent routes to the same int32 words; the test
battery pins each pair so a bug in the kernel's tiling/halo bookkeeping
cannot hide behind a matching bug in the sweep (or vice versa).

The oracle is deliberately UNTILED — one whole-frame computation — so it
knows nothing about halos, DMA offsets, or edge masking: exactly the
things the megakernel must get right to match it.
"""
from __future__ import annotations

import numpy as np

from repro.core.fixed_point import FixedPointConfig, Q16_16
from repro.kernels.fixed_conv.ref import (fixed_add_ref, fixed_conv2d_ref,
                                          fixed_sigmoid_plan_ref)

# tap masks over the row-major (4,) kernel, mirroring fcn_sweep._mask
_M_ALL = np.array([1, 1, 1, 1], np.int64)
_M_TOP = np.array([1, 1, 0, 0], np.int64)      # keep kernel row 0
_M_BOT = np.array([0, 0, 1, 1], np.int64)
_M_LEFT = np.array([1, 0, 1, 0], np.int64)     # keep kernel col 0
_M_RIGHT = np.array([0, 1, 0, 1], np.int64)
_M_00 = np.array([1, 0, 0, 0], np.int64)
_M_01 = np.array([0, 1, 0, 0], np.int64)
_M_10 = np.array([0, 0, 1, 0], np.int64)
_M_11 = np.array([0, 0, 0, 1], np.int64)


def _pool_mix_ref(e, o):
    """(B,H,W) -> (B,H/2,W/2): even output rows pool `e`, odd rows `o`."""
    return np.maximum(np.maximum(e[:, ::2, ::2], e[:, ::2, 1::2]),
                      np.maximum(o[:, 1::2, ::2], o[:, 1::2, 1::2]))


def _pool_quadrants_ref(tl, tr, bl, br):
    return np.maximum(np.maximum(tl[:, ::2, ::2], tr[:, ::2, 1::2]),
                      np.maximum(bl[:, 1::2, ::2], br[:, 1::2, 1::2]))


def frame_trunk_quad_ref(x: np.ndarray, w1: np.ndarray, b1, w2: np.ndarray,
                         b2, cfg: FixedPointConfig = Q16_16) -> np.ndarray:
    """x (H, W) int words; w1/w2 (4,) row-major taps; b1/b2 scalar bias
    words.  Returns the (4, H/4, W/4) int64 level-2 quad
    [interior, last_row, last_col, corner]."""
    if cfg.saturate:
        raise NotImplementedError("oracle requires wraparound configs, "
                                  "like the megakernel it pins")
    x = np.asarray(x, np.int64)[None]              # (1, H, W)
    w1 = np.asarray(w1, np.int64).reshape(4)
    w2 = np.asarray(w2, np.int64).reshape(4)
    b1 = np.int64(np.asarray(b1).reshape(-1)[0])
    b2 = np.int64(np.asarray(b2).reshape(-1)[0])

    def conv(src, w4, mask, bias):
        return fixed_conv2d_ref(src, w4 * mask, bias, cfg)

    def plan(y):
        return fixed_sigmoid_plan_ref(y, cfg)

    def add(a, b):
        return fixed_add_ref(a, b, cfg)

    # level 0: role-independent pixels, collapsed quad
    s_ii = plan(conv(x, w1, _M_ALL, b1))
    s_li = plan(conv(x, w1, _M_TOP, b1))
    s_il = plan(conv(x, w1, _M_LEFT, b1))
    s_ll = plan(conv(x, w1, _M_00, b1))
    I1 = _pool_mix_ref(s_ii, s_ii)
    B1 = _pool_mix_ref(s_ii, s_li)
    R1 = _pool_quadrants_ref(s_ii, s_il, s_ii, s_il)
    C1 = _pool_quadrants_ref(s_ii, s_il, s_li, s_ll)

    # level 1: full mixed-source stage, _sweep_stage's association order
    z = np.int64(0)
    s_ii2 = plan(conv(I1, w2, _M_ALL, b2))
    s_li2 = plan(conv(B1, w2, _M_TOP, b2))
    s_il2 = plan(conv(R1, w2, _M_LEFT, b2))
    s_ll2 = plan(conv(C1, w2, _M_00, b2))
    s_pi2 = plan(add(conv(I1, w2, _M_TOP, b2), conv(B1, w2, _M_BOT, z)))
    s_ip2 = plan(add(conv(I1, w2, _M_LEFT, b2), conv(R1, w2, _M_RIGHT, z)))
    s_pp2 = plan(add(add(add(conv(I1, w2, _M_00, b2),
                             conv(R1, w2, _M_01, z)),
                         conv(B1, w2, _M_10, z)),
                     conv(C1, w2, _M_11, z)))
    s_pl2 = plan(add(conv(R1, w2, _M_00, b2), conv(C1, w2, _M_10, z)))
    s_lp2 = plan(add(conv(B1, w2, _M_00, b2), conv(C1, w2, _M_01, z)))

    return np.stack([
        _pool_mix_ref(s_ii2, s_ii2)[0],
        _pool_mix_ref(s_pi2, s_li2)[0],
        _pool_quadrants_ref(s_ip2, s_il2, s_ip2, s_il2)[0],
        _pool_quadrants_ref(s_pp2, s_pl2, s_lp2, s_ll2)[0],
    ])
