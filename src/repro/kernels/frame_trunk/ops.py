"""jit'd public wrapper + VMEM-budget tile chooser for the trunk megakernel.

`frame_trunk_quad` is the one-launch trunk: (H, W) int32 frame words ->
(4, H/4, W/4) level-2 role-map quad [interior, last_row, last_col, corner],
word-exact with the composed FcnSweep trunk (streaming/fcn_sweep.py) and
with the per-stage kernels/fixed_conv launches it replaces.

Tile-size selection (`choose_tile`) is a static VMEM budget computation:
for a candidate (th, tw) tile the kernel's resident int32 words are

    (th+halo)(tw+halo)           input tile + bottom/right halo apron
  + 11 (th+halo-1)(tw+halo-1)    4 level-0 conv/PLAN maps + the worst-case
                                 ~7 limb temporaries of one tap's fixed mul
  + 4 (th/2+1)(tw/2+1)           level-1 quad incl. its pooled halo row/col
  + 16 (th/2)(tw/2)              9 level-1 role maps + limb temporaries
  + 4 (th/4)(tw/4)               the output quad tile

all x4 bytes (`frame_trunk_vmem_bytes`).  The chooser scans tile extents
that divide the frame and are multiples of 4 (two 2x2/2 pools), keeping
the largest-area tile that fits the 14 MB budget — a 112x112 frame runs as
one tile (~900 KB), 512x512 splits into two 512x256 tiles (~9 MB each), so
the acceptance-bar 512 frame genuinely exercises tile seams.

The perf ledger's bytes-moved account (`analysis/mfu.py`,
`trunk_workload(..., "sweep_megakernel")`) counts this kernel's off-chip
traffic from the same geometry: n_tiles x (th+HALO)(tw+HALO) input words
DMA'd HBM->VMEM (the halo apron is genuinely re-read at tile seams) plus
the 4 x (H/4)(W/4) output quad written back — nothing else leaves the
core, which is exactly the ~20x byte reduction over the composed sweep's
per-launch HBM round-trips that the ledger's `mfu`/`achieved_bw` columns
surface.  `tests/test_mfu.py` pins the model to `choose_tile`/`HALO`.

Geometry contract (loud, tested in tests/test_frame_trunk_props.py): the
frame must have H % 4 == W % 4 == 0 and be at least 4x4 — the same pooled
lattice the sweep itself requires — and saturating fixed-point configs are
rejected (the megakernel's decomposed accumulation leans on wraparound
associativity exactly like the composed sweep).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fxp
from repro.core import runtime
from repro.kernels.frame_trunk.kernel import HALO, frame_trunk_pallas

_VMEM_BUDGET = 14 * 2 ** 20  # leave headroom out of ~16 MB/core


def frame_trunk_vmem_bytes(th: int, tw: int, *, halo: int = HALO) -> int:
    """Resident VMEM bytes for one (th, tw) tile program (see module
    docstring for the breakdown)."""
    h0, w0 = th + halo - 1, tw + halo - 1         # level-0 conv extent
    words = ((th + halo) * (tw + halo)
             + 11 * h0 * w0
             + 4 * (th // 2 + 1) * (tw // 2 + 1)
             + 16 * (th // 2) * (tw // 2)
             + 4 * (th // 4) * (tw // 4))
    return 4 * words


def _tile_candidates(n: int) -> list[int]:
    """Divisors of n that are multiples of 4, largest first."""
    return [d for d in range(n, 3, -1) if n % d == 0 and d % 4 == 0]


def check_frame_geometry(H: int, W: int) -> None:
    """The pooled-lattice contract every trunk entry point shares."""
    if H < 4 or W < 4:
        raise ValueError(
            f"frame {H}x{W} is too small to tile: the trunk pools 4x in "
            f"each dim, so frames must be at least 4x4")
    if H % 4 or W % 4:
        raise ValueError(
            f"frame {H}x{W} breaks the pooled-lattice contract: two 2x2/2 "
            f"pools need H % 4 == W % 4 == 0 (pad or crop the frame)")


def choose_tile(H: int, W: int, *, halo: int = HALO,
                budget: int = _VMEM_BUDGET) -> tuple[int, int]:
    """Largest-area (th, tw) tile that divides the (H, W) frame on the
    pooled lattice and fits the VMEM budget.  Deterministic: ties prefer
    the squarer tile, then the taller one."""
    check_frame_geometry(H, W)
    best = None
    for th in _tile_candidates(H):
        for tw in _tile_candidates(W):
            if frame_trunk_vmem_bytes(th, tw, halo=halo) > budget:
                continue
            key = (th * tw, min(th, tw), th)
            if best is None or key > best[0]:
                best = (key, (th, tw))
    if best is None:
        raise ValueError(
            f"VMEM budget {budget} B cannot fit even a 4x4 tile of the "
            f"{H}x{W} frame "
            f"({frame_trunk_vmem_bytes(4, 4, halo=halo)} B needed)")
    return best[1]


@functools.partial(jax.jit, static_argnames=("cfg", "th", "tw", "interpret"))
def _frame_trunk_jit(x, w1, b1, w2, b2, *, cfg, th, tw, interpret):
    xp = jnp.pad(x.astype(jnp.int32), ((0, HALO), (0, HALO)))
    return frame_trunk_pallas(
        xp, w1.reshape(4).astype(jnp.int32), b1.reshape(1).astype(jnp.int32),
        w2.reshape(4).astype(jnp.int32), b2.reshape(1).astype(jnp.int32),
        cfg=cfg, th=th, tw=tw, interpret=interpret)


def frame_trunk_quad(x: jnp.ndarray, w1: jnp.ndarray, b1: jnp.ndarray,
                     w2: jnp.ndarray, b2: jnp.ndarray, *,
                     cfg: fxp.FixedPointConfig = fxp.Q16_16,
                     tile: tuple[int, int] | None = None,
                     interpret: bool | None = None) -> jnp.ndarray:
    """Both trunk stages over one (H, W) int32 word frame in ONE launch:
    returns the (4, H/4, W/4) int32 quad [interior, last_row, last_col,
    corner].  w1/w2 are the (2,2,1,1) or (4,) int32 conv taps, b1/b2 the
    bias words.  `tile=None` picks the tile via `choose_tile`; an explicit
    (th, tw) must divide the frame on the pooled lattice (tests use small
    forced tiles to exercise seams on small frames).  `interpret=None`
    follows `core.runtime` (the process-wide real-device switch)."""
    H, W = x.shape
    check_frame_geometry(H, W)
    if cfg.saturate:
        raise NotImplementedError(
            "frame_trunk requires a wraparound fixed-point config: "
            "saturating addition is not associative, so the megakernel's "
            "decomposed masked-conv accumulation could drift from the "
            "composed words (same contract as FcnSweep)")
    if tile is None:
        th, tw = choose_tile(H, W)
    else:
        th, tw = tile
        if th % 4 or tw % 4 or th < 4 or tw < 4 or H % th or W % tw:
            raise ValueError(
                f"tile {th}x{tw} must be multiples of 4 dividing the "
                f"{H}x{W} frame")
    return _frame_trunk_jit(x, w1, b1, w2, b2, cfg=cfg, th=th, tw=tw,
                            interpret=runtime.resolve_interpret(interpret))
