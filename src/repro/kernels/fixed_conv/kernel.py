"""Fused Qm.n fixed-point conv pipeline as a Pallas kernel — the paper's
Verilog datapath (§III-B, Fig. 4) as ONE kernel launch, entirely in int32.

Pipeline stages, fused per program instance (one image per grid step):

  windowing      -> four static shifted VMEM views of the SAME-padded block
                    (the Verilog line buffer becomes `x[dh:dh+H, dw:dw+W]`)
  parallel MAC   -> per-tap 32x32 fixed multiply via 16-BIT LIMB
                    DECOMPOSITION (below), int32 wraparound accumulate —
                    the DSP MAC array, one tap per unrolled step
  bias add       -> `fixed_add` (wraparound, or sign-checked saturation)
  PLAN sigmoid   -> shift-add piecewise-linear unit (optional epilogue)
  maxpool 2x2/2  -> 3-comparator tree over strided views (optional epilogue)

Why the limb decomposition: a Qm.n product needs the full 64-bit result of a
32x32 multiply before the >> frac_bits renormalization, but the TPU (and
JAX without x64) only has 32-bit integer lanes.  So `fixed_point
._full_mul_shift` splits each operand into an unsigned low limb (16 bits)
and a signed high limb and reassembles

    a*b = ah*bh*2^32 + (ah*bl + al*bh)*2^16 + al*bl   (mod 2^32 after >>),

where every partial product provably fits 32 bits.  The kernel body calls
the SAME `fixed_point` helpers the emulated "fixed" backend uses, so the two
substrates cannot drift: any future change to the arithmetic lands on both.

Why interpret mode is bit-identical to compiled mode: every op in the
pipeline is integer (shifts, masks, adds, compares, bitcasts) — there is no
floating-point reassociation, no MXU accumulation-order freedom, nothing
with rounding latitude.  Integer two's-complement ops have exactly one
defined result, so the Pallas interpreter on CPU and the compiled TPU kernel
produce the same words.  (The only float in sight is the documented f32
magnitude *heuristic* that drives the optional saturation decision; it is
elementwise and identically evaluated on both substrates.)

Grid: (batch,) with whole spatial dims in VMEM, mirroring kernels/conv2d;
the ops.py wrapper enforces the VMEM budget and handles padding/stride.

Granularity note: this kernel fuses ONE pipeline stage per launch (the
deployed 28x28 classifier runs two of them).  `kernels/frame_trunk` is the
whole-frame sibling: both trunk stages plus the sweep's quad role maps over
a spatially TILED big frame in a single launch, built from the same
`fixed_point` helpers — so the two fusion granularities share one
arithmetic definition and cannot drift.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import fixed_point as fxp

_TAPS = ((0, 0), (0, 1), (1, 0), (1, 1))   # (dh, dw) per 2x2 kernel tap


def _pool2x2(y: jnp.ndarray) -> jnp.ndarray:
    """3-comparator tree on even-cropped (H, W); exact for int words."""
    H, W = y.shape
    y = y[:H - H % 2, :W - W % 2]
    return jnp.maximum(jnp.maximum(y[::2, ::2], y[::2, 1::2]),
                       jnp.maximum(y[1::2, ::2], y[1::2, 1::2]))


def _fixed_conv_kernel(x_ref, w_ref, b_ref, o_ref, *,
                       cfg: fxp.FixedPointConfig, activation: str | None,
                       pool: bool):
    x = x_ref[0]                                       # (H+1, W+1) int32
    H = x.shape[0] - 1
    W = x.shape[1] - 1
    acc = jnp.zeros((H, W), jnp.int32)
    for t, (dh, dw) in enumerate(_TAPS):               # unrolled MAC taps
        win = x[dh:dh + H, dw:dw + W]                  # windowing module
        acc = acc + fxp.fixed_mul(win, w_ref[t], cfg)  # limb MAC, int32 wrap
    y = fxp.fixed_add(acc, b_ref[0], cfg)              # bias add
    if activation == "plan":
        y = fxp.fixed_sigmoid_plan(y, cfg)             # shift-add PLAN unit
    if pool:
        y = _pool2x2(y)                                # comparator tree
    o_ref[...] = y[None]


def fixed_conv2d_pallas(x: jnp.ndarray, w4: jnp.ndarray, b: jnp.ndarray, *,
                        cfg: fxp.FixedPointConfig = fxp.Q16_16,
                        activation: str | None = None, pool: bool = False,
                        interpret: bool = True) -> jnp.ndarray:
    """x (B, H+1, W+1) int32 pre-padded (SAME: 0 after); w4 (4,) int32 taps;
    b (1,) int32 bias word.  Returns (B, H, W) int32, or the pooled
    (B, H//2, W//2) when `pool` fuses the comparator-tree stage."""
    B, Hp, Wp = x.shape
    H, W = Hp - 1, Wp - 1
    Ho, Wo = (H // 2, W // 2) if pool else (H, W)
    kern = functools.partial(_fixed_conv_kernel, cfg=cfg,
                             activation=activation, pool=pool)
    return pl.pallas_call(
        kern,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, Hp, Wp), lambda i: (i, 0, 0)),
            pl.BlockSpec((4,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, Ho, Wo), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Ho, Wo), jnp.int32),
        interpret=interpret,
    )(x, w4, b)


def _fixed_pool_kernel(x_ref, o_ref):
    o_ref[...] = _pool2x2(x_ref[0])[None]


def fixed_maxpool2x2_pallas(x: jnp.ndarray, *,
                            interpret: bool = True) -> jnp.ndarray:
    """x (B, H, W) int32, H/W even (wrapper crops) -> (B, H/2, W/2)."""
    B, H, W = x.shape
    return pl.pallas_call(
        _fixed_pool_kernel,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, H, W), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, H // 2, W // 2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H // 2, W // 2), jnp.int32),
        interpret=interpret,
    )(x)


def _fixed_plan_kernel(x_ref, o_ref, *, cfg: fxp.FixedPointConfig):
    o_ref[...] = fxp.fixed_sigmoid_plan(x_ref[...], cfg)


def fixed_sigmoid_plan_pallas(x: jnp.ndarray, *,
                              cfg: fxp.FixedPointConfig = fxp.Q16_16,
                              block_rows: int = 256,
                              interpret: bool = True) -> jnp.ndarray:
    """x (R, C) int32, R a multiple of block_rows (wrapper pads) -> int32
    PLAN sigmoid words, the VPU shift-add activation unit."""
    R, C = x.shape
    return pl.pallas_call(
        functools.partial(_fixed_plan_kernel, cfg=cfg),
        grid=(R // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.int32),
        interpret=interpret,
    )(x)
