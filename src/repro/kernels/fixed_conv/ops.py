"""jit'd public wrappers for the fused fixed-point Pallas pipeline.

Handles SAME padding (Keras even-kernel convention: 0 before, 1 after),
stride (output decimation — unlike the float kernels/conv2d, which realizes
stride natively, this path still decimates a stride-1 output and budgets
VMEM for the PRE-decimation block; smallNet's fixed pipeline uses the fused
pool, not strides, so the wasted work is zero on the deployed graph), the
optional fused PLAN + maxpool epilogues, and scalar/word-shape plumbing.

`FixedPointConfig` is a frozen dataclass, so it rides through `jax.jit` as a
static argument — one compiled executable per (shape, format, mode).

Spatial extent is fully general: the FCN frame sweep runs these launches
over whole HxW frames (112x112 streaming frames use ~400 KB of the 14 MB
budget, including the limb temporaries; the check trips a little past
670x670), and the fused `pool=True` epilogue crops odd extents to even
exactly like the emulated `maxpool_fixed`.

These are the PER-STAGE launches; `kernels/frame_trunk` fuses BOTH trunk
stages (and all of the sweep's role maps) over a spatially tiled big frame
into one launch, reusing the same `fixed_point` word semantics — the
relationship mirrors `conv2d` <-> `fixed_conv`: same arithmetic contract,
different fusion granularity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fxp
from repro.core import runtime
from repro.kernels.fixed_conv.kernel import (fixed_conv2d_pallas,
                                             fixed_maxpool2x2_pallas,
                                             fixed_sigmoid_plan_pallas)

_VMEM_BUDGET = 14 * 2 ** 20  # leave headroom out of ~16 MB/core

_ACTIVATIONS = (None, "plan")


def _check_vmem(Hp: int, Wp: int, H1: int, W1: int) -> None:
    # padded input + int32 accumulator + the worst-case limb temporaries of
    # one tap's fixed multiply (~6 extra (H,W) int32 arrays), all x4 bytes.
    vmem = (Hp * Wp + 7 * H1 * W1) * 4
    if vmem > _VMEM_BUDGET:
        raise ValueError(
            f"image block exceeds VMEM budget: {vmem} B "
            f"(input {Hp}x{Wp} + pre-decimation output {H1}x{W1} "
            "with limb temporaries)")


def fixed_conv2d(x: jnp.ndarray, w4: jnp.ndarray, b: jnp.ndarray, *,
                 cfg: fxp.FixedPointConfig = fxp.Q16_16,
                 activation: str | None = None, pool: bool = False,
                 stride: int = 1,
                 interpret: bool | None = None) -> jnp.ndarray:
    """Fused fixed-point 2x2 SAME conv: (B,H,W) int32 -> (B,H,W) int32.

    `activation="plan"` fuses the shift-add PLAN sigmoid epilogue;
    `pool=True` additionally fuses the 2x2/2 comparator-tree maxpool
    (output (B, H//2, W//2)); `stride>1` decimates the full stride-1 output
    (mutually exclusive with `pool`).  Bit-exact with the emulated "fixed"
    backend (`backends.conv_fixed` et al.) in every format/mode, and with
    the `kernels/frame_trunk` megakernel that fuses both trunk stages.
    `interpret=None` follows the `core.runtime` process default.
    """
    return _fixed_conv2d_jit(x, w4, b, cfg=cfg, activation=activation,
                             pool=pool, stride=stride,
                             interpret=runtime.resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("cfg", "activation", "pool",
                                             "stride", "interpret"))
def _fixed_conv2d_jit(x: jnp.ndarray, w4: jnp.ndarray, b: jnp.ndarray, *,
                      cfg: fxp.FixedPointConfig, activation: str | None,
                      pool: bool, stride: int,
                      interpret: bool) -> jnp.ndarray:
    if activation not in _ACTIVATIONS:
        raise ValueError(f"activation must be one of {_ACTIVATIONS}")
    if pool and stride > 1:
        raise ValueError("pool and stride>1 cannot be combined")
    B, H, W = x.shape
    _check_vmem(H + 1, W + 1, H, W)
    xp = jnp.pad(x.astype(jnp.int32), ((0, 0), (0, 1), (0, 1)))  # SAME 0-after
    y = fixed_conv2d_pallas(xp, w4.reshape(4).astype(jnp.int32),
                            b.reshape(1).astype(jnp.int32), cfg=cfg,
                            activation=activation, pool=pool,
                            interpret=interpret)
    if stride > 1:
        y = y[:, ::stride, ::stride]                  # output decimation
    return y


def fixed_maxpool2x2(x: jnp.ndarray, *,
                     interpret: bool | None = None) -> jnp.ndarray:
    """(B, H, W) int32 -> (B, H//2, W//2), VALID 2x2/2 comparator tree."""
    return _fixed_maxpool2x2_jit(
        x, interpret=runtime.resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _fixed_maxpool2x2_jit(x: jnp.ndarray, *, interpret: bool) -> jnp.ndarray:
    B, H, W = x.shape
    He, We = H - H % 2, W - W % 2
    return fixed_maxpool2x2_pallas(x[:, :He, :We].astype(jnp.int32),
                                   interpret=interpret)


def fixed_sigmoid(x: jnp.ndarray, *,
                  cfg: fxp.FixedPointConfig = fxp.Q16_16,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Standalone PLAN sigmoid launch over any-shaped int32 words."""
    return _fixed_sigmoid_jit(x, cfg=cfg,
                              interpret=runtime.resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def _fixed_sigmoid_jit(x: jnp.ndarray, *, cfg: fxp.FixedPointConfig,
                       interpret: bool) -> jnp.ndarray:
    shape = x.shape
    C = shape[-1] if len(shape) > 1 else 1
    x2 = x.astype(jnp.int32).reshape(-1, C)
    R = x2.shape[0]
    block = min(256, R)
    Rp = (R + block - 1) // block * block
    y = fixed_sigmoid_plan_pallas(jnp.pad(x2, ((0, Rp - R), (0, 0))),
                                  cfg=cfg, block_rows=block,
                                  interpret=interpret)
    return y[:R].reshape(shape)
