"""Numpy int64 oracle for the fixed-point conv pipeline.

Every function here mirrors, word for word, the emulated Qm.n semantics in
`core/fixed_point.py` / `core/backends.py` — but computed in plain numpy
int64 where the full 62-bit products exist without limb tricks.  The Pallas
kernels and the emulated jnp path are both tested against THIS module, so a
bug in the limb decomposition cannot hide behind a matching bug in the
reference.

Semantics pinned here (the contract of the fixed datapath):

  * products: exact int64 `a*b`, arithmetic shift by frac_bits; round-nearest
    adds bit (frac_bits-1) of the full product; result wrapped to total_bits
    (two's complement).
  * saturating mul: the saturation DECISION is the float32 magnitude
    heuristic from `fixed_point.fixed_mul` (f32(a)*f32(b)/scale compared
    against f32(max_int)/f32(min_int)), reproduced here with explicit
    float32 casts so the boundary behaviour matches bit-for-bit.
  * adds: int32 wraparound; saturating add checks operand/result signs in
    the 32-bit domain BEFORE the final wrap to total_bits (exactly what
    `fixed_add` does — for sub-32-bit formats this means the int32 add never
    overflows and the word simply wraps at total_bits).
  * MAC accumulate: per-product wrap to total_bits, then int32 (mod 2^32)
    accumulation, with the final wrap to total_bits applied after the bias
    add — the order `conv_fixed` / `fixed_matmul` use.
  * PLAN sigmoid: shift-add only; the slope shifts follow round_nearest via
    the same "add bit (k-1)" rule as the products.
"""
from __future__ import annotations

import numpy as np

from repro.core.fixed_point import FixedPointConfig, Q16_16


def random_words(rng, shape, cfg: FixedPointConfig, extremes: int = 6) -> np.ndarray:
    """Random valid Qm.n words with max_int/min_int injected so wraparound
    (and the saturation decision) is exercised, not just smooth-range
    values.  Shared by the golden-vector generator and the parity tests."""
    x = rng.integers(cfg.min_int, cfg.max_int + 1, shape).astype(np.int64)
    flat = x.reshape(-1)
    idx = rng.choice(flat.size, size=min(extremes, flat.size), replace=False)
    for j, i in enumerate(idx):
        flat[i] = cfg.max_int if j % 2 == 0 else cfg.min_int
    return flat.reshape(shape)


def wrap_bits_ref(x: np.ndarray, total_bits: int) -> np.ndarray:
    """Two's-complement wrap of int64 values to `total_bits` (sign-extended)."""
    m = np.int64(1) << total_bits
    half = m >> 1
    return ((x.astype(np.int64) + half) % m - half).astype(np.int64)


def _shift_round_ref(x: np.ndarray, k: int, round_nearest: bool) -> np.ndarray:
    x = x.astype(np.int64)
    if k == 0 or not round_nearest:
        return x >> k
    return (x >> k) + ((x >> (k - 1)) & 1)


def to_fixed_ref(x, cfg: FixedPointConfig = Q16_16) -> np.ndarray:
    scaled = np.round(np.asarray(x, np.float32) * np.float32(cfg.scale))
    scaled = np.clip(scaled, np.float32(cfg.min_int), np.float32(cfg.max_int))
    return wrap_bits_ref(scaled.astype(np.int64), cfg.total_bits)


def fixed_mul_ref(a: np.ndarray, b: np.ndarray,
                  cfg: FixedPointConfig = Q16_16) -> np.ndarray:
    a = np.asarray(a, np.int64)
    b = np.asarray(b, np.int64)
    full = a * b                                     # exact: |full| < 2^62
    p = _shift_round_ref(full, cfg.frac_bits, cfg.round_nearest)
    p = wrap_bits_ref(p, 32)                         # the int32 word
    if cfg.saturate:
        # float32 magnitude heuristic, float32 thresholds (matches jnp's
        # weak-typed comparison where max_int rounds to 2^31 in f32)
        approx = (a.astype(np.float32) * b.astype(np.float32)
                  / np.float32(cfg.scale))
        p = np.where(approx > np.float32(cfg.max_int), cfg.max_int,
                     np.where(approx < np.float32(cfg.min_int), cfg.min_int,
                              p)).astype(np.int64)
    return wrap_bits_ref(p, cfg.total_bits)


def fixed_add_ref(a: np.ndarray, b: np.ndarray,
                  cfg: FixedPointConfig = Q16_16) -> np.ndarray:
    a = np.asarray(a, np.int64)
    b = np.asarray(b, np.int64)
    s = wrap_bits_ref(a + b, 32)                     # int32 wraparound add
    if cfg.saturate:
        ovf = (np.sign(a) == np.sign(b)) & (np.sign(s) != np.sign(a)) & (a != 0)
        sat = np.where(a > 0, cfg.max_int, cfg.min_int).astype(np.int64)
        s = np.where(ovf, sat, s)
    return wrap_bits_ref(s, cfg.total_bits)


def windows_2x2_same_ref(x: np.ndarray) -> np.ndarray:
    """(B,H,W) -> (B,H,W,4) of 2x2 SAME patches (0 before, 1 after pad)."""
    xp = np.pad(np.asarray(x, np.int64), ((0, 0), (0, 1), (0, 1)))
    return np.stack([xp[:, :-1, :-1], xp[:, :-1, 1:],
                     xp[:, 1:, :-1], xp[:, 1:, 1:]], axis=-1)


def fixed_sigmoid_plan_ref(x: np.ndarray,
                           cfg: FixedPointConfig = Q16_16) -> np.ndarray:
    x = np.asarray(x, np.int64)
    # jnp.abs on int32 wraps at INT32_MIN (|-2^31| stays -2^31); mirror it
    ax = wrap_bits_ref(np.abs(x), 32)
    rn = cfg.round_nearest
    one = int(to_fixed_ref(1.0, cfg)) if cfg.int_bits >= 1 else cfg.max_int
    y = np.where(
        ax >= int(to_fixed_ref(5.0, cfg)), one,
        np.where(
            ax >= int(to_fixed_ref(2.375, cfg)),
            _shift_round_ref(ax, 5, rn) + int(to_fixed_ref(0.84375, cfg)),
            np.where(
                ax >= int(to_fixed_ref(1.0, cfg)),
                _shift_round_ref(ax, 3, rn) + int(to_fixed_ref(0.625, cfg)),
                _shift_round_ref(ax, 2, rn) + int(to_fixed_ref(0.5, cfg)))))
    # the emulated path computes `one - y` in int32; wrap to match
    return wrap_bits_ref(np.where(x < 0, one - y, y), 32)


def fixed_maxpool2x2_ref(x: np.ndarray) -> np.ndarray:
    """(B,H,W) int -> (B,H//2,W//2): comparator tree (odd trailing row/col
    cropped, VALID semantics)."""
    x = np.asarray(x, np.int64)
    B, H, W = x.shape
    x = x[:, :H - H % 2, :W - W % 2]
    return np.maximum(np.maximum(x[:, ::2, ::2], x[:, ::2, 1::2]),
                      np.maximum(x[:, 1::2, ::2], x[:, 1::2, 1::2]))


def fixed_conv2d_ref(x: np.ndarray, w4: np.ndarray, b,
                     cfg: FixedPointConfig = Q16_16, *,
                     activation: str | None = None, pool: bool = False,
                     stride: int = 1) -> np.ndarray:
    """The full pipeline oracle: windowing -> MAC -> bias -> [PLAN] -> [pool].

    x (B,H,W) int words; w4 (4,) taps; b scalar bias word.  Matches the
    emulated `backends.conv_fixed` + `fixed_sigmoid_plan` + `maxpool_fixed`
    composition word-for-word.
    """
    if pool and stride > 1:
        raise ValueError("pool and stride>1 cannot be combined")
    win = windows_2x2_same_ref(x)                    # (B,H,W,4)
    prods = np.stack(
        [fixed_mul_ref(win[..., t], np.int64(w4[t]), cfg) for t in range(4)],
        axis=-1)
    acc = wrap_bits_ref(prods.sum(axis=-1), 32)      # int32 MAC accumulate
    y = fixed_add_ref(acc, np.int64(b), cfg)
    if activation == "plan":
        y = fixed_sigmoid_plan_ref(y, cfg)
    elif activation is not None:
        raise ValueError(activation)
    if stride > 1:
        y = y[:, ::stride, ::stride]
    if pool:
        y = fixed_maxpool2x2_ref(y)
    return y


def fixed_dense_ref(x: np.ndarray, w: np.ndarray, b: np.ndarray,
                    cfg: FixedPointConfig = Q16_16) -> np.ndarray:
    """(B,K) @ (K,N) + b, fixed-point MAC array semantics (per-product wrap
    to total_bits, int32 accumulate, wrap, bias add)."""
    x = np.asarray(x, np.int64)
    w = np.asarray(w, np.int64)
    prods = fixed_mul_ref(x[:, :, None], w[None, :, :], cfg)   # (B,K,N)
    acc = wrap_bits_ref(wrap_bits_ref(prods.sum(axis=1), 32), cfg.total_bits)
    return fixed_add_ref(acc, np.asarray(b, np.int64).reshape(1, -1), cfg)
