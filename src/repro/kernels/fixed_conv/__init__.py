from repro.kernels.fixed_conv.ops import (fixed_conv2d, fixed_maxpool2x2,
                                          fixed_sigmoid)
from repro.kernels.fixed_conv.ref import (fixed_conv2d_ref, fixed_dense_ref,
                                          fixed_maxpool2x2_ref,
                                          fixed_sigmoid_plan_ref)
