"""int8 x int8 -> int32 tiled matmul with fused per-channel dequant.

The TPU-native generalization of the paper's fixed-point MAC array: match
the numeric format to the native multiplier.  The Zynq DSP48 is a 25x18-bit
multiplier, hence the paper's fixed-point ints; the MXU's cheap multiplier is
int8 (2x the bf16 rate on v5e), hence int8 storage with exact int32
accumulation — same co-design insight, different optimum.

Tiling: grid (M/bm, N/bn, K/bk), K innermost so the (bm, bn) int32
accumulator stays resident in VMEM scratch across the K sweep (the MXU
analogue of the DSP accumulator register), with a fused dequant epilogue on
the last K step.  Block sizes are MXU-aligned (multiples of 8 x 128; int8
lanes pack 32x128 tiles natively).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import fixed_point as fxp


def _qmm_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _epilogue():
        # fused dequant: int32 accumulator * (row scale x col scale)
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * sx_ref[...].reshape(-1, 1) * sw_ref[...].reshape(1, -1))


def quant_matmul_pallas(xq: jnp.ndarray, wq: jnp.ndarray,
                        sx: jnp.ndarray, sw: jnp.ndarray, *,
                        bm: int = 256, bn: int = 256, bk: int = 512,
                        interpret: bool = True) -> jnp.ndarray:
    """xq (M,K) int8, wq (K,N) int8, sx (M,) f32 row scales, sw (N,) f32
    per-channel scales -> (M,N) f32.  M,K,N must be multiples of the block
    sizes (the ops.py wrapper pads)."""
    M, K = xq.shape
    _, N = wq.shape
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _qmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm,), lambda i, j, k: (i,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(xq, wq, sx, sw)


# ---------------------------------------------------------------------------
# Fixed-point (Qm.n) dense MAC — the int32 sibling of the int8 kernel above
# ---------------------------------------------------------------------------

def _fixed_mm_kernel(x_ref, w_ref, b_ref, o_ref, *, cfg: fxp.FixedPointConfig):
    """One batch block: the Qm.n MAC array + bias add, inside the launch.

    This CANNOT use `jnp.dot`: the Qm.n MAC renormalizes (>> frac_bits,
    wrap) EVERY product before accumulating, exactly like the paper's DSP
    array — so the kernel body calls the SAME `fixed_matmul`/`fixed_add`
    the emulated "fixed" backend uses (bit-exactness by construction).
    Every op is integer -> interpret mode is bit-identical to compiled.
    """
    y = fxp.fixed_matmul(x_ref[...], w_ref[...], cfg)          # (bm, N)
    o_ref[...] = fxp.fixed_add(y, b_ref[...].reshape(1, -1), cfg)


def fixed_matmul_pallas(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, *,
                        cfg: fxp.FixedPointConfig = fxp.Q16_16,
                        bm: int = 128, interpret: bool = True) -> jnp.ndarray:
    """x (M,K) int32 Qm.n, w (K,N) int32, b (N,) int32 -> (M,N) int32.
    M must be a multiple of bm (the ops.py wrapper pads); K and N stay whole
    so the per-row MAC sweep lives in one program instance."""
    M, K = x.shape
    _, N = w.shape
    return pl.pallas_call(
        functools.partial(_fixed_mm_kernel, cfg=cfg),
        grid=(M // bm,),
        in_specs=[
            pl.BlockSpec((bm, K), lambda i: (i, 0)),
            pl.BlockSpec((K, N), lambda i: (0, 0)),
            pl.BlockSpec((N,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(x, w, b)
