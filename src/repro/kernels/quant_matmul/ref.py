"""Pure-jnp oracle for quant_matmul."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quant_matmul_ref(xq: jnp.ndarray, wq: jnp.ndarray,
                     sx: jnp.ndarray | float = 1.0,
                     sw: jnp.ndarray | float = 1.0) -> jnp.ndarray:
    M, _ = xq.shape
    _, N = wq.shape
    sx = jnp.broadcast_to(jnp.asarray(sx, jnp.float32).reshape(-1), (M,))
    sw = jnp.broadcast_to(jnp.asarray(sw, jnp.float32).reshape(-1), (N,))
    acc = jax.lax.dot_general(xq, wq, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * sx[:, None] * sw[None, :]
