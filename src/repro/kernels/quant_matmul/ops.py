"""jit'd wrappers: pad to aligned tiles, pick block sizes, slice back.

`quant_matmul` is the int8 PTQ dense MAC; `fixed_dense` is its Qm.n int32
sibling — the smallNet dense layer as a single fixed-point Pallas launch,
bit-exact with the emulated `fixed_point.fixed_matmul` + `fixed_add` path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fxp
from repro.core import runtime
from repro.kernels.quant_matmul.kernel import (fixed_matmul_pallas,
                                               quant_matmul_pallas)

_FIXED_VMEM_BUDGET = 14 * 2 ** 20


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def quant_matmul(xq: jnp.ndarray, wq: jnp.ndarray,
                 sx: jnp.ndarray | float = 1.0,
                 sw: jnp.ndarray | float = 1.0, *,
                 interpret: bool | None = None) -> jnp.ndarray:
    """Dequantized f32 = (xq @ wq) * sx[:,None] * sw[None,:].

    xq (M,K) int8; wq (K,N) int8; sx scalar or (M,); sw scalar or (N,).
    `interpret=None` follows the `core.runtime` process default.
    """
    return _quant_matmul_jit(xq, wq, sx, sw,
                             interpret=runtime.resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _quant_matmul_jit(xq: jnp.ndarray, wq: jnp.ndarray,
                      sx: jnp.ndarray | float,
                      sw: jnp.ndarray | float, *,
                      interpret: bool) -> jnp.ndarray:
    M, K = xq.shape
    _, N = wq.shape
    sx = jnp.broadcast_to(jnp.asarray(sx, jnp.float32).reshape(-1), (M,)) \
        if jnp.ndim(sx) <= 1 else sx
    sw = jnp.broadcast_to(jnp.asarray(sw, jnp.float32).reshape(-1), (N,)) \
        if jnp.ndim(sw) <= 1 else sw
    bm = min(256, _round_up(M, 8))
    bn = min(256, _round_up(N, 128))
    bk = min(512, _round_up(K, 128))
    Mp, Kp, Np = _round_up(M, bm), _round_up(K, bk), _round_up(N, bn)
    xp = jnp.pad(xq, ((0, Mp - M), (0, Kp - K)))
    wp = jnp.pad(wq, ((0, Kp - K), (0, Np - N)))
    sxp = jnp.pad(sx, (0, Mp - M))
    swp = jnp.pad(sw, (0, Np - N))
    y = quant_matmul_pallas(xp, wp, sxp, swp, bm=bm, bn=bn, bk=bk,
                            interpret=interpret)
    return y[:M, :N]


def fixed_dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None = None,
                *, cfg: fxp.FixedPointConfig = fxp.Q16_16,
                interpret: bool | None = None) -> jnp.ndarray:
    """Fixed-point dense layer launch: (M,K) @ (K,N) + b, all int32 Qm.n.

    Zero-pads the batch to the block size (a zero row is a valid fixed word
    vector, so padded rows are just discarded work) and slices back.
    `interpret=None` follows the `core.runtime` process default.
    """
    return _fixed_dense_jit(x, w, b, cfg=cfg,
                            interpret=runtime.resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def _fixed_dense_jit(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None,
                     *, cfg: fxp.FixedPointConfig,
                     interpret: bool) -> jnp.ndarray:
    M, K = x.shape
    _, N = w.shape
    if b is None:
        b = jnp.zeros((N,), jnp.int32)
    bm = min(128, M)
    Mp = (M + bm - 1) // bm * bm
    # the (bm, K, N) per-product intermediate plus ~6 limb temporaries
    vmem = (bm * K * N * 7 + K * N) * 4
    if vmem > _FIXED_VMEM_BUDGET:
        raise ValueError(
            f"fixed_dense block exceeds VMEM budget: {vmem} B "
            f"(bm={bm}, K={K}, N={N} with limb temporaries)")
    y = fixed_matmul_pallas(
        jnp.pad(x.astype(jnp.int32), ((0, Mp - M), (0, 0))),
        w.astype(jnp.int32), b.reshape(N).astype(jnp.int32),
        cfg=cfg, bm=bm, interpret=interpret)
    return y[:M]
