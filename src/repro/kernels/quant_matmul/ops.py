"""jit'd wrapper: pads to MXU-aligned tiles, picks block sizes, slices back."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.quant_matmul.kernel import quant_matmul_pallas


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("interpret",))
def quant_matmul(xq: jnp.ndarray, wq: jnp.ndarray,
                 sx: jnp.ndarray | float = 1.0,
                 sw: jnp.ndarray | float = 1.0, *,
                 interpret: bool = True) -> jnp.ndarray:
    """Dequantized f32 = (xq @ wq) * sx[:,None] * sw[None,:].

    xq (M,K) int8; wq (K,N) int8; sx scalar or (M,); sw scalar or (N,).
    """
    M, K = xq.shape
    _, N = wq.shape
    sx = jnp.broadcast_to(jnp.asarray(sx, jnp.float32).reshape(-1), (M,)) \
        if jnp.ndim(sx) <= 1 else sx
    sw = jnp.broadcast_to(jnp.asarray(sw, jnp.float32).reshape(-1), (N,)) \
        if jnp.ndim(sw) <= 1 else sw
    bm = min(256, _round_up(M, 8))
    bn = min(256, _round_up(N, 128))
    bk = min(512, _round_up(K, 128))
    Mp, Kp, Np = _round_up(M, bm), _round_up(K, bk), _round_up(N, bn)
    xp = jnp.pad(xq, ((0, Mp - M), (0, Kp - K)))
    wp = jnp.pad(wq, ((0, Kp - K), (0, Np - N)))
    sxp = jnp.pad(sx, (0, Mp - M))
    swp = jnp.pad(sw, (0, Np - N))
    y = quant_matmul_pallas(xp, wp, sxp, swp, bm=bm, bn=bn, bk=bk,
                            interpret=interpret)
    return y[:M, :N]
