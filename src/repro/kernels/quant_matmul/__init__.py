from repro.kernels.quant_matmul.ops import fixed_dense, quant_matmul
from repro.kernels.quant_matmul.ref import quant_matmul_ref
