"""Pure-jnp oracle for maxpool2d."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def maxpool2d_ref(x: jnp.ndarray) -> jnp.ndarray:
    B, H, W, C = x.shape
    x = x[:, :H - H % 2, :W - W % 2, :]
    init = jnp.asarray(-jnp.inf, x.dtype) if jnp.issubdtype(x.dtype, jnp.floating) \
        else jnp.iinfo(x.dtype).min
    return jax.lax.reduce_window(x, init, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
