"""2x2/2 max pooling as a Pallas kernel — the paper's comparator-tree block.

One program instance pools one image; the 2x2 window is realized as a
3-comparator tree over four strided VMEM views (exactly the FPGA structure,
but vectorized over the whole feature map on the VPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pool_kernel(x_ref, o_ref):
    x = x_ref[0]
    a = jnp.maximum(x[::2, ::2, :], x[::2, 1::2, :])
    b = jnp.maximum(x[1::2, ::2, :], x[1::2, 1::2, :])
    o_ref[...] = jnp.maximum(a, b)[None]


def maxpool2d_pallas(x: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """x (B, H, W, C) with H, W even -> (B, H/2, W/2, C)."""
    B, H, W, C = x.shape
    return pl.pallas_call(
        _pool_kernel,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, H, W, C), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, H // 2, W // 2, C), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H // 2, W // 2, C), x.dtype),
        interpret=interpret,
    )(x)
