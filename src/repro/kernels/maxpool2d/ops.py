"""jit'd wrapper: pads odd spatial dims (VALID-crop semantics preserved)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import runtime
from repro.kernels.maxpool2d.kernel import maxpool2d_pallas


def maxpool2d(x: jnp.ndarray, *, interpret: bool | None = None) -> jnp.ndarray:
    """(B, H, W, C) -> (B, H//2, W//2, C), VALID 2x2/2 max pool.
    `interpret=None` follows the `core.runtime` process default."""
    return _maxpool2d_jit(x, interpret=runtime.resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _maxpool2d_jit(x: jnp.ndarray, *, interpret: bool) -> jnp.ndarray:
    B, H, W, C = x.shape
    He, We = H - H % 2, W - W % 2
    return maxpool2d_pallas(x[:, :He, :We, :], interpret=interpret)
