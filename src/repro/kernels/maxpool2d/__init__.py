from repro.kernels.maxpool2d.ops import maxpool2d
from repro.kernels.maxpool2d.ref import maxpool2d_ref
