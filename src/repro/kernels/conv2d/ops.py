"""jit'd public wrapper for the Pallas conv2d kernel.

Handles SAME padding (Keras even-kernel convention: 0 before, 1 after),
stride (via output decimation for the small strides this model family uses),
and the VMEM-budget check for the whole-image blocking strategy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.conv2d.kernel import conv2d_pallas

_VMEM_BUDGET = 14 * 2 ** 20  # leave headroom out of ~16 MB/core


@functools.partial(jax.jit, static_argnames=("stride", "padding",
                                             "apply_sigmoid", "interpret"))
def conv2d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None = None, *,
           stride: int = 1, padding: str = "SAME",
           apply_sigmoid: bool = False, interpret: bool = True) -> jnp.ndarray:
    """NHWC x HWIO -> NHWC, f32. Pallas windowing+MAC kernel."""
    kh, kw, cin, cout = w.shape
    if b is None:
        b = jnp.zeros((cout,), jnp.float32)
    if padding == "SAME":
        x = jnp.pad(x, ((0, 0), (0, kh - 1), (0, kw - 1), (0, 0)))
    elif padding != "VALID":
        raise ValueError(padding)
    B, Hp, Wp, _ = x.shape
    vmem = (Hp * Wp * cin + (Hp - kh + 1) * (Wp - kw + 1) * cout) * 4
    if vmem > _VMEM_BUDGET:
        raise ValueError(f"image block exceeds VMEM budget: {vmem} B")
    y = conv2d_pallas(x.astype(jnp.float32), w.astype(jnp.float32),
                      b.astype(jnp.float32), apply_sigmoid=apply_sigmoid,
                      interpret=interpret)
    if stride > 1:
        y = y[:, ::stride, ::stride, :]
    return y
