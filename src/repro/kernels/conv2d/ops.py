"""jit'd public wrapper for the Pallas conv2d kernel.

Handles SAME padding (Keras even-kernel convention: 0 before, 1 after),
stride, the fused activation epilogue, and the VMEM-budget check for the
whole-image blocking strategy.

Stride limitation (documented): the kernel always computes the FULL stride-1
output and decimates it afterwards (`y[:, ::stride, ::stride]`).  That is
exact, and cheap for this model family's small strides, but the work (and
the VMEM) for the discarded rows/columns is still spent — so the VMEM
budget check accounts for the PRE-decimation output block, not the smaller
strided result.  A natively-strided kernel is future work (see ROADMAP).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.conv2d.kernel import conv2d_pallas

_VMEM_BUDGET = 14 * 2 ** 20  # leave headroom out of ~16 MB/core


@functools.partial(jax.jit, static_argnames=("stride", "padding",
                                             "apply_sigmoid", "activation",
                                             "interpret"))
def conv2d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None = None, *,
           stride: int = 1, padding: str = "SAME",
           apply_sigmoid: bool = False, activation: str | None = None,
           interpret: bool = True) -> jnp.ndarray:
    """NHWC x HWIO -> NHWC, f32. Pallas windowing+MAC kernel.

    `activation` in {None, "sigmoid", "plan"} fuses the activation unit into
    the kernel epilogue (`apply_sigmoid=True` is legacy for "sigmoid").
    """
    kh, kw, cin, cout = w.shape
    if b is None:
        b = jnp.zeros((cout,), jnp.float32)
    if padding == "SAME":
        x = jnp.pad(x, ((0, 0), (0, kh - 1), (0, kw - 1), (0, 0)))
    elif padding != "VALID":
        raise ValueError(padding)
    B, Hp, Wp, _ = x.shape
    # Pre-decimation output block: the kernel materializes the full stride-1
    # result in VMEM even when stride > 1 (see module docstring), so that is
    # what must fit alongside the padded input block.
    H1, W1 = Hp - kh + 1, Wp - kw + 1
    vmem = (Hp * Wp * cin + H1 * W1 * cout) * 4
    if vmem > _VMEM_BUDGET:
        raise ValueError(
            f"image block exceeds VMEM budget: {vmem} B "
            f"(input {Hp}x{Wp}x{cin} + pre-decimation output {H1}x{W1}x{cout})")
    y = conv2d_pallas(x.astype(jnp.float32), w.astype(jnp.float32),
                      b.astype(jnp.float32), apply_sigmoid=apply_sigmoid,
                      activation=activation, interpret=interpret)
    if stride > 1:
        y = y[:, ::stride, ::stride, :]          # output decimation
    return y
