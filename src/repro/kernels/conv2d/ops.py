"""jit'd public wrapper for the Pallas conv2d kernel.

Handles SAME padding (Keras even-kernel convention: 0 before, 1 after),
stride, the fused activation epilogue, and the VMEM-budget check for the
whole-image blocking strategy.

Stride is NATIVE: each kernel tap keeps only every stride-th row/column
before its MXU dot, so the accumulator, the MAC work, and the VMEM output
block all cover just the kept pixels — the full stride-1 grid is never
materialized.  The VMEM budget therefore checks padded input + STRIDED
output, which is what lets coarse-stride sweeps over frame-sized inputs
(512x512 and up) run at all.  Identical values to decimating a stride-1
output, since each output pixel's MAC is independent.

Spatial extent is fully general (nothing here assumes the classifier's
28x28): the streaming FCN sweep (streaming/fcn_sweep.py) runs this kernel
over whole video frames, and the budget arithmetic is the only size gate —
a stride-1 single-channel frame fits up to ~1300x1300 before the check
trips (112x112 streaming frames use ~100 KB of the 14 MB budget).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import runtime
from repro.kernels.conv2d.kernel import conv2d_pallas

_VMEM_BUDGET = 14 * 2 ** 20  # leave headroom out of ~16 MB/core


def conv2d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None = None, *,
           stride: int = 1, padding: str = "SAME",
           apply_sigmoid: bool = False, activation: str | None = None,
           interpret: bool | None = None) -> jnp.ndarray:
    """NHWC x HWIO -> NHWC, f32. Pallas windowing+MAC kernel.

    `activation` in {None, "sigmoid", "plan"} fuses the activation unit into
    the kernel epilogue (`apply_sigmoid=True` is legacy for "sigmoid").
    `interpret=None` follows the process-wide `core.runtime` switch; the
    flag is resolved HERE, in the un-jitted entry point, so flipping the
    default can never be baked stale into a compiled executable.
    """
    return _conv2d_jit(x, w, b, stride=stride, padding=padding,
                       apply_sigmoid=apply_sigmoid, activation=activation,
                       interpret=runtime.resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("stride", "padding",
                                             "apply_sigmoid", "activation",
                                             "interpret"))
def _conv2d_jit(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None, *,
                stride: int, padding: str, apply_sigmoid: bool,
                activation: str | None, interpret: bool) -> jnp.ndarray:
    kh, kw, cin, cout = w.shape
    if b is None:
        b = jnp.zeros((cout,), jnp.float32)
    if padding == "SAME":
        x = jnp.pad(x, ((0, 0), (0, kh - 1), (0, kw - 1), (0, 0)))
    elif padding != "VALID":
        raise ValueError(padding)
    B, Hp, Wp, _ = x.shape
    # Strided output block: the kernel MACs only the kept rows/columns (see
    # module docstring), so the VMEM check is padded input + strided output.
    H1, W1 = Hp - kh + 1, Wp - kw + 1
    Hs, Ws = -(-H1 // stride), -(-W1 // stride)
    vmem = (Hp * Wp * cin + Hs * Ws * cout) * 4
    if vmem > _VMEM_BUDGET:
        raise ValueError(
            f"image block exceeds VMEM budget: {vmem} B "
            f"(input {Hp}x{Wp}x{cin} + strided output {Hs}x{Ws}x{cout})")
    return conv2d_pallas(x.astype(jnp.float32), w.astype(jnp.float32),
                         b.astype(jnp.float32), stride=stride,
                         apply_sigmoid=apply_sigmoid,
                         activation=activation, interpret=interpret)
