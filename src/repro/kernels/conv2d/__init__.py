from repro.kernels.conv2d.ops import conv2d
from repro.kernels.conv2d.ref import conv2d_ref
