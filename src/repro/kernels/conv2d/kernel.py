"""Windowed conv as a Pallas TPU kernel — the paper's conv datapath on the MXU.

The FPGA design (paper Fig. 4) = windowing module -> parallel MAC array ->
bias -> activation, sequenced by an FSM.  TPU-native mapping:

  windowing module  -> static shifted VMEM views (the line buffer becomes
                       `x_ref[dh:dh+H, dw:dw+W]` slices of the padded block)
  parallel MAC array-> one MXU `jnp.dot` per kernel tap: (H*W, Cin)@(Cin, Cout),
                       accumulated in f32 — KH*KW taps unrolled, exactly the
                       paper's "one MAC per tap" parallelism but systolic
  BRAM feature maps -> VMEM blocks, double-buffered by the Pallas grid
                       pipeline (the grid schedule is the FSM)
  bias + activation -> fused epilogue in the same kernel; `activation` picks
                       the exact sigmoid or the PLAN piecewise-linear unit
                       (the paper's shift-add hardware sigmoid), so the
                       conv+PLAN fast path is a single kernel launch

Grid: (batch,) — each program instance convolves one image; spatial dims are
kept whole in VMEM (checked by the wrapper against the VMEM budget).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fixed_point import sigmoid_plan_f32

_ACTIVATIONS = (None, "sigmoid", "plan")


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, kh: int, kw: int,
                 stride: int, activation: str | None):
    Hs, Ws, cout = o_ref.shape[1], o_ref.shape[2], o_ref.shape[3]
    cin = x_ref.shape[3]
    # kept-pixel spans: output (i,j) reads input (i*stride+dh, j*stride+dw),
    # so each tap loads a contiguous window and keeps every stride-th row/col
    # BEFORE the MXU dot — the accumulator and the MAC work cover only the
    # strided output, never the full stride-1 grid.
    hspan, wspan = (Hs - 1) * stride + 1, (Ws - 1) * stride + 1
    acc = jnp.zeros((Hs * Ws, cout), jnp.float32)
    for dh in range(kh):            # static unroll: the parallel MAC taps
        for dw in range(kw):
            win = x_ref[0, dh:dh + hspan, dw:dw + wspan, :]  # windowing
            win = win[::stride, ::stride]                    # kept rows/cols
            acc = acc + jnp.dot(win.reshape(Hs * Ws, cin), w_ref[dh, dw],
                                preferred_element_type=jnp.float32)
    acc = acc + b_ref[...]                                    # bias add
    if activation == "sigmoid":                               # activation unit
        acc = jax.nn.sigmoid(acc)
    elif activation == "plan":
        acc = sigmoid_plan_f32(acc)
    o_ref[...] = acc.reshape(1, Hs, Ws, cout)


def conv2d_pallas(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, *,
                  stride: int = 1,
                  apply_sigmoid: bool = False,
                  activation: str | None = None,
                  interpret: bool = True) -> jnp.ndarray:
    """x (B, H+kh-1, W+kw-1, Cin) pre-padded; w (kh, kw, Cin, Cout); b (Cout,).
    Returns (B, ceil(H/stride), ceil(W/stride), Cout) f32 — stride is realized
    NATIVELY: only the kept rows/columns are MAC'd and only the strided output
    block lives in VMEM.  `activation` in {None, "sigmoid", "plan"} selects
    the fused epilogue (`apply_sigmoid=True` is legacy spelling for
    "sigmoid")."""
    if activation is None and apply_sigmoid:
        activation = "sigmoid"
    if activation not in _ACTIVATIONS:
        raise ValueError(f"activation must be one of {_ACTIVATIONS}")
    B, Hp, Wp, cin = x.shape
    kh, kw, _, cout = w.shape
    H1, W1 = Hp - kh + 1, Wp - kw + 1
    Hs, Ws = -(-H1 // stride), -(-W1 // stride)   # kept rows/cols (ceil)
    kern = functools.partial(_conv_kernel, kh=kh, kw=kw, stride=stride,
                             activation=activation)
    return pl.pallas_call(
        kern,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, cin), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, cout), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((cout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, Hs, Ws, cout), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hs, Ws, cout), jnp.float32),
        interpret=interpret,
    )(x, w, b)
