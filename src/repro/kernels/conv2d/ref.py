"""Pure-jnp oracle for the conv2d kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.fixed_point import sigmoid_plan_f32


def conv2d_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None = None, *,
               stride: int = 1, padding: str = "SAME",
               apply_sigmoid: bool = False,
               activation: str | None = None) -> jnp.ndarray:
    if activation is None and apply_sigmoid:
        activation = "sigmoid"
    kh, kw, _, cout = w.shape
    if b is None:
        b = jnp.zeros((cout,), jnp.float32)
    pad = ((0, kh - 1), (0, kw - 1)) if padding == "SAME" else ((0, 0), (0, 0))
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32), window_strides=(1, 1),
        padding=pad, dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = y + b.astype(jnp.float32)
    if activation == "sigmoid":
        y = jax.nn.sigmoid(y)
    elif activation == "plan":
        y = sigmoid_plan_f32(y)
    if stride > 1:
        y = y[:, ::stride, ::stride, :]
    return y
