"""PLAN piecewise-linear sigmoid as a Pallas VPU kernel.

The paper's activation block is a logic-level sigmoid; in hardware the
standard realization is the PLAN approximation (shift-add only).  On TPU
this is a VPU (vector unit) elementwise kernel: selects + multiply-adds on
(8,128)-aligned VMEM tiles — included both as the activation epilogue used
by the fixed-point serving path and as the minimal example of a VPU-only
Pallas kernel in this codebase.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _plan_kernel(x_ref, o_ref):
    x = x_ref[...]
    ax = jnp.abs(x)
    y = jnp.where(ax >= 5.0, 1.0,
                  jnp.where(ax >= 2.375, 0.03125 * ax + 0.84375,
                            jnp.where(ax >= 1.0, 0.125 * ax + 0.625,
                                      0.25 * ax + 0.5)))
    o_ref[...] = jnp.where(x < 0, 1.0 - y, y)


def sigmoid_pla_pallas(x: jnp.ndarray, *, block_rows: int = 256,
                       interpret: bool = True) -> jnp.ndarray:
    """x (R, C) f32, R a multiple of block_rows (wrapper pads)."""
    R, C = x.shape
    return pl.pallas_call(
        _plan_kernel,
        grid=(R // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.float32),
        interpret=interpret,
    )(x)
