from repro.kernels.sigmoid_pla.ops import sigmoid_pla
from repro.kernels.sigmoid_pla.ref import sigmoid_pla_ref
