"""Pure-jnp oracle for the PLAN sigmoid kernel (shared with core.fixed_point)."""
from repro.core.fixed_point import sigmoid_plan_f32 as sigmoid_pla_ref  # noqa: F401
