"""jit'd wrapper: reshapes any-rank input onto aligned 2-D tiles."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import runtime
from repro.kernels.sigmoid_pla.kernel import sigmoid_pla_pallas


def sigmoid_pla(x: jnp.ndarray, *, interpret: bool | None = None) -> jnp.ndarray:
    """PLAN sigmoid launch; `interpret=None` follows `core.runtime`."""
    return _sigmoid_pla_jit(x, interpret=runtime.resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _sigmoid_pla_jit(x: jnp.ndarray, *, interpret: bool) -> jnp.ndarray:
    shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    C = 128
    n = flat.shape[0]
    rows = max(1, -(-n // C))
    block = min(256, rows)
    rows_p = -(-rows // block) * block
    pad = rows_p * C - n
    x2 = jnp.pad(flat, (0, pad)).reshape(rows_p, C)
    y = sigmoid_pla_pallas(x2, block_rows=block, interpret=interpret)
    return y.reshape(-1)[:n].reshape(shape)
