# Custom Pallas kernels for the paper's compute hot-spots (conv datapath,
# comparator-tree pool, PLAN sigmoid, int8 MAC array, and the fused Qm.n
# fixed-point pipeline).  Each package pairs a kernel with a jit'd ops
# wrapper and an oracle (pure-jnp, or numpy int64 for the fixed path); the
# backend dispatch layer (core/backends.py) wires the wrappers into the model.
from repro.kernels.conv2d.ops import conv2d
from repro.kernels.conv2d.ref import conv2d_ref
from repro.kernels.fixed_conv.ops import (fixed_conv2d, fixed_maxpool2x2,
                                          fixed_sigmoid)
from repro.kernels.fixed_conv.ref import (fixed_conv2d_ref, fixed_dense_ref,
                                          fixed_maxpool2x2_ref,
                                          fixed_sigmoid_plan_ref)
from repro.kernels.maxpool2d.ops import maxpool2d
from repro.kernels.maxpool2d.ref import maxpool2d_ref
from repro.kernels.quant_matmul.ops import fixed_dense, quant_matmul
from repro.kernels.quant_matmul.ref import quant_matmul_ref
from repro.kernels.sigmoid_pla.ops import sigmoid_pla
from repro.kernels.sigmoid_pla.ref import sigmoid_pla_ref
