# Custom Pallas kernels for the paper's compute hot-spots (conv datapath,
# comparator-tree pool, PLAN sigmoid, int8 MAC array).  Each package pairs a
# kernel with a jit'd ops wrapper and a pure-jnp oracle; the backend
# dispatch layer (core/backends.py) wires the wrappers into the model.
from repro.kernels.conv2d.ops import conv2d
from repro.kernels.conv2d.ref import conv2d_ref
from repro.kernels.maxpool2d.ops import maxpool2d
from repro.kernels.maxpool2d.ref import maxpool2d_ref
from repro.kernels.quant_matmul.ops import quant_matmul
from repro.kernels.quant_matmul.ref import quant_matmul_ref
from repro.kernels.sigmoid_pla.ops import sigmoid_pla
from repro.kernels.sigmoid_pla.ref import sigmoid_pla_ref
