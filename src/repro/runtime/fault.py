"""Fault tolerance & straggler mitigation for the training runtime.

What runs in this container vs. what the design provides at fleet scale:

  * Checkpoint/restart — implemented & tested: atomic sharded checkpoints,
    auto-resume-from-latest, bitwise-identical continuation (tests/
    test_fault_tolerance.py), corruption detection via per-array CRC.
  * Elastic scaling — implemented & tested: restore re-shards onto a
    different mesh/device count (checkpoint.restore_checkpoint(shardings=...)).
  * Node-failure detection — at fleet scale this is the job scheduler's
    heartbeat; here `StepWatchdog` provides the in-process analogue: a step
    exceeding `timeout_s` marks the step failed, triggers checkpoint-restore
    semantics instead of hanging.
  * Straggler mitigation — (1) deterministic host-indexed data sharding
    (data/lm_data.py): any replacement host can recompute exactly the shard
    of the machine it replaces, no data-server state; (2) step-time SLO
    tracking with the watchdog; (3) the spare-pod pattern (swap "pod" slice
    of the mesh) is a mesh-relabel + reshard under elastic restore.
"""
from __future__ import annotations

import dataclasses
import signal
from typing import Callable


class StepTimeout(RuntimeError):
    pass


@dataclasses.dataclass
class StepWatchdog:
    """SIGALRM-based step timeout: the in-process stand-in for the fleet
    scheduler's missing-heartbeat detection."""
    timeout_s: float = 300.0

    def __enter__(self):
        def _handler(signum, frame):
            raise StepTimeout(f"step exceeded {self.timeout_s}s")
        self._old = signal.signal(signal.SIGALRM, _handler)
        signal.setitimer(signal.ITIMER_REAL, self.timeout_s)
        return self

    def __exit__(self, *exc):
        signal.setitimer(signal.ITIMER_REAL, 0.0)
        signal.signal(signal.SIGALRM, self._old)
        return False


@dataclasses.dataclass
class StepStats:
    """Step-time SLO tracker: flags stragglers as p50 outliers."""
    window: int = 50
    slo_factor: float = 2.0

    def __post_init__(self):
        self.times: list[float] = []

    def record(self, dt: float) -> bool:
        """Returns True if this step is a straggler (> slo_factor x median)."""
        self.times.append(dt)
        self.times = self.times[-self.window:]
        if len(self.times) < 5:
            return False
        med = sorted(self.times)[len(self.times) // 2]
        return dt > self.slo_factor * med


def run_with_restarts(make_state: Callable, train_one: Callable,
                      manager, total_steps: int, *,
                      max_restarts: int = 3, timeout_s: float = 300.0):
    """Crash-safe outer loop: restore-latest -> step -> checkpoint; any
    exception (incl. watchdog timeouts) restarts from the last checkpoint.
    `make_state()` builds fresh state; `train_one(state, step)` -> state."""
    restarts = 0
    while True:
        restored = manager.restore_latest(make_state())
        state, start = (restored if restored is not None
                        else (make_state(), 0))
        step = start
        try:
            while step < total_steps:
                with StepWatchdog(timeout_s):
                    state = train_one(state, step)
                step += 1
                manager.save_async(step, state)
            manager.wait()
            return state, restarts
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            manager.wait()
