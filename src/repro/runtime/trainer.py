"""Trainer: the composable train loop used by examples/ and launch/train.py.

Wires together: model zoo + sharded step functions + deterministic data +
async checkpointing + fault hooks (watchdog, straggler stats, auto-resume).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs.base import ArchConfig
from repro.data import lm_data
from repro.models import model as M
from repro.optim import AdamConfig, adam_init, cosine_schedule
from repro.runtime import fault
from repro.runtime.steps import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    seq_len: int = 256
    global_batch: int = 8
    lr: float = 3e-4
    warmup_steps: int = 20
    ckpt_dir: str | None = None
    ckpt_every: int = 25
    log_every: int = 10
    seed: int = 0
    step_timeout_s: float = 0.0        # 0 = watchdog off


class Trainer:
    def __init__(self, cfg: ArchConfig, tcfg: TrainerConfig):
        self.cfg, self.tcfg = cfg, tcfg
        self.model = M.build(cfg)
        self.ocfg = AdamConfig(lr=tcfg.lr, moment_dtype=cfg.param_dtype)
        self.lr_fn = cosine_schedule(tcfg.lr, tcfg.warmup_steps, tcfg.total_steps)
        self.step_fn = jax.jit(make_train_step(self.model, self.ocfg, self.lr_fn),
                               donate_argnums=(0, 1))
        self.data_cfg = lm_data.DataConfig(
            vocab=cfg.vocab, seq_len=tcfg.seq_len,
            global_batch=tcfg.global_batch, seed=tcfg.seed)
        self.manager = (CheckpointManager(tcfg.ckpt_dir)
                        if tcfg.ckpt_dir else None)
        self.stats = fault.StepStats()

    def init_state(self):
        params, _ = self.model.init(jax.random.key(self.tcfg.seed))
        return {"params": params, "opt": adam_init(params, self.ocfg)}

    def run(self, on_metrics: Callable[[int, dict], None] | None = None):
        state, start = self.init_state(), 0
        if self.manager is not None:
            restored = self.manager.restore_latest(state)
            if restored is not None:
                state, start = restored
        history = []
        for step in range(start, self.tcfg.total_steps):
            batch = jax.tree_util.tree_map(
                jnp.asarray, lm_data.host_batch(self.data_cfg, step))
            t0 = time.perf_counter()
            if self.tcfg.step_timeout_s > 0:
                with fault.StepWatchdog(self.tcfg.step_timeout_s):
                    state["params"], state["opt"], metrics = self.step_fn(
                        state["params"], state["opt"], batch)
                    jax.block_until_ready(metrics)
            else:
                state["params"], state["opt"], metrics = self.step_fn(
                    state["params"], state["opt"], batch)
                jax.block_until_ready(metrics)
            dt = time.perf_counter() - t0
            straggler = self.stats.record(dt)
            if straggler:
                metrics = dict(metrics, straggler=True)
            history.append(float(metrics["loss"]))
            if on_metrics and step % self.tcfg.log_every == 0:
                on_metrics(step, {k: (float(v) if hasattr(v, "item") else v)
                                  for k, v in metrics.items()})
            if self.manager is not None and (step + 1) % self.tcfg.ckpt_every == 0:
                self.manager.save_async(step + 1, state)
        if self.manager is not None:
            self.manager.wait()
        return state, history
