"""Step functions: microbatched train_step, prefill_step, decode (serve) step.

train_step = lax.scan over gradient-accumulation microbatches (bounds
activation memory; DESIGN.md §5) + Adam update.  Gradient accumulation dtype
follows param_dtype: f32 for <=100B-param configs, bf16 for the giants
(documented HBM trade-off).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.optim import AdamConfig, adam_update


def make_train_step(model, optim_cfg: AdamConfig,
                    lr_schedule: Callable | None = None) -> Callable:
    cfg = model.cfg

    def train_step(params, opt_state, batch):
        B = batch["tokens"].shape[0]
        n_micro = max(1, B // max(1, cfg.micro_batch))
        acc_dtype = cfg.param_dtype

        def to_micro(x):
            return x.reshape((n_micro, B // n_micro) + x.shape[1:])

        mb = jax.tree_util.tree_map(to_micro, batch)

        def micro(carry, b):
            gacc, lacc = carry
            (loss, _met), grads = jax.value_and_grad(model.loss, has_aux=True)(params, b)
            gacc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(acc_dtype), gacc, grads)
            return (gacc, lacc + loss), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, acc_dtype), params)
        (gsum, lsum), _ = jax.lax.scan(micro, (zeros, jnp.zeros((), jnp.float32)), mb)
        grads = jax.tree_util.tree_map(lambda g: g / n_micro, gsum)
        lr = lr_schedule(opt_state.step) if lr_schedule else None
        params, opt_state, om = adam_update(grads, opt_state, params, optim_cfg, lr)
        return params, opt_state, {"loss": lsum / n_micro, **om}

    return train_step


def make_prefill_step(model) -> Callable:
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model) -> Callable:
    def decode_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)
    return decode_step
