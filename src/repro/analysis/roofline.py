"""Three-term roofline from the compiled dry-run artifact (TPU v5e target).

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

Sources & loop correction (DESIGN.md §5):
  * FLOPs: dot/conv ops parsed from post-SPMD HLO text with while-loop trip
    multipliers (analysis/hlo_parse.py) — cost_analysis() counts loop bodies
    once, so it UNDERCOUNTS scanned models; we report both.
  * bytes: cost_analysis()['bytes accessed'] scaled by the flops correction
    ratio for loop-body traffic, cross-checked against the analytic model
    (weights-read + activation traffic + cache traffic); we report the
    analytic term as primary because the XLA byte counter double-counts
    fusion-internal traffic.
  * collective bytes: parsed from HLO with loop multipliers.

MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) cross-checks how much of
compiled compute is useful.
"""
from __future__ import annotations

import dataclasses


from repro.analysis.hlo_parse import analyze_hlo
from repro.analysis.mfu import DEVICE_DB
from repro.configs.base import ArchConfig, SHAPES, ShapeSpec, get_config

# --- TPU v5e hardware constants (per chip) ---------------------------------
# The per-chip peaks live in the MFU device database (analysis/mfu.py) so
# the LLM roofline and the smallNet perf ledger can never disagree about
# what the hardware can do; these module-level names remain the v5e view
# this three-term model is calibrated for.
_V5E = DEVICE_DB["tpu-v5e"]
PEAK_FLOPS_BF16 = _V5E.peak("bf16")
PEAK_FLOPS_INT8 = _V5E.peak("int8")
HBM_BW = _V5E.mem_bw
ICI_BW_PER_LINK = 50e9      # ~50 GB/s/link; v5e has 4 links usable per chip


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops_per_device: float
    hlo_flops_raw: float               # cost_analysis (loop bodies once)
    bytes_per_device: float
    collective_bytes_per_device: float      # bf16-wire corrected (primary)
    collective_bytes_raw: float             # as parsed (f32-legalized upper bound)
    collective_breakdown: dict
    model_flops_total: float           # 6ND / 6N_active*D
    useful_ratio: float                # MODEL_FLOPS / (HLO_FLOPs * devices)
    devices: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step estimate: overlapped model = max of the three."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of peak at the roofline step time (MFU
        upper bound implied by the compiled program)."""
        if self.step_time_s <= 0:
            return 0.0
        per_dev = self.model_flops_total / self.devices
        return per_dev / (self.step_time_s * PEAK_FLOPS_BF16)


def param_count(cfg: ArchConfig) -> tuple[float, float]:
    """(total params, active params) analytic."""
    d, L, ff, hd = cfg.d_model, cfg.n_layers, cfg.d_ff, cfg.head_dim
    H, K = cfg.n_heads, cfg.n_kv_heads
    attn = d * H * hd + 2 * d * K * hd + H * hd * d
    mlp_dense = (3 if cfg.mlp == "gated" else 2) * d * ff
    embed = cfg.vocab_padded * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "moe":
        moe = cfg.n_experts * 3 * d * ff + d * cfg.n_experts
        total = L * (attn + moe) + embed
        active = L * (attn + cfg.top_k * 3 * d * ff) + embed
        return float(total), float(active)
    if cfg.family == "ssm":
        # rwkv block: 5 square proj + lora + channel mix (ck, cv, cr)
        blk = 5 * d * d + d * ff * 2 + d * d + 10 * 32 * d
        total = L * blk + embed
        return float(total), float(total)
    if cfg.family == "hybrid":
        P = cfg.attn_period
        n_super = L // P
        d_in = 2 * d
        mamba = d * 2 * d_in + d_in * (max(1, d // 16) + 32) + \
            max(1, d // 16) * d_in + d_in * d
        moe = cfg.n_experts * 3 * d * ff
        per_super = (P - 1) * mamba + attn + (P // cfg.moe_every) * moe + \
            (P - P // cfg.moe_every) * mlp_dense
        active_super = (P - 1) * mamba + attn + \
            (P // cfg.moe_every) * cfg.top_k * 3 * d * ff + \
            (P - P // cfg.moe_every) * mlp_dense
        return float(n_super * per_super + embed), float(n_super * active_super + embed)
    if cfg.family == "audio":
        enc = cfg.encoder_layers * (attn + mlp_dense)
        dec = L * (2 * attn + mlp_dense)
        return float(enc + dec + embed), float(enc + dec + embed)
    total = L * (attn + mlp_dense) + embed
    return float(total), float(total)


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """6*N_active*D for train; 2*N_active*D for prefill; 2*N_active*B for
    one decode step (+ attention term where applicable)."""
    _, active = param_count(cfg)
    if shape.kind == "train":
        D = shape.global_batch * shape.seq_len
        return 6.0 * active * D
    if shape.kind == "prefill":
        D = shape.global_batch * shape.seq_len
        return 2.0 * active * D
    # decode: one token per sequence + attention over the cache
    flops = 2.0 * active * shape.global_batch
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        attn_layers = cfg.n_layers
    elif cfg.family == "hybrid":
        attn_layers = cfg.n_layers // cfg.attn_period
    else:
        attn_layers = 0
    flops += (4.0 * shape.global_batch * cfg.n_heads * cfg.head_dim
              * shape.seq_len * attn_layers)
    return flops


def analytic_bytes(cfg: ArchConfig, shape: ShapeSpec, devices: int) -> float:
    """Per-device HBM traffic model (documented in EXPERIMENTS.md §Roofline):
    train:   n_micro*(2 reads + 1 grad write of params) + 3x optimizer state
             + 4x layer-boundary activations
    prefill: params once + 2x activations + KV write
    decode:  params once + full KV/state cache read + write-back of one slot
    Parameter/cache bytes use the actual sharded layout (/devices).
    """
    total, _ = param_count(cfg)
    pb = total * (2 if cfg.param_dtype.__name__ == "bfloat16" else 4)
    dt = 2  # activation bytes (bf16)
    if shape.kind == "train":
        n_micro = max(1, shape.global_batch // max(1, cfg.micro_batch))
        acts = cfg.n_layers * shape.global_batch * shape.seq_len * cfg.d_model * dt
        opt = 3 * pb
        traffic = n_micro * 3 * pb + opt + 4 * acts
    elif shape.kind == "prefill":
        acts = cfg.n_layers * shape.global_batch * shape.seq_len * cfg.d_model * dt
        kv = (2 * cfg.n_layers * shape.global_batch * shape.seq_len
              * cfg.n_kv_heads * cfg.head_dim * dt)
        traffic = pb + 2 * acts + kv
    else:
        if cfg.family == "ssm":
            cache = (cfg.n_layers * shape.global_batch * cfg.n_heads
                     * cfg.head_dim * cfg.head_dim * 4)
        elif cfg.family == "hybrid":
            n_super = cfg.n_layers // cfg.attn_period
            cache = (2 * n_super * shape.global_batch * shape.seq_len
                     * cfg.n_kv_heads * cfg.head_dim * dt)
            cache += (cfg.n_layers - n_super) * shape.global_batch * \
                2 * cfg.d_model * 16 * 4
        else:
            cache = (2 * cfg.n_layers * shape.global_batch * shape.seq_len
                     * cfg.n_kv_heads * cfg.head_dim * dt)
        traffic = pb + cache
    return traffic / devices


def roofline_from_artifacts(arch: str, shape_name: str, hlo_text: str,
                            cost: dict, devices: int) -> Roofline:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    summ = analyze_hlo(hlo_text)
    mf = model_flops(cfg, shape)
    bytes_dev = analytic_bytes(cfg, shape, devices)
    hlo_flops = summ.flops
    # primary collective term uses the bf16-wire correction: XLA-CPU
    # legalizes bf16 matmul operands to f32 before SPMD partitioning, so
    # collectives a TPU lowering moves in bf16 parse as f32 here (the raw
    # number is also recorded as the upper bound)
    coll = summ.total_collective_bytes_bf16wire
    return Roofline(
        arch=arch, shape=shape_name,
        compute_s=hlo_flops / PEAK_FLOPS_BF16,
        memory_s=bytes_dev / HBM_BW,
        collective_s=coll / (4 * ICI_BW_PER_LINK),
        hlo_flops_per_device=hlo_flops,
        hlo_flops_raw=float(cost.get("flops", 0.0)),
        bytes_per_device=bytes_dev,
        collective_bytes_per_device=coll,
        collective_bytes_raw=summ.total_collective_bytes,
        collective_breakdown={k: float(v) for k, v in summ.collective_bytes.items()},
        model_flops_total=mf,
        useful_ratio=mf / max(hlo_flops * devices, 1.0),
        devices=devices,
    )


def to_dict(r: Roofline) -> dict:
    d = dataclasses.asdict(r)
    d["dominant"] = r.dominant
    d["step_time_s"] = r.step_time_s
    d["roofline_fraction"] = r.roofline_fraction
    return d


def smallnet_rooflines(*, device_name: str = "tpu-v5e", H: int = 112,
                       W: int = 112, stride: int = 8) -> dict[str, dict]:
    """Analytic two-term rooflines for smallNet's actual hot paths — the
    perf-ledger routes (host tiler / composed sweep / megakernel sweep)
    plus the deployed single-image cell — on one device from the MFU
    database.  No compilation: the workload model (analysis/mfu.py) is
    closed-form, so this runs in microseconds and the bench-smoke lane can
    gate it on every push (NaN or zero-denominator here means the model or
    a device entry broke)."""
    from repro.analysis import mfu
    from repro.streaming.tiler import tile_positions

    if device_name not in DEVICE_DB:
        raise KeyError(f"unknown device {device_name!r} "
                       f"(known: {sorted(DEVICE_DB)})")
    dev = DEVICE_DB[device_name]
    n_windows = len(tile_positions((H, W), mfu.PATCH, stride))
    out: dict[str, dict] = {}
    for backend in ("ref", "fixed_pallas"):
        dtype, wb = mfu.backend_numerics(backend)
        for route in mfu.ROUTE_WORKLOADS:
            wl = mfu.route_workload(route, H, W, n_windows, wb)
            out[f"smallnet-{backend}|{route}"] = mfu.roofline_terms(
                wl, device=dev, dtype=dtype)
    dtype, wb = mfu.backend_numerics("fixed_pallas")
    out["smallnet-fixed_pallas|deployed"] = mfu.roofline_terms(
        mfu.deployed_workload(wb), device=dev, dtype=dtype)
    return out
