"""MFU + bytes-moved accounting for smallNet's hot paths.

The paper's headline claims are efficiency numbers (5.1x at 1.5 W), so the
perf ledger needs more than FPS: every (backend, route) row should say how
close it runs to the hardware roofline.  This module supplies the two
halves of that account:

  1. a DEVICE DATABASE (`DEVICE_DB`): per-dtype peak FLOP/s + memory
     bandwidth for the CPU, common GPUs, and TPU generations — the
     achievable-FLOPs denominator, in the style of PrimeIntellect's
     `mfu_tracker.py` (device -> generation -> flagship peaks).  Lookups
     are TOTAL: an unknown accelerator raises with the known-device list
     (silent zeros would quietly report MFU=inf or 0), while CPU hosts —
     where Pallas kernels run under the interpreter — always fall back to
     the generic "cpu" entry (`resolve`).

  2. an ANALYTIC WORKLOAD MODEL (`trunk_workload` / `sweep_workload` /
     `tiler_workload` / `deployed_workload`): model FLOPs and bytes moved
     per frame for each route the perf ledger rows — the host tiler, the
     composed quad-cascade sweep, and the `kernels/frame_trunk`
     megakernel (whose input bytes are the real halo'd HBM->VMEM tile DMA
     traffic, via `choose_tile`).

MFU denominator convention (documented in README §Observability): the
numerator is MODEL FLOPs — 2 flops per multiply-accumulate of the convs
and dense layers the route's algorithm specifies, padding taps included
(the datapath multiplies them against real zero operands), activations /
bias adds / pool comparisons excluded — NOT the HLO instruction count.
`tests/test_mfu.py` cross-checks the model against `analysis/hlo_parse.py`
conv FLOPs on the XLA-visible ref path; Pallas launches are opaque to HLO,
which is exactly why the denominator is analytic.

Bytes-moved convention: off-chip traffic between kernel launches.  The
composed sweep round-trips every intermediate role map through HBM (each
launch reads its inputs and writes its outputs), the megakernel moves only
the halo'd input tiles in and the pooled quad out — that asymmetry, not
FLOPs, is what the one-launch trunk actually buys, and `achieved_bw`
makes it visible in the ledger.

MFU clock convention (`mfu_clock`): on real accelerators, the measured
wall time of the route's jitted per-frame program; under interpret-mode
emulation (every CPU CI host), the roofline floor `modeled_seconds` —
emulator wall time is not a device clock, and the floor keeps committed
ledger MFU deterministic across machines.  Every ledger row records which
basis produced its mfu.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

# ---------------------------------------------------------------------------
# Device database
# ---------------------------------------------------------------------------

DTYPE_CLASSES = ("f32", "bf16", "f16", "int8", "int32")


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """Peak rates for one device: FLOP/s per dtype class + HBM/DRAM
    bandwidth in bytes/s.  `kinds` are substrings matched (case-insensitive)
    against `jax.Device.device_kind` by `lookup`."""
    name: str
    kinds: tuple[str, ...]
    peak_flops: Mapping[str, float]
    mem_bw: float
    source: str

    def peak(self, dtype: str) -> float:
        if dtype not in self.peak_flops:
            raise KeyError(
                f"device {self.name!r} has no peak for dtype class "
                f"{dtype!r}; known: {sorted(self.peak_flops)}")
        return self.peak_flops[dtype]


def _spec(name, kinds, f32, bf16, f16, i8, i32, bw, source):
    return DeviceSpec(name, kinds,
                      {"f32": f32, "bf16": bf16, "f16": f16,
                       "int8": i8, "int32": i32}, bw, source)


# Vendor-nameplate peaks where published; integer-pipeline and f32-on-MXU
# numbers are order-of-magnitude engineering estimates (flagged per entry).
# TPUs run int32 on the VPU, not the MXU, so the int32 peaks are small —
# which is the honest denominator for this repo's Qm.n substrates.
DEVICE_DB: dict[str, DeviceSpec] = {s.name: s for s in [
    _spec("cpu", ("cpu",),
          2.5e12, 2.5e12, 2.5e12, 5.0e12, 1.2e12, 1.0e11,
          "generic AVX-512 server estimate (16c x 2 FMA x 16 lanes); the "
          "interpret-mode fallback entry — Pallas interpret achieves a "
          "tiny fraction of even this"),
    _spec("tpu-v4", ("TPU v4",),
          69e12, 275e12, 275e12, 275e12, 5.5e12, 1228e9,
          "TPU v4 datasheet; f32/int32 estimated"),
    _spec("tpu-v5e", ("TPU v5 lite", "TPU v5e"),
          49e12, 197e12, 197e12, 394e12, 3.9e12, 819e9,
          "TPU v5e datasheet; f32/int32 estimated"),
    _spec("tpu-v5p", ("TPU v5p", "TPU v5"),
          115e12, 459e12, 459e12, 918e12, 9.2e12, 2765e9,
          "TPU v5p datasheet; f32/int32 estimated"),
    _spec("tpu-v6e", ("TPU v6 lite", "TPU v6e"),
          230e12, 918e12, 918e12, 1836e12, 18e12, 1640e9,
          "TPU v6e (Trillium) datasheet; f32/int32 estimated"),
    _spec("v100", ("V100",),
          15.7e12, 15.7e12, 125e12, 62.8e12, 15.7e12, 900e9,
          "V100 SXM2 datasheet (no bf16/int8 tensor cores: CUDA-core "
          "rates)"),
    _spec("a100", ("A100",),
          19.5e12, 312e12, 312e12, 624e12, 19.5e12, 2039e9,
          "A100 SXM4-80GB datasheet, dense (no sparsity)"),
    _spec("h100", ("H100",),
          67e12, 989e12, 989e12, 1979e12, 33.5e12, 3352e9,
          "H100 SXM5 datasheet, dense; int32 estimated"),
    _spec("rtx-4090", ("RTX 4090",),
          82.6e12, 165.2e12, 165.2e12, 660.6e12, 41e12, 1008e9,
          "Ada flagship consumer datasheet; int32 estimated"),
]}


def lookup(device_kind: str) -> DeviceSpec:
    """Total device lookup: exact DB key, then case-insensitive substring
    match on each entry's `kinds`.  Unknown devices raise LOUDLY — an MFU
    against a silently-guessed peak is worse than no MFU."""
    if device_kind in DEVICE_DB:
        return DEVICE_DB[device_kind]
    dk = device_kind.lower()
    # longest kind pattern wins so "TPU v5p" never matches the "TPU v5"
    # alias of a different generation first
    best = None
    for spec in DEVICE_DB.values():
        for kind in spec.kinds:
            if kind.lower() in dk and (best is None or len(kind) > best[0]):
                best = (len(kind), spec)
    if best is not None:
        return best[1]
    raise KeyError(
        f"unknown device kind {device_kind!r}: not in the MFU device "
        f"database (known: {sorted(DEVICE_DB)}).  Add a DeviceSpec with "
        f"its per-dtype peaks to analysis/mfu.py — do not let MFU divide "
        f"by a guess.")


def resolve(device=None) -> tuple[DeviceSpec, bool]:
    """(spec, interpret) for the device the process is actually using.
    `device=None` reads jax's default device.  CPU hosts always resolve to
    the generic "cpu" entry (whatever the host CPU's device_kind says) —
    that is the interpret-mode fallback: on CPU every Pallas kernel runs
    under the interpreter, flagged by the returned `interpret` bool."""
    import jax

    from repro.core import runtime
    dev = jax.devices()[0] if device is None else device
    if dev.platform == "cpu":
        return DEVICE_DB["cpu"], runtime.interpret_default()
    return lookup(dev.device_kind), False


# backend name -> (dtype class for the peak denominator, bytes per word
# moved off-chip).  Every registered smallnet backend moves 4-byte words:
# float32 activations or int32 Qm.n words (the int8 backend keeps f32
# activations; only its dense MAC runs int8).
BACKEND_NUMERICS: dict[str, tuple[str, int]] = {
    "ref": ("f32", 4), "plan": ("f32", 4),
    "pallas": ("f32", 4), "pallas_plan": ("f32", 4),
    "fixed": ("int32", 4), "fixed_pallas": ("int32", 4),
    "int8": ("int8", 4),
}


def backend_numerics(backend: str) -> tuple[str, int]:
    if backend not in BACKEND_NUMERICS:
        raise KeyError(
            f"backend {backend!r} has no MFU numerics entry "
            f"(known: {sorted(BACKEND_NUMERICS)})")
    return BACKEND_NUMERICS[backend]


# ---------------------------------------------------------------------------
# Analytic workload model
# ---------------------------------------------------------------------------

PATCH = 28                 # the deployed window side
_HEAD_IN, _HEAD_OUT = 49, 10
_TRUNK_PARAM_WORDS = 10    # 2 convs x (4 taps + 1 bias)
_HEAD_PARAM_WORDS = _HEAD_IN * _HEAD_OUT + _HEAD_OUT
PARAM_WORDS = _TRUNK_PARAM_WORDS + _HEAD_PARAM_WORDS          # 510

# quad-cascade tap counts (streaming/fcn_sweep.py `_sweep_stage`): live
# taps of each masked conv, i.e. the MACs the algorithm specifies
_L0_TAPS = 4 + 2 + 2 + 1             # s_ii + s_li + s_il + s_ll
_L1_TAPS = _L0_TAPS + (4 + 4 + 4 + 2 + 2)   # + s_pi s_ip s_pp s_pl s_lp


@dataclasses.dataclass(frozen=True)
class Workload:
    """Model FLOPs + off-chip bytes for one route over one frame.  Bytes
    are split so scaling laws stay exact: `bytes_params` is the constant
    weight traffic (counted once per frame), everything else scales with
    the frame."""
    name: str
    flops: int
    bytes_in: int
    bytes_out: int
    bytes_params: int

    @property
    def bytes_total(self) -> int:
        return self.bytes_in + self.bytes_out + self.bytes_params

    @property
    def intensity(self) -> float:
        """Arithmetic intensity, FLOPs per byte moved."""
        return self.flops / max(self.bytes_total, 1)

    def __add__(self, other: "Workload") -> "Workload":
        return Workload(f"{self.name}+{other.name}",
                        self.flops + other.flops,
                        self.bytes_in + other.bytes_in,
                        self.bytes_out + other.bytes_out,
                        self.bytes_params + other.bytes_params)


def _conv_flops(h: int, w: int, taps: int) -> int:
    """2 flops per MAC, `taps` MACs per output position."""
    return 2 * taps * h * w


def deployed_workload(word_bytes: int = 4) -> Workload:
    """One 28x28 image through `smallnet.apply`: conv1 over 28x28 SAME (4
    taps), conv2 over 14x14, dense 49->10.  The hand-countable unit cell:
    2*(4*784 + 4*196 + 490) = 8820 model FLOPs."""
    flops = (_conv_flops(PATCH, PATCH, 4)
             + _conv_flops(PATCH // 2, PATCH // 2, 4)
             + 2 * _HEAD_IN * _HEAD_OUT)
    return Workload("deployed", flops,
                    bytes_in=PATCH * PATCH * word_bytes,
                    bytes_out=_HEAD_OUT * word_bytes,
                    bytes_params=PARAM_WORDS * word_bytes)


def trunk_workload(H: int, W: int, route: str = "trunk",
                   word_bytes: int = 4) -> Workload:
    """Model FLOPs + bytes for the conv trunk over one HxW frame.

    route="trunk":  the plain two-stage trunk (`smallnet.conv_trunk`'s
        interior map), perfectly fused: read the frame once, write the
        pooled H/4 x W/4 map once.  This is the roofline IDEAL every
        sweep route is measured against.
    route="sweep_composed":  the quad role-map cascade of
        `fcn_sweep._sweep_stage` — 4 masked convs at level 0 (9 live taps
        per pixel) and 4 single- + 5 mixed-source maps at level 1 (25
        live taps), with every intermediate map round-tripping HBM
        between launches (convs, PLAN units, accumulates, pools).
    route="sweep_megakernel":  the same quad maps computed inside
        `kernels/frame_trunk` tiles: FLOPs cover each tile's halo'd conv
        extents ((th+2)x(tw+2) at level 0 — slightly MORE arithmetic than
        the composed cascade at seams), but the only off-chip traffic is
        the real (th+3)x(tw+3) HBM->VMEM tile DMA in and the pooled quad
        out, via the kernel's own `choose_tile`.
    """
    A = H * W
    w = word_bytes
    if route == "trunk":
        flops = _conv_flops(H, W, 4) + _conv_flops(H // 2, W // 2, 4)
        return Workload("trunk", flops, A * w, (A // 16) * w,
                        _TRUNK_PARAM_WORDS * w)
    if route == "sweep_composed":
        a = A // 4
        flops = 2 * _L0_TAPS * A + 2 * _L1_TAPS * a
        # per-launch HBM round-trips (elements):
        #   level 0: 4 convs read the frame (4A), 3 PLAN units re-read the
        #   un-fused conv outs (3A), pools read interior A + mix 2A +
        #   last-col 2A + corner 4A = 9A -> 16A read;
        #   writes: 4 conv outs + 3 PLAN outs + pooled quad A -> 8A
        #   level 1 (maps of a = A/4 elements): 16 conv launches (4 single
        #   + 12 masked partials) read 16a, 7 accumulate adds read 14a,
        #   8 PLAN units read 8a, pools read 9a -> 47a read;
        #   writes: 16a conv + 7a add + 8a PLAN + a pooled quad -> 32a
        reads = 16 * A + 47 * a
        writes = 8 * A + 32 * a
        return Workload("sweep_composed", flops, reads * w, writes * w,
                        _TRUNK_PARAM_WORDS * w)
    if route == "sweep_megakernel":
        from repro.kernels.frame_trunk.ops import HALO, choose_tile
        th, tw = choose_tile(H, W)
        n_tiles = (H // th) * (W // tw)
        flops = n_tiles * (2 * _L0_TAPS * (th + 2) * (tw + 2)
                           + 2 * _L1_TAPS * (th // 2) * (tw // 2))
        dma_in = n_tiles * (th + HALO) * (tw + HALO)
        quad_out = 4 * (H // 4) * (W // 4)
        return Workload("sweep_megakernel", flops, dma_in * w, quad_out * w,
                        _TRUNK_PARAM_WORDS * w)
    raise ValueError(
        f"unknown trunk route {route!r} "
        f"(known: trunk, sweep_composed, sweep_megakernel)")


def head_workload(n_windows: int, word_bytes: int = 4) -> Workload:
    """The windowed dense head: gather 49 pooled features per window, one
    49->10 MAC per window."""
    w = word_bytes
    return Workload("head", 2 * _HEAD_IN * _HEAD_OUT * n_windows,
                    n_windows * _HEAD_IN * w, n_windows * _HEAD_OUT * w,
                    _HEAD_PARAM_WORDS * w)


def sweep_workload(H: int, W: int, n_windows: int, route: str,
                   word_bytes: int = 4) -> Workload:
    """The full FcnSweep per-frame program: trunk (composed or megakernel
    route) + windowed dense head."""
    return (trunk_workload(H, W, route, word_bytes)
            + head_workload(n_windows, word_bytes))


def tiler_workload(n_windows: int, word_bytes: int = 4) -> Workload:
    """The host-tiler route: every window re-runs the full 28x28 deployed
    network, and every window's 784 pixels are re-read from the frame —
    overlapping windows re-convolve (and re-move) shared pixels, which is
    exactly what the sweep exists to avoid."""
    d = deployed_workload(word_bytes)
    return Workload("tiler", d.flops * n_windows,
                    d.bytes_in * n_windows, d.bytes_out * n_windows,
                    PARAM_WORDS * word_bytes)


ROUTE_WORKLOADS = ("tiler", "sweep_composed", "sweep_megakernel")


def route_workload(route: str, H: int, W: int, n_windows: int,
                   word_bytes: int = 4) -> Workload:
    """The perf-ledger entry point: one Workload per (route, geometry)."""
    if route == "tiler":
        return tiler_workload(n_windows, word_bytes)
    if route in ("sweep_composed", "sweep_megakernel"):
        return sweep_workload(H, W, n_windows, route, word_bytes)
    raise ValueError(f"unknown ledger route {route!r} "
                     f"(known: {ROUTE_WORKLOADS})")


# ---------------------------------------------------------------------------
# Achieved rates, MFU, roofline terms
# ---------------------------------------------------------------------------

def achieved(workload: Workload, seconds: float) -> dict:
    """Measured rates for one frame of `workload` computed in `seconds`."""
    if not seconds > 0:
        raise ValueError(f"achieved() needs a positive duration, got "
                         f"{seconds!r}")
    return {"achieved_flops": workload.flops / seconds,
            "achieved_bw": workload.bytes_total / seconds}


def mfu(workload: Workload, seconds: float, *, device: DeviceSpec,
        dtype: str) -> float:
    """Model-FLOPs utilization: (model FLOPs / wall seconds) / peak FLOP/s
    of the device at the backend's dtype class.  By construction in (0, 1]
    for any real measurement — a value outside that range means the
    workload model or the device entry is wrong, and the ledger gate
    treats it as a failure, not a triumph."""
    return achieved(workload, seconds)["achieved_flops"] / device.peak(dtype)


def modeled_seconds(workload: Workload, *, device: DeviceSpec,
                    dtype: str) -> float:
    """Roofline floor time for one frame: max(compute floor, memory floor).
    This is the MFU clock under interpret-mode emulation: on a CPU host
    every Pallas launch runs under the interpreter, so wall time measures
    the INTERPRETER, not the device program the kernel describes — by the
    emulator's clock, round-tripping 2 MB through HBM costs the same as
    DMAing 100 KB once, which would invert every conclusion the bytes
    model exists to surface.  The roofline floor is deterministic and
    machine-independent, so ledger MFU gates stay reproducible on any CI
    host; on real accelerators the measured clock is used instead
    (`mfu_clock`)."""
    t = roofline_terms(workload, device=device, dtype=dtype)
    return max(t["compute_s"], t["memory_s"])


def mfu_clock(workload: Workload, measured_s: float, *, device: DeviceSpec,
              dtype: str, interpret: bool) -> tuple[float, str]:
    """(seconds, basis) the MFU/achieved-rate columns divide by: the
    measured device-program wall time on real hardware, the roofline floor
    (`modeled_seconds`) under interpret-mode emulation.  The basis string
    ("measured" / "roofline_model") is committed next to every mfu value
    so a ledger row can never be misread as a hardware measurement."""
    if interpret:
        return modeled_seconds(workload, device=device, dtype=dtype), \
            "roofline_model"
    return measured_s, "measured"


def roofline_terms(workload: Workload, *, device: DeviceSpec,
                   dtype: str) -> dict:
    """Two-term roofline for one frame: compute floor, memory floor, the
    binding term, and the attainable FLOP/s at this arithmetic intensity
    (min(peak, intensity * bw) — the classic roofline ceiling)."""
    peak = device.peak(dtype)
    compute_s = workload.flops / peak
    memory_s = workload.bytes_total / device.mem_bw
    return {
        "flops": workload.flops,
        "bytes": workload.bytes_total,
        "intensity": workload.intensity,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "bound": "compute" if compute_s >= memory_s else "memory",
        "attainable_flops": min(peak, workload.intensity * device.mem_bw),
        "peak_flops": peak,
        "mem_bw": device.mem_bw,
        "device": device.name,
        "dtype": dtype,
    }
