"""HLO-text analysis: loop-trip-count-aware FLOP and collective-byte totals.

XLA's cost_analysis() counts every while (scan) body ONCE (verified in this
container: an 8-layer scan reports exactly 1/8 the unrolled FLOPs).  This
parser walks the post-SPMD HLO text and:

  1. builds per-computation symbol tables (instruction -> result shape),
  2. finds every `while` op and extracts its trip count from the condition
     computation's `s32[] constant(N)` + compare pattern,
  3. assigns each computation a multiplier = product of enclosing loop trips
     (following calls=/to_apply=/body= edges from the entry computation),
  4. sums with multipliers:
     - dot FLOPs: 2 * out_elems * prod(lhs contracting dims)  (operand shape
       from the symbol table),
     - convolution FLOPs: 2 * out_elems * kernel_volume,
     - collective wire bytes with ring-cost factors:
         all-gather:          out_bytes * (g-1)/g
         all-reduce:          2 * bytes * (g-1)/g
         reduce-scatter:      out_bytes * (g-1)
         all-to-all:          bytes * (g-1)/g
         collective-permute:  bytes
       (g = replica group size parsed from `replica_groups=[n,g]<=[...]`).

All shapes in post-SPMD HLO are PER-DEVICE shard shapes, so totals are
per-device; multiply by device count for fleet totals.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_list(segment: str) -> list[tuple[str, str]]:
    return _SHAPE_RE.findall(segment)


def _elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _bytes(dt: str, dims: str) -> int:
    return _elems(dims) * _DTYPE_BYTES.get(dt, 4)


def _parse_computations(txt: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in txt.splitlines():
        ls = line.rstrip()
        s = ls.strip()
        if s.endswith("{") and "->" in s and ("(" in s):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", s)
            if m:
                cur = m.group(1)
                comps[cur] = []
            continue
        if s.startswith("}"):
            cur = None
            continue
        if cur is not None and s:
            comps[cur].append(s)
    return comps


def _opcode_of(rhs: str) -> str:
    """rhs looks like 'f32[8,16]{1,0} dot(%a, %b), ...' or '(f32[..]) while(...'"""
    # strip result type: find first token after the type expression(s)
    m = re.search(r"\)\s*([\w\-]+)\(", rhs)       # tuple-typed results
    m2 = re.search(r"\}\s*([\w\-]+)\(", rhs)      # layout-annotated results
    m3 = re.search(r"\]\s*([\w\-]+)\(", rhs)      # plain results
    for mm in (m2, m3, m):
        if mm:
            return mm.group(1)
    return ""


def _result_segment(rhs: str) -> str:
    """Portion of rhs before the opcode call — contains result shapes."""
    op = _opcode_of(rhs)
    if not op:
        return rhs
    idx = rhs.find(op + "(")
    return rhs[:idx] if idx > 0 else rhs


def _call_args(rhs: str, op: str) -> str | None:
    """The argument list of `op(...)` in rhs, paren-balanced — operand
    layouts like '{1,0:T(8,128)}' contain parens, so a [^)]* capture would
    truncate the list at the first ')'."""
    start = rhs.find(op + "(")
    if start < 0:
        return None
    i = start + len(op) + 1
    depth = 1
    for j in range(i, len(rhs)):
        if rhs[j] == "(":
            depth += 1
        elif rhs[j] == ")":
            depth -= 1
            if depth == 0:
                return rhs[i:j]
    return None


def _split_operands(arglist: str) -> list[str]:
    """Split an instruction argument list on top-level commas only — shape
    dims ('f32[32,64]') and layouts ('{1,0}') contain commas too."""
    out, cur, depth = [], [], 0
    for ch in arglist:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return out


def _operand_dims(arglist: str, idx: int, tab: dict) -> list[int]:
    """Dims of the idx-th operand of an instruction's argument list.

    Newer XLA prints operands TYPED ('f32[32,64]{1,0} %name'); older
    prints bare names ('%name') — read the inline shape when present,
    fall back to the symbol table otherwise."""
    ops = _split_operands(arglist)
    if idx >= len(ops):
        return []
    operand = ops[idx]
    shapes = _shape_list(operand.split("%")[0])   # inline type, if printed
    if shapes:
        dims = shapes[0][1]
    else:
        mname = re.search(r"%[\w\.\-]+", operand)
        sym = tab.get(mname.group(0)) if mname else None
        if sym is None:
            return []
        dims = sym[1]
    return [int(d) for d in dims.split(",")] if dims else []


def _trip_count(cond_lines: list[str]) -> int:
    consts = {}
    for ls in cond_lines:
        m = re.search(r"%([\w\.\-]+)\s*=\s*s32\[\]\s*constant\((\d+)\)", ls)
        if m:
            consts["%" + m.group(1)] = int(m.group(2))
    for ls in cond_lines:
        if "compare(" in ls and "direction=LT" in ls:
            for name, val in consts.items():
                if name in ls:
                    return val
    return max(consts.values(), default=1)


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:  # explicit group list: size of the first group
        return len(m.group(1).split(","))
    return 2


@dataclasses.dataclass
class HloSummary:
    flops: float                  # per-device, loop-aware
    collective_bytes: dict        # kind -> wire bytes, per-device, loop-aware
    dot_flops_once: float         # without loop multipliers (sanity)
    n_collectives: int
    collective_bytes_f32: float = 0.0   # subset moved as f32 (see below)

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))

    @property
    def total_collective_bytes_bf16wire(self) -> float:
        """CPU-backend correction: XLA-CPU legalizes bf16 matmul operands to
        f32 BEFORE SPMD partitioning, so activation/weight collectives that a
        TPU lowering moves in bf16 appear as f32 here (verified: parameters
        are stored bf16 in the same HLO).  This estimate halves the f32
        collective subset — the TPU wire volume."""
        return self.total_collective_bytes - 0.5 * self.collective_bytes_f32


def analyze_hlo(txt: str) -> HloSummary:
    comps = _parse_computations(txt)

    # per-computation symbol tables: %name -> (dtype, dims) of first result
    symtab: dict[str, dict[str, tuple[str, str]]] = {}
    for cname, lines in comps.items():
        tab = {}
        for ls in lines:
            m = _INSTR_RE.match(ls)
            if not m:
                continue
            shapes = _shape_list(_result_segment(m.group(2)))
            if shapes:
                tab["%" + m.group(1)] = shapes[0]
        symtab[cname] = tab

    # call edges with loop multipliers
    calls: dict[str, list[tuple[str, int]]] = defaultdict(list)
    for cname, lines in comps.items():
        for ls in lines:
            mb = re.search(r"body=%?([\w\.\-]+)", ls)
            mc = re.search(r"condition=%?([\w\.\-]+)", ls)
            if mb and mc and " while(" in ls:
                trips = _trip_count(comps.get(mc.group(1), []))
                calls[cname].append((mb.group(1), trips))
                calls[cname].append((mc.group(1), trips))
                continue
            for m in re.finditer(r"(?:calls=|to_apply=)%?([\w\.\-]+)", ls):
                calls[cname].append((m.group(1), 1))

    called = {c for lst in calls.values() for c, _ in lst}
    entries = [c for c in comps if c not in called]
    mult: dict[str, float] = defaultdict(float)

    def visit(name: str, m: float, depth=0):
        if depth > 64 or name not in comps:
            return
        mult[name] += m
        for callee, trips in calls.get(name, []):
            visit(callee, m * trips, depth + 1)

    for e in entries:
        visit(e, 1.0)

    flops = 0.0
    flops_once = 0.0
    coll: dict[str, float] = defaultdict(float)
    coll_f32 = 0.0
    n_coll = 0
    for cname, lines in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        tab = symtab[cname]
        for ls in lines:
            mi = _INSTR_RE.match(ls)
            if not mi:
                continue
            rhs = mi.group(2)
            op = _opcode_of(rhs)
            if op == "dot":
                shapes = _shape_list(_result_segment(rhs))
                if not shapes:
                    continue
                out_n = _elems(shapes[0][1])
                k = 1
                mcd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
                args = _call_args(rhs, "dot")
                if args is not None and mcd:
                    dims = _operand_dims(args, 0, tab)
                    for i in (int(x) for x in mcd.group(1).split(",") if x):
                        if i < len(dims):
                            k *= dims[i]
                f = 2.0 * out_n * k
                flops += m * f
                flops_once += f
            elif op == "convolution":
                shapes = _shape_list(rhs)
                if len(shapes) >= 2:
                    out_n = _elems(shapes[0][1])
                    args = _call_args(rhs, "convolution")
                    kvol = 1
                    if args is not None:
                        kdims = _operand_dims(args, 1, tab)
                        if kdims:
                            kvol = 1
                            for d in kdims:
                                kvol *= d
                    f = 2.0 * out_n * kvol
                    flops += m * f
                    flops_once += f
            elif op in _COLLECTIVES:
                shapes = _shape_list(_result_segment(rhs))
                b = sum(_bytes(dt, dims) for dt, dims in shapes)
                g = _group_size(rhs)
                if op == "all-gather":
                    wire = b * (g - 1) / g
                elif op == "all-reduce":
                    wire = 2.0 * b * (g - 1) / g
                elif op == "reduce-scatter":
                    wire = b * (g - 1)
                elif op == "all-to-all":
                    wire = b * (g - 1) / g
                else:
                    wire = float(b)
                coll[op] += m * wire
                if shapes and shapes[0][0] == "f32":
                    coll_f32 += m * wire
                n_coll += 1
    return HloSummary(flops=flops, collective_bytes=dict(coll),
                      dot_flops_once=flops_once, n_collectives=n_coll,
                      collective_bytes_f32=coll_f32)
