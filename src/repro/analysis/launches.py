"""Launches-per-frame accounting: count Pallas kernel dispatches in a jaxpr.

The megakernel PR's whole claim is a launch-topology change — O(stages x
role-maps) Pallas dispatches per frame collapsing to ONE trunk launch — so
the perf ledger and the stream_table smoke gate pin the number, not the
prose.  Counting is static: trace the program with `jax.make_jaxpr` and
walk every equation (recursing through pjit/scan/cond sub-jaxprs) for the
`pallas_call` primitive.  This counts launches in the PROGRAM, which under
jit is exactly launches-per-call; it is mode-independent (interpret vs
compiled lower the same jaxpr) and costs one trace, no execution.
"""
from __future__ import annotations

from typing import Any, Callable

import jax


def _subjaxprs(params: dict):
    """Sub-jaxprs hiding in an eqn's params (pjit jaxpr=..., scan/cond
    branches=[...], custom_* call_jaxpr=...)."""
    for v in params.values():
        if isinstance(v, jax.core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jax.core.Jaxpr):
            yield v
        elif isinstance(v, (list, tuple)):
            for item in v:
                if isinstance(item, jax.core.ClosedJaxpr):
                    yield item.jaxpr
                elif isinstance(item, jax.core.Jaxpr):
                    yield item


def _count_in_jaxpr(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for sub in _subjaxprs(eqn.params):
            n += _count_in_jaxpr(sub)
    return n


def count_pallas_launches(fn: Callable, *args: Any, **kwargs: Any) -> int:
    """Number of `pallas_call` dispatches in one call of `fn(*args)`."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return _count_in_jaxpr(closed.jaxpr)
