import os
import sys

# The LLM cells lower against a 512-device placeholder mesh; the smallnet
# --smoke path is pure analytics + one tiny CPU lowering and must not pay
# the 512-device client startup (conftest documents the same rule for
# tests), so the flag is only set for the full sweep.
if "--smoke" not in sys.argv:
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        "--xla_disable_hlo_passes=while-loop-invariant-code-motion,"
        "while-loop-expensive-invariant-code-motion")

"""Roofline sweep -> benchmarks/roofline_results.json.

Full mode compiles every single-pod LLM cell and derives the three-term
roofline from the compiled HLO (the PR-0 seed behavior).  All modes also
emit the SMALLNET rows: analytic two-term rooflines for the perf-ledger
routes (tiler / composed sweep / megakernel sweep, ref + fixed_pallas
numerics) from `analysis/mfu.py`'s workload model, cross-checked against
`analysis/hlo_parse.py` conv FLOPs on the XLA-visible ref trunk.

    python -m repro.analysis.run_roofline [--arch A] [--shape S] [--force]
    python -m repro.analysis.run_roofline --smoke   # smallnet only, CI gate

--smoke is the bench-smoke CI lane: it recomputes only the smallnet rows
and exits nonzero if any roofline term is NaN/inf/zero-denominator or the
HLO cross-check drifts past 2% — the observability layer must never
silently rot.
"""
import argparse
import gc
import json
import math
import pathlib
import time
import traceback


RESULTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "roofline_results.json"


def smallnet_rows(device_name: str) -> tuple[dict, list[str]]:
    """(rows keyed 'smallnet-<backend>|<route>', failures).  Failures are
    non-finite terms, zero denominators, and HLO-cross-check drift."""
    from repro.analysis.roofline import smallnet_rooflines

    failures = []
    rows = smallnet_rooflines(device_name=device_name)
    for key, r in rows.items():
        for term in ("flops", "bytes", "intensity", "compute_s", "memory_s",
                     "attainable_flops", "peak_flops", "mem_bw"):
            v = r[term]
            if not math.isfinite(v):
                failures.append(f"{key}: {term}={v!r} is not finite")
            elif v <= 0:
                failures.append(f"{key}: {term}={v!r} — zero/negative "
                                f"denominator would make MFU meaningless")
    failures += _hlo_crosscheck()
    return rows, failures


def _hlo_crosscheck(H: int = 56, W: int = 56) -> list[str]:
    """Lower the plain ref trunk and compare XLA's conv FLOPs against the
    analytic model.  Only the float path is XLA-visible (Pallas launches
    are opaque custom calls — exactly why the ledger denominator is
    analytic), and only conv/dot ops are counted on both sides, so the
    two totals must agree to rounding."""
    import jax
    import jax.numpy as jnp

    from repro.analysis.hlo_parse import analyze_hlo
    from repro.analysis.mfu import trunk_workload
    from repro.core import smallnet

    params = smallnet.seeded_params()
    frame = jax.ShapeDtypeStruct((1, H, W, 1), jnp.float32)
    txt = jax.jit(
        lambda f: smallnet.conv_trunk(params, f, backend="ref")
    ).lower(frame).compile().as_text()
    hlo_flops = analyze_hlo(txt).flops
    model = trunk_workload(H, W, "trunk").flops
    if hlo_flops <= 0:
        return [f"hlo-crosscheck: XLA reports {hlo_flops} conv FLOPs for "
                f"the {H}x{W} ref trunk"]
    drift = abs(hlo_flops - model) / model
    if drift > 0.02:
        return [f"hlo-crosscheck: analytic trunk model {model} vs HLO "
                f"{hlo_flops:.0f} FLOPs ({drift:.1%} drift) — the workload "
                f"model no longer matches the compiled program"]
    return []


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="smallnet rows only; nonzero exit on NaN/zero "
                         "rooflines or HLO-model drift (CI bench-smoke)")
    ap.add_argument("--device", default="tpu-v5e",
                    help="MFU-database device for the smallnet rows")
    args = ap.parse_args()
    res = json.loads(RESULTS.read_text()) if RESULTS.exists() else {}

    rows, failures = smallnet_rows(args.device)
    res.update({k: dict(v, device=args.device) for k, v in rows.items()})
    for key in sorted(rows):
        r = rows[key]
        print(f"[roofline] {key} bound={r['bound']} "
              f"intensity={r['intensity']:.1f} flop/B "
              f"attainable={r['attainable_flops']:.3g} FLOP/s", flush=True)
    RESULTS.write_text(json.dumps(res, indent=1, sort_keys=True))

    if args.smoke:
        for f in failures:
            print(f"[roofline] FAIL {f}")
        print(f"[roofline] smoke {'FAIL' if failures else 'OK'}")
        return 1 if failures else 0

    from repro.analysis.roofline import roofline_from_artifacts, to_dict
    from repro.configs.base import ARCH_IDS, SHAPES, get_config
    from repro.launch.lowering import lower_cell, cell_report
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh()
    n_llm_failures = 0
    for arch in ARCH_IDS:
        if args.arch and arch != args.arch:
            continue
        cfg = get_config(arch)
        for s in SHAPES.values():
            if args.shape and s.name != args.shape:
                continue
            if s.name == "long_500k" and not cfg.supports_long_context():
                continue
            key = f"{arch}|{s.name}"
            if not args.force and key in res and "error" not in res[key]:
                continue
            t0 = time.time()
            print(f"[roofline] {key} ...", flush=True)
            try:
                art = lower_cell(arch, s.name, mesh)
                rep = cell_report(art)
                r = roofline_from_artifacts(arch, s.name, art.compiled.as_text(),
                                            rep.get("cost", {}), 256)
                d = to_dict(r)
                d["compile_seconds"] = round(time.time() - t0, 1)
                d["peak_bytes_per_device"] = rep.get("memory", {}).get(
                    "peak_estimate_per_device")
                res[key] = d
                print(f"[roofline] {key} dominant={d['dominant']} "
                      f"step={d['step_time_s']*1e3:.1f}ms "
                      f"frac={d['roofline_fraction']:.3f}", flush=True)
                del art
                gc.collect()
            except Exception as e:
                n_llm_failures += 1
                res[key] = {"error": f"{type(e).__name__}: {e}"}
                traceback.print_exc(limit=3)
            RESULTS.write_text(json.dumps(res, indent=1, sort_keys=True))
    print(f"[roofline] done, {n_llm_failures} failures")
    return 1 if (n_llm_failures or failures) else 0


if __name__ == "__main__":
    sys.exit(main())
