import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion,"
    "while-loop-expensive-invariant-code-motion")

"""Roofline sweep: compile every single-pod cell, derive the three-term
roofline from the compiled HLO, cache to benchmarks/roofline_results.json.

    python -m repro.analysis.run_roofline [--arch A] [--shape S] [--force]
"""
import argparse
import gc
import json
import pathlib
import sys
import time
import traceback


from repro.analysis.roofline import roofline_from_artifacts, to_dict
from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.launch.lowering import lower_cell, cell_report
from repro.launch.mesh import make_production_mesh

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "roofline_results.json"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    res = json.loads(RESULTS.read_text()) if RESULTS.exists() else {}
    mesh = make_production_mesh()
    failures = 0
    for arch in ARCH_IDS:
        if args.arch and arch != args.arch:
            continue
        cfg = get_config(arch)
        for s in SHAPES.values():
            if args.shape and s.name != args.shape:
                continue
            if s.name == "long_500k" and not cfg.supports_long_context():
                continue
            key = f"{arch}|{s.name}"
            if not args.force and key in res and "error" not in res[key]:
                continue
            t0 = time.time()
            print(f"[roofline] {key} ...", flush=True)
            try:
                art = lower_cell(arch, s.name, mesh)
                rep = cell_report(art)
                r = roofline_from_artifacts(arch, s.name, art.compiled.as_text(),
                                            rep.get("cost", {}), 256)
                d = to_dict(r)
                d["compile_seconds"] = round(time.time() - t0, 1)
                d["peak_bytes_per_device"] = rep.get("memory", {}).get(
                    "peak_estimate_per_device")
                res[key] = d
                print(f"[roofline] {key} dominant={d['dominant']} "
                      f"step={d['step_time_s']*1e3:.1f}ms "
                      f"frac={d['roofline_fraction']:.3f}", flush=True)
                del art
                gc.collect()
            except Exception as e:
                failures += 1
                res[key] = {"error": f"{type(e).__name__}: {e}"}
                traceback.print_exc(limit=3)
            RESULTS.write_text(json.dumps(res, indent=1, sort_keys=True))
    print(f"[roofline] done, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
