"""RWKV-6 "Finch" block: data-dependent-decay time mix + channel mix.

Faithful structure (token-shift LoRA modulation, per-channel decay
w = exp(-exp(.)), bonus `u`, per-head norm, gated output); the WKV linear
recurrence runs as a `lax.scan` over time with state (B, H, hd, hd) — O(1)
in sequence length, which is what qualifies this arch for `long_500k`.
The chunked GLA-style parallel form is a §Perf hillclimb variant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import scan_utils

LORA_RANK = 32


def init_rwkv_block(key, cfg) -> tuple[dict, dict]:
    d, dff = cfg.d_model, cfg.d_ff
    H, hd = cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 12)
    std = 1.0 / (d ** 0.5)
    dn = lambda k, sh, s=std: (jax.random.normal(k, sh, jnp.float32) * s).astype(cfg.param_dtype)
    p = {
        # time-mix interpolation params + LoRA
        "mu": dn(ks[0], (5, d), 0.02),             # per-channel mix for w,k,v,r,g
        "lora_a": dn(ks[1], (d, 5 * LORA_RANK)),
        "lora_b": dn(ks[2], (5, LORA_RANK, d), 0.02),
        "w0": dn(ks[3], (d,), 0.02),               # decay bias
        "u": dn(ks[4], (H, hd), 0.02),             # bonus
        "wr": dn(ks[5], (d, d)), "wk": dn(ks[6], (d, d)),
        "wv": dn(ks[7], (d, d)), "wg": dn(ks[8], (d, d)),
        "wo": dn(ks[9], (d, d)),
        "ln_x": jnp.ones((d,), cfg.param_dtype),   # per-head group norm scale
        # channel mix
        "mu_c": dn(ks[10], (2, d), 0.02),
        "ck": dn(ks[11], (d, dff)),
        "cr": dn(jax.random.fold_in(key, 101), (d, d)),
        "cv": dn(jax.random.fold_in(key, 102), (dff, d)),
    }
    a = {
        "mu": (None, None), "lora_a": ("fsdp", None), "lora_b": (None, None, "fsdp"),
        "w0": (None,), "u": (None, None),
        "wr": ("fsdp", "qkv"), "wk": ("fsdp", "qkv"),
        "wv": ("fsdp", "qkv"), "wg": ("fsdp", "qkv"), "wo": ("qkv", "fsdp"),
        "ln_x": (None,),
        "mu_c": (None, None), "ck": ("fsdp", "ffn"),
        "cr": ("fsdp", "qkv"), "cv": ("ffn", "fsdp"),
    }
    return p, a


def _mix_inputs(x, xprev, p, cfg):
    """Token-shift LoRA: five modulated interpolations (w,k,v,r,g)."""
    delta = xprev - x                                             # (B,T,d)
    base = x + delta * p["mu"][0].astype(x.dtype)
    lo = jnp.tanh(base @ p["lora_a"].astype(x.dtype))             # (B,T,5R)
    B, T, _ = x.shape
    lo = lo.reshape(B, T, 5, LORA_RANK)
    mod = jnp.einsum("btzr,zrd->btzd", lo, p["lora_b"].astype(x.dtype))
    mus = p["mu"].astype(x.dtype)                                 # (5, d)
    return [x + delta * (mus[z] + mod[:, :, z]) for z in range(5)]


def _wkv_scan(r, k, v, w, u, *, state=None):
    """Linear recurrence.  r,k,v (B,T,H,hd); w (B,T,H,hd) decay in (0,1).
    Returns (y (B,T,H,hd), final state (B,H,hd,hd))."""
    B, T, H, hd = r.shape
    if state is None:
        state = jnp.zeros((B, H, hd, hd), jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = [a.astype(jnp.float32) for a in inp]     # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]                  # (B,H,hd,hd)
        yt = jnp.einsum("bhk,bhkv->bhv", rt, S)
        S = wt[..., :, None] * S + kv
        return S, yt

    # Pin the scan inputs seq-UNsharded: the residual stream is
    # sequence-parallel ("res_seq" -> model), and scanning over a sharded
    # time axis makes GSPMD re-all-gather the whole stack EVERY step
    # (measured: 3.2 TB/step-loop on rwkv6 train_4k).  One gather per layer
    # here, reduce-scatter after the output projection.
    pin = lambda a: constrain(a, None, "batch", None, None)
    xs = (pin(r.swapaxes(0, 1)), pin(k.swapaxes(0, 1)), pin(v.swapaxes(0, 1)),
          pin(w.astype(jnp.bfloat16).swapaxes(0, 1)))
    state, ys = scan_utils.chunked_scan(step, state, xs)
    ys = pin(ys)       # pins the cotangent too: bwd scan must not re-gather
    y = ys.swapaxes(0, 1)
    # the `u` bonus term is separable from the recurrence:
    #   y_t = r_t.S_{t-1} + (sum_k r*u*k)_t * v_t
    # computing it vectorized outside the scan kills one einsum per step AND
    # a per-step (H,hd) gradient all-reduce that fired 524288x per train step
    bonus = jnp.einsum("bthk,hk,bthk->bth", r.astype(jnp.float32), u,
                       k.astype(jnp.float32))
    y = y + bonus[..., None] * v.astype(jnp.float32)
    return y, state


def time_mix(x, p, cfg, *, xprev_last=None, state=None):
    """x (B,T,d). For decode, xprev_last (B,d) is the previous token's x and
    state the carried WKV state; returns (out, (new_xprev, new_state))."""
    B, T, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    if xprev_last is None:
        xprev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        xprev = jnp.concatenate([xprev_last[:, None], x[:, :-1]], 1)
    xw, xk, xv, xr, xg = _mix_inputs(x, xprev, p, cfg)
    r = (xr @ p["wr"].astype(x.dtype)).reshape(B, T, H, hd)
    k = (xk @ p["wk"].astype(x.dtype)).reshape(B, T, H, hd)
    v = (xv @ p["wv"].astype(x.dtype)).reshape(B, T, H, hd)
    g = jax.nn.silu(xg @ p["wg"].astype(x.dtype))
    # decay: w0 + per-token LoRA-modulated channel decay (uses the xw branch)
    wlog = p["w0"].astype(jnp.float32)[None, None, :] + \
        jnp.tanh(xw.astype(jnp.float32) @ p["lora_a"].astype(jnp.float32)[:, :LORA_RANK]) @ \
        p["lora_b"][0].astype(jnp.float32)
    wdec = jnp.exp(-jnp.exp(jnp.clip(wlog, -8.0, 4.0))).reshape(B, T, H, hd)
    y, new_state = _wkv_scan(r, k, v, wdec, p["u"].astype(jnp.float32), state=state)
    # per-head group norm, then gate + out proj
    y = y.reshape(B, T, H, hd)
    mu = jnp.mean(y, -1, keepdims=True)
    var = jnp.mean(jnp.square(y - mu), -1, keepdims=True)
    y = ((y - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, T, d)
    y = (y * p["ln_x"].astype(jnp.float32)).astype(x.dtype) * g
    out = y @ p["wo"].astype(x.dtype)
    return out, (x[:, -1], new_state)


def channel_mix(x, p, cfg, *, xprev_last=None):
    if xprev_last is None:
        xprev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        xprev = jnp.concatenate([xprev_last[:, None], x[:, :-1]], 1)
    delta = xprev - x
    mus = p["mu_c"].astype(x.dtype)
    xk = x + delta * mus[0]
    xr = x + delta * mus[1]
    k = jnp.square(jax.nn.relu(xk @ p["ck"].astype(x.dtype)))
    k = constrain(k, "batch", "seq", "ffn")
    r = jax.nn.sigmoid(xr @ p["cr"].astype(x.dtype))
    return r * (k @ p["cv"].astype(x.dtype)), x[:, -1]


def rwkv_state_shape(batch: int, cfg):
    """Decode-carry state for one block."""
    H, hd, d = cfg.n_heads, cfg.head_dim, cfg.d_model
    return {
        "wkv": jax.ShapeDtypeStruct((batch, H, hd, hd), jnp.float32),
        "x_tm": jax.ShapeDtypeStruct((batch, d), cfg.dtype),
        "x_cm": jax.ShapeDtypeStruct((batch, d), cfg.dtype),
    }
