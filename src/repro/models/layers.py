"""Shared model layers: norms, RoPE, MLPs, embeddings.

Every init function returns (params, axes) where `axes` is a parallel pytree
of logical-axis-name tuples consumed by distributed.sharding.specs_from_axes.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), -1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layernorm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), -1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def apply_norm(x, p, kind: str):
    if kind == "rmsnorm":
        return rmsnorm(x, p["w"])
    return layernorm(x, p["w"], p["b"])


def init_norm(d: int, kind: str, dtype) -> tuple[dict, dict]:
    if kind == "rmsnorm":
        return {"w": jnp.ones((d,), dtype)}, {"w": (None,)}
    return ({"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)},
            {"w": (None,), "b": (None,)})


# --- RoPE -------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x (B, S, H, D); positions (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    if ang.ndim == 2:                                  # (S, D/2) -> broadcast B
        ang = ang[None]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# --- dense / linear ---------------------------------------------------------

def init_linear(key, d_in: int, d_out: int, dtype, *, bias: bool = False,
                in_axis: str | None = "fsdp", out_axis: str | None = "w_model",
                scale: float | None = None) -> tuple[dict, dict]:
    std = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)}
    a = {"w": (in_axis, out_axis)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
        a["b"] = (out_axis,)
    return p, a


def _materialize(w, compute_dtype):
    """int8 (paper-style baked) weights dequantize on-use; HBM moves 1 byte
    per element instead of 2 — the paper's quantized-deployment technique as
    a serving-roofline optimization."""
    from repro.core.ptq import QuantTensor
    if isinstance(w, QuantTensor):
        return w.q.astype(compute_dtype) * w.scale.astype(compute_dtype)
    return w.astype(compute_dtype)


def linear(x: jnp.ndarray, p: dict, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    y = x.astype(compute_dtype) @ _materialize(p["w"], compute_dtype)
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


# --- MLP --------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, kind: str, dtype) -> tuple[dict, dict]:
    ks = jax.random.split(key, 3)
    if kind == "gated":          # SwiGLU (llama family)
        wi, ai = init_linear(ks[0], d, d_ff, dtype)
        wg, ag = init_linear(ks[1], d, d_ff, dtype)
        wo, ao = init_linear(ks[2], d_ff, d, dtype, in_axis="w_model", out_axis="fsdp")
        return ({"wi": wi, "wg": wg, "wo": wo}, {"wi": ai, "wg": ag, "wo": ao})
    wi, ai = init_linear(ks[0], d, d_ff, dtype)
    wo, ao = init_linear(ks[2], d_ff, d, dtype, in_axis="w_model", out_axis="fsdp")
    return ({"wi": wi, "wo": wo}, {"wi": ai, "wo": ao})


def mlp(x: jnp.ndarray, p: dict, kind: str, compute_dtype=jnp.bfloat16,
        *, decode: bool = False) -> jnp.ndarray:
    if decode:
        # decode: batch-replicated activations + FSDP-sharded weights ->
        # partial-sum all-reduces (MBs) instead of weight gathers (100s MB)
        x = constrain(x, None, None, "embed")
    else:
        # explicit SP boundary before the TP matmul (see attention._qkv)
        x = constrain(x, "batch", None, "embed")
    if kind == "gated":
        h = jax.nn.silu(linear(x, p["wg"], compute_dtype)) * linear(x, p["wi"], compute_dtype)
    else:
        h = jax.nn.gelu(linear(x, p["wi"], compute_dtype))
    h = constrain(h, None if decode else "batch", "seq", "ffn")
    return linear(h, p["wo"], compute_dtype)


# --- embeddings -------------------------------------------------------------

def init_embed(key, vocab: int, d: int, dtype) -> tuple[dict, dict]:
    p = {"w": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}
    return p, {"w": ("vocab", "fsdp")}


def embed(tokens: jnp.ndarray, p: dict, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    from repro.core.ptq import QuantTensor
    w = p["w"]
    if isinstance(w, QuantTensor):
        rows = jnp.take(w.q, tokens, axis=0).astype(compute_dtype)
        return rows * w.scale.reshape(-1).astype(compute_dtype)
    return jnp.take(w.astype(compute_dtype), tokens, axis=0)
