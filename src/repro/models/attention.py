"""GQA attention: chunked-causal (train/prefill) + KV-cache decode.

Memory discipline comes from chunking over query blocks with a `lax.scan`
(the pure-JAX "flash" pattern): scores for one (q-chunk x full-KV) tile live
at a time, so 32k-token prefill never materializes an (S, S) matrix.

Sharding: Q/K/V projections are TP-sharded on the flattened head dim
("qkv" -> model); the attention core shards "heads" over model when the head
count divides the axis, else GSPMD resolves from the projection shardings.
Decode KV caches shard head_dim over model (always divisible: 64/128).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers

NEG_INF = -1e9


def init_attention(key, cfg) -> tuple[dict, dict]:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    wq, aq = layers.init_linear(ks[0], d, H * hd, cfg.param_dtype, bias=cfg.qkv_bias,
                                out_axis="qkv")
    wk, ak = layers.init_linear(ks[1], d, K * hd, cfg.param_dtype, bias=cfg.qkv_bias,
                                out_axis="qkv")
    wv, av = layers.init_linear(ks[2], d, K * hd, cfg.param_dtype, bias=cfg.qkv_bias,
                                out_axis="qkv")
    wo, ao = layers.init_linear(ks[3], H * hd, d, cfg.param_dtype,
                                in_axis="qkv", out_axis="fsdp")
    return ({"wq": wq, "wk": wk, "wv": wv, "wo": wo},
            {"wq": aq, "wk": ak, "wv": av, "wo": ao})


def _qkv(x, p, cfg, positions):
    B, S, _ = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    # explicit SP boundary (Megatron): all-gather the seq-sharded residual
    # BEFORE the TP projection — without this GSPMD resolves the
    # seq-model/TP-model conflict by fully replicating W_qkv instead
    # (measured: 4 TB/step of f32[16384,16384] gathers on llama3-405b)
    x = constrain(x, "batch", None, "embed")
    q = layers.linear(x, p["wq"], cfg.dtype).reshape(B, S, H, hd)
    k = layers.linear(x, p["wk"], cfg.dtype).reshape(B, S, K, hd)
    v = layers.linear(x, p["wv"], cfg.dtype).reshape(B, S, K, hd)
    if cfg.use_rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", None)
    # context-parallel fallback (see make_rules "kv_seq"): only when the
    # KV length divides the model axis — whisper's 1500-frame encoder keeps
    # the replicated path
    kv_seq = "kv_seq" if S % 16 == 0 else "seq"
    k = constrain(k, "batch", kv_seq, "kv_heads", None)
    v = constrain(v, "batch", kv_seq, "kv_heads", None)
    return q, k, v


def _gqa_scores(q, k, scale):
    """q (B,Sq,H,hd), k (B,Skv,K,hd) -> (B, Sq, H, Skv) with GQA grouping."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd)
    s = jnp.einsum("bqkgd,btkd->bqkgt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    return s.reshape(B, Sq, H, k.shape[1])


def _gqa_out(w, v):
    """w (B,Sq,H,Skv) f32, v (B,Skv,K,hd) -> (B,Sq,H,hd)."""
    B, Sq, H, T = w.shape
    K = v.shape[2]
    G = H // K
    wg = w.reshape(B, Sq, K, G, T)
    o = jnp.einsum("bqkgt,btkd->bqkgd", wg, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, v.shape[3])


def causal_attention(q, k, v, *, q_chunk: int = 512, causal: bool = True):
    """Chunked attention over query blocks. Shapes as in _gqa_scores."""
    B, S, H, hd = q.shape
    scale = 1.0 / (hd ** 0.5)
    nchunk = max(1, S // q_chunk)
    assert S % nchunk == 0, (S, q_chunk)
    c = S // nchunk
    qs = q.reshape(B, nchunk, c, H, hd).swapaxes(0, 1)   # (n, B, c, H, hd)

    @jax.checkpoint                                      # recompute per-chunk
    def _chunk(i, qc):                                   # scores in bwd (never
        s = _gqa_scores(qc, k, scale)                    # stack f32 (B,c,H,S)
        if causal:                                       # across chunks)
            qpos = i * c + jnp.arange(c)
            kpos = jnp.arange(S)
            mask = kpos[None, :] <= qpos[:, None]        # (c, S)
            s = jnp.where(mask[None, :, None, :], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        return _gqa_out(w, v).astype(q.dtype)            # (B, c, H, hd)

    def chunk_fn(carry, args):
        i, qc = args
        return carry, _chunk(i, qc)

    _, outs = jax.lax.scan(chunk_fn, None, (jnp.arange(nchunk), qs))
    return outs.swapaxes(0, 1).reshape(B, S, H, hd)


def attention_block(x, p, cfg, positions, *, causal=True):
    """Full attention sublayer: qkv -> chunked attention -> out proj."""
    B, S, _ = x.shape
    q, k, v = _qkv(x, p, cfg, positions)
    o = causal_attention(q, k, v, q_chunk=min(cfg.q_chunk, S), causal=causal)
    o = constrain(o, "batch", "seq", "heads", None)
    return layers.linear(o.reshape(B, S, -1), p["wo"], cfg.dtype)


# --- decode with KV cache ----------------------------------------------------

class KVCache(NamedTuple):
    k: jnp.ndarray   # (B, T, K, hd)
    v: jnp.ndarray   # (B, T, K, hd)


def init_kv_cache(batch: int, max_len: int, cfg, dtype=None) -> KVCache:
    dt = dtype or cfg.dtype
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dt), jnp.zeros(shape, dt))


def decode_attention_block(x, p, cfg, cache: KVCache, pos: jnp.ndarray):
    """x (B, 1, d); pos scalar int32 (current position); returns (out, cache)."""
    B = x.shape[0]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    # decode activations are tiny: replicate the batch so the FSDP-sharded
    # weight contracts into partial sums (MB-scale all-reduces) instead of
    # GSPMD gathering the weights (measured 88 GiB/token on llama3-405b)
    x = constrain(x, None, None, "embed")
    q = layers.linear(x, p["wq"], cfg.dtype).reshape(B, 1, H, hd)
    k = layers.linear(x, p["wk"], cfg.dtype).reshape(B, 1, K, hd)
    v = layers.linear(x, p["wv"], cfg.dtype).reshape(B, 1, K, hd)
    if cfg.use_rope:
        posb = jnp.full((B, 1), pos, jnp.int32)
        q = layers.apply_rope(q, posb, cfg.rope_theta)
        k = layers.apply_rope(k, posb, cfg.rope_theta)
    # masked token write: elementwise over the T-sharded cache, so the
    # update never crosses shards (a dynamic-update-slice on a sharded seq
    # axis would make GSPMD gather the whole cache)
    T_ = cache.k.shape[1]
    write = (jnp.arange(T_)[None, :, None, None] == pos)
    ck = jnp.where(write, k.astype(cache.k.dtype), cache.k)
    cv = jnp.where(write, v.astype(cache.v.dtype), cache.v)
    ck = constrain(ck, "cache_batch", "cache_seq", None, None)
    cv = constrain(cv, "cache_batch", "cache_seq", None, None)
    T = ck.shape[1]
    scale = 1.0 / (hd ** 0.5)
    s = _gqa_scores(q, ck, scale)                        # (B, 1, H, T)
    mask = jnp.arange(T)[None, None, None, :] <= pos
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = _gqa_out(w, cv).astype(x.dtype).reshape(B, 1, H * hd)
    # contraction-sharded input -> wo stays resident (partial-sum AR instead
    # of gathering wo over the fsdp axis)
    o = constrain(o, None, None, "qkv")
    return layers.linear(o, p["wo"], cfg.dtype), KVCache(ck, cv)


# --- cross attention (whisper decoder) ---------------------------------------

def cross_attention_block(x, p, cfg, enc_k, enc_v):
    """x (B,S,d); enc_k/enc_v (B,T,K,hd) precomputed from encoder output."""
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    q = layers.linear(x, p["wq"], cfg.dtype).reshape(B, S, H, hd)
    scale = 1.0 / (hd ** 0.5)
    s = _gqa_scores(q, enc_k, scale)
    w = jax.nn.softmax(s, axis=-1)
    o = _gqa_out(w, enc_v).astype(x.dtype).reshape(B, S, H * hd)
    return layers.linear(o, p["wo"], cfg.dtype)


def encoder_kv(enc_out, p, cfg):
    B, T, _ = enc_out.shape
    K, hd = cfg.n_kv_heads, cfg.head_dim
    k = layers.linear(enc_out, p["wk"], cfg.dtype).reshape(B, T, K, hd)
    v = layers.linear(enc_out, p["wv"], cfg.dtype).reshape(B, T, K, hd)
    return k, v
