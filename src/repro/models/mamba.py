"""Selective SSM (Mamba) block for the Jamba hybrid.

in_proj -> causal depthwise conv1d (k=4) -> silu -> selective scan
(data-dependent Δ, B, C; diagonal A) -> gate -> out_proj.
State is (B, d_inner, d_state): O(1) in sequence length (long_500k-capable).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import scan_utils

D_STATE = 16
D_CONV = 4
DT_RANK_DIV = 16     # dt_rank = d_model / 16


def init_mamba_block(key, cfg) -> tuple[dict, dict]:
    d = cfg.d_model
    d_in = 2 * d
    dt_rank = max(1, d // DT_RANK_DIV)
    ks = jax.random.split(key, 6)
    std = 1.0 / (d ** 0.5)
    dn = lambda k, sh, s=std: (jax.random.normal(k, sh, jnp.float32) * s).astype(cfg.param_dtype)
    p = {
        "in_proj": dn(ks[0], (d, 2 * d_in)),          # x & gate
        "conv_w": dn(ks[1], (D_CONV, d_in), 0.2),     # depthwise
        "conv_b": jnp.zeros((d_in,), cfg.param_dtype),
        "x_proj": dn(ks[2], (d_in, dt_rank + 2 * D_STATE)),
        "dt_proj": dn(ks[3], (dt_rank, d_in), 0.1),
        "dt_bias": jnp.zeros((d_in,), cfg.param_dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, D_STATE + 1, dtype=jnp.float32)[None],
                                  (d_in, 1))).astype(cfg.param_dtype),
        "D": jnp.ones((d_in,), cfg.param_dtype),
        "out_proj": dn(ks[5], (d_in, d)),
    }
    a = {
        "in_proj": ("fsdp", "ffn"), "conv_w": (None, "ffn"), "conv_b": ("ffn",),
        "x_proj": ("ffn", None), "dt_proj": (None, "ffn"), "dt_bias": ("ffn",),
        "A_log": ("ffn", None), "D": ("ffn",), "out_proj": ("ffn", "fsdp"),
    }
    return p, a


def _causal_conv(x, w, b, *, state=None):
    """Depthwise causal conv along T. x (B,T,C); w (K,C); returns (y, new_state)
    where state is the last K-1 inputs (B, K-1, C)."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return y + b[None, None, :], xp[:, -(K - 1):, :]


def _selective_scan(u, dt, Bc, Cc, A, D, *, state=None):
    """u (B,T,C); dt (B,T,C); Bc/Cc (B,T,N); A (C,N); D (C,).
    h_t = exp(dt*A) h + dt*B*u ; y = C.h + D*u. Returns (y, final h (B,C,N))."""
    Bsz, T, C = u.shape
    N = A.shape[1]
    if state is None:
        state = jnp.zeros((Bsz, C, N), jnp.float32)

    def step(h, inp):
        ut, dtt, bt, ct = [a.astype(jnp.float32) for a in inp]   # upcast per step
        dA = jnp.exp(dtt[..., None] * A[None])      # (B,C,N)
        dBu = (dtt * ut)[..., None] * bt[:, None, :]
        h = dA * h + dBu
        yt = jnp.einsum("bcn,bn->bc", h, ct)
        return h, yt

    # pin scan inputs seq-unsharded (see rwkv6._wkv_scan: scanning a
    # res_seq-sharded axis degenerates to per-step whole-stack all-gathers)
    pin3 = lambda a: constrain(a, None, "batch", "ffn")
    pin_n = lambda a: constrain(a, None, "batch", None)
    xs = (pin3(u.swapaxes(0, 1)), pin3(dt.swapaxes(0, 1)),
          pin_n(Bc.swapaxes(0, 1)), pin_n(Cc.swapaxes(0, 1)))
    state, ys = scan_utils.chunked_scan(step, state, xs)
    ys = pin3(ys)      # pins the cotangent too: bwd scan must not re-gather
    return ys.swapaxes(0, 1) + u.astype(jnp.float32) * D[None, None, :], state


def mamba_block(x, p, cfg, *, state=None):
    """x (B,T,d) -> (out, new_state). state = {"conv": (B,3,d_in), "ssm": (B,d_in,N)}."""
    B, T, d = x.shape
    dt_rank = max(1, d // DT_RANK_DIV)
    st_conv = None if state is None else state["conv"]
    st_ssm = None if state is None else state["ssm"]
    xz = x @ p["in_proj"].astype(x.dtype)           # (B,T,2*d_in)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = constrain(xs, "batch", "seq", "ffn")
    xs, new_conv = _causal_conv(xs, p["conv_w"].astype(x.dtype),
                                p["conv_b"].astype(x.dtype), state=st_conv)
    xs = jax.nn.silu(xs)
    proj = xs @ p["x_proj"].astype(x.dtype)         # (B,T,dt_rank+2N)
    dt_raw = proj[..., :dt_rank]
    Bc = proj[..., dt_rank:dt_rank + D_STATE]
    Cc = proj[..., dt_rank + D_STATE:]
    dt = jax.nn.softplus(dt_raw @ p["dt_proj"].astype(x.dtype)
                         + p["dt_bias"].astype(x.dtype))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, new_ssm = _selective_scan(xs, dt, Bc, Cc, A,
                                 p["D"].astype(jnp.float32), state=st_ssm)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, {"conv": new_conv, "ssm": new_ssm}


def mamba_state_shape(batch: int, cfg):
    d_in = 2 * cfg.d_model
    return {
        "conv": jax.ShapeDtypeStruct((batch, D_CONV - 1, d_in), cfg.dtype),
        "ssm": jax.ShapeDtypeStruct((batch, d_in, D_STATE), jnp.float32),
    }
