"""Chunked linear-recurrence scan with per-chunk remat.

A plain `lax.scan` over T timesteps saves per-step residuals for AD —
O(T * state) memory, which is what made jamba/rwkv training blow past HBM
(57 GiB/device at 4k x 16384 x f32).  Chunking saves only chunk-boundary
states and recomputes inside a chunk on the backward pass:
memory O(T/C * state + C * step_temps), compute +1 forward of the chunk.

Also keeps inputs in their storage dtype (bf16) across the outer scan and
upcasts *inside* the chunk, halving the stacked-input footprint.
"""
from __future__ import annotations


import jax


def chunked_scan(step, init_state, xs, *, chunk: int = 128):
    """Like lax.scan(step, init_state, xs) for time-major xs (T leading),
    with per-chunk remat.  `step(state, x_t) -> (state, y_t)`."""
    T = jax.tree_util.tree_leaves(xs)[0].shape[0]
    c = min(chunk, T)
    while T % c:
        c -= 1
    n = T // c
    if n == 1:
        return jax.lax.scan(step, init_state, xs)

    xs_c = jax.tree_util.tree_map(
        lambda a: a.reshape((n, c) + a.shape[1:]), xs)

    @jax.checkpoint
    def chunk_body(state, xc):
        return jax.lax.scan(step, state, xc)

    state, ys = jax.lax.scan(chunk_body, init_state, xs_c)
    ys = jax.tree_util.tree_map(
        lambda a: a.reshape((T,) + a.shape[2:]), ys)
    return state, ys
