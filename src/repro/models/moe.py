"""Mixture-of-Experts: top-k router + GShard-style capacity dispatch.

Baseline dispatch is the classic TPU einsum form (GShard/Switch): tokens are
grouped (group dim shards over data), each group routes via a (g, E, C)
one-hot dispatch/combine tensor and two einsums.  Fully static shapes, EP
shards experts over "model".

Cost note (napkin math recorded for §Perf): dispatch+combine einsums cost
~ 2 * 2 * (g*k*cf) * d FLOPs/token.  At g=512, k=8, cf=1.25, d=4096 that is
~28 % of the expert FFN FLOPs for qwen3-moe — the acknowledged baseline
overhead that the sorted/gather dispatch hillclimb variant removes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain


def init_moe(key, cfg) -> tuple[dict, dict]:
    d, dff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    std = 1.0 / (d ** 0.5)
    p = {
        "router": {"w": (jax.random.normal(ks[0], (d, E), jnp.float32) * std
                         ).astype(cfg.param_dtype)},
        "wi": (jax.random.normal(ks[1], (E, d, dff), jnp.float32) * std).astype(cfg.param_dtype),
        "wg": (jax.random.normal(ks[2], (E, d, dff), jnp.float32) * std).astype(cfg.param_dtype),
        "wo": (jax.random.normal(ks[3], (E, dff, d), jnp.float32) * (1.0 / dff ** 0.5)
               ).astype(cfg.param_dtype),
    }
    a = {
        "router": {"w": (None, None)},
        "wi": ("experts", "fsdp", None),
        "wg": ("experts", "fsdp", None),
        "wo": ("experts", None, "fsdp"),
    }
    return p, a


def _pick_group(T: int, group_size: int) -> int:
    """Largest divisor of T that is <= group_size."""
    g = min(group_size, T)
    while T % g:
        g -= 1
    return g


def moe_mlp(x, p, cfg, *, group_size: int = 512, capacity_factor: float = 1.25):
    """x (B, S, d) -> ((B, S, d), aux_loss). GShard grouped capacity dispatch."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    g = _pick_group(T, group_size)
    G = T // g
    C = max(1, int(g * k * capacity_factor / E))
    xt = x.reshape(G, g, d)
    xt = constrain(xt, "expert_group", None, None)

    # --- router (f32) ---
    logits = jnp.einsum("gsd,de->gse", xt.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (G,g,E)
    topw, topi = jax.lax.top_k(probs, k)                       # (G,g,k)
    topw = topw / jnp.sum(topw, -1, keepdims=True)
    # Switch-style load-balance aux
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(topi[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # --- capacity positions: rank of each (token, choice) in its expert queue
    oh = jax.nn.one_hot(topi, E, dtype=jnp.int32)              # (G,g,k,E)
    flat = oh.reshape(G, g * k, E)
    pos = (jnp.cumsum(flat, axis=1) - 1).reshape(G, g, k, E)   # (G,g,k,E)
    pos_k = jnp.sum(pos * oh, axis=-1)                         # (G,g,k)
    in_cap = pos_k < C

    # --- combine tensor (G,g,E,C), built per-k to avoid a 5-D intermediate
    combine = jnp.zeros((G, g, E, C), jnp.float32)
    for kk in range(k):
        oe = jax.nn.one_hot(topi[..., kk], E, dtype=jnp.float32)       # (G,g,E)
        oc = jax.nn.one_hot(jnp.where(in_cap[..., kk], pos_k[..., kk], -1),
                            C, dtype=jnp.float32)                      # (G,g,C)
        combine = combine + topw[..., kk, None, None] * oe[..., None] * oc[:, :, None, :]
    # pin shardings on every routing tensor: without these GSPMD invents a
    # combined-axis sharding for g and then falls back to full replication
    # on the dispatch/combine einsums (observed on jamba: 5 GiB/device)
    combine = constrain(combine, "expert_group", None, "experts", None)
    dispatch = (combine > 0).astype(cfg.dtype)                 # (G,g,E,C)
    dispatch = constrain(dispatch, "expert_group", None, "experts", None)

    # --- dispatch -> expert FFN -> combine ---
    xe = jnp.einsum("gsec,gsd->egcd", dispatch, xt.astype(cfg.dtype))
    xe = constrain(xe, "experts", "expert_group", None, None)
    wi = p["wi"].astype(cfg.dtype)
    wg = p["wg"].astype(cfg.dtype)
    wo = p["wo"].astype(cfg.dtype)
    if cfg.mlp == "gated":
        h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xe, wg)) * \
            jnp.einsum("egcd,edf->egcf", xe, wi)
    else:
        h = jax.nn.gelu(jnp.einsum("egcd,edf->egcf", xe, wi))
    h = constrain(h, "experts", "expert_group", None, None)
    ye = jnp.einsum("egcf,efd->egcd", h, wo)
    ye = constrain(ye, "experts", "expert_group", None, None)
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(cfg.dtype), ye)
    y = constrain(y, "expert_group", None, None)
    return y.reshape(B, S, d), aux
