"""Architecture assembly: decoder stacks, hybrid interleave, enc-dec, VLM.

All stacks scan over stacked layer params (`lax.scan`), optionally with
per-layer remat — this keeps the HLO one-layer-sized (critical for the
512-device dry-run compiles) and bounds activation memory.

Entry points (all pure functions of (cfg, params, ...)):
    init_params(cfg, key)              -> (params, axes)
    forward(cfg, params, batch)        -> (logits, aux)       [train/prefill math]
    prefill(cfg, params, batch)        -> (last_logits, cache)
    decode_step(cfg, params, cache, token, pos) -> (logits, cache)
    init_cache_shape(cfg, batch, max_len)       -> pytree of ShapeDtypeStruct
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models import layers, mamba, moe, rwkv6

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def _stack_init(init_fn, key, n: int):
    """vmap an init over n keys -> stacked params; prepend 'layers' axis name."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    _, axes = init_fn(key)
    axes = jax.tree_util.tree_map(lambda a: ("layers",) + a, axes,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return params, axes


def _init_dense_block(cfg, key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    pa, aa = attn.init_attention(k1, cfg)
    n1, an1 = layers.init_norm(cfg.d_model, cfg.norm, cfg.param_dtype)
    n2, an2 = layers.init_norm(cfg.d_model, cfg.norm, cfg.param_dtype)
    if cfg.family in ("moe",):
        pm, am = moe.init_moe(k2, cfg)
    else:
        pm, am = layers.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp, cfg.param_dtype)
    return ({"attn": pa, "mlp": pm, "norm1": n1, "norm2": n2},
            {"attn": aa, "mlp": am, "norm1": an1, "norm2": an2})


def _init_rwkv_layer(cfg, key):
    p, a = rwkv6.init_rwkv_block(key, cfg)
    n1, an1 = layers.init_norm(cfg.d_model, cfg.norm, cfg.param_dtype)
    n2, an2 = layers.init_norm(cfg.d_model, cfg.norm, cfg.param_dtype)
    return ({"rwkv": p, "norm1": n1, "norm2": n2},
            {"rwkv": a, "norm1": an1, "norm2": an2})


def _init_jamba_superblock(cfg, key):
    """8 sublayers: mamba at all slots except attn_offset; MoE every 2nd."""
    P = cfg.attn_period
    ks = jax.random.split(key, 2 * P + 2)
    subs_p, subs_a = {}, {}
    # 7 mamba mixers (stacked), 1 attention mixer
    pm, am = _stack_init(lambda k: mamba.init_mamba_block(k, cfg), ks[0], P - 1)
    pa, aa = attn.init_attention(ks[1], cfg)
    # MLPs: alternate dense / MoE across the P sublayers
    n_moe = P // cfg.moe_every
    pmoe, amoe = _stack_init(lambda k: moe.init_moe(k, cfg), ks[2], n_moe)
    pmlp, amlp = _stack_init(
        lambda k: layers.init_mlp(k, cfg.d_model, cfg.d_ff, cfg.mlp, cfg.param_dtype),
        ks[3], P - n_moe)
    norms_p, norms_a = _stack_init(
        lambda k: (layers.init_norm(cfg.d_model, cfg.norm, cfg.param_dtype)),
        ks[4], 2 * P)
    return ({"mamba": pm, "attn": pa, "moe": pmoe, "mlp": pmlp, "norms": norms_p},
            {"mamba": am, "attn": aa, "moe": amoe, "mlp": amlp, "norms": norms_a})


def _init_whisper_dec_block(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    psa, asa = attn.init_attention(k1, cfg)
    pca, aca = attn.init_attention(k2, cfg)
    pm, am = layers.init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.mlp, cfg.param_dtype)
    norms = [layers.init_norm(cfg.d_model, cfg.norm, cfg.param_dtype) for _ in range(3)]
    return ({"self": psa, "cross": pca, "mlp": pm,
             "norm1": norms[0][0], "norm2": norms[1][0], "norm3": norms[2][0]},
            {"self": asa, "cross": aca, "mlp": am,
             "norm1": norms[0][1], "norm2": norms[1][1], "norm3": norms[2][1]})


def init_params(cfg, key) -> tuple[dict, dict]:
    ks = jax.random.split(key, 8)
    pe, ae = layers.init_embed(ks[0], cfg.vocab_padded, cfg.d_model, cfg.param_dtype)
    nf, anf = layers.init_norm(cfg.d_model, cfg.norm, cfg.param_dtype)
    params: dict = {"embed": pe, "final_norm": nf}
    axes: dict = {"embed": ae, "final_norm": anf}
    if not cfg.tie_embeddings:
        ph, ah = layers.init_linear(ks[1], cfg.d_model, cfg.vocab_padded,
                                    cfg.param_dtype, out_axis="vocab")
        params["lm_head"], axes["lm_head"] = ph, ah

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        params["blocks"], axes["blocks"] = _stack_init(
            lambda k: _init_dense_block(cfg, k), ks[2], cfg.n_layers)
    elif fam == "ssm":
        params["blocks"], axes["blocks"] = _stack_init(
            lambda k: _init_rwkv_layer(cfg, k), ks[2], cfg.n_layers)
    elif fam == "hybrid":
        n_super = cfg.n_layers // cfg.attn_period
        params["blocks"], axes["blocks"] = _stack_init(
            lambda k: _init_jamba_superblock(cfg, k), ks[2], n_super)
    elif fam == "audio":
        params["enc_blocks"], axes["enc_blocks"] = _stack_init(
            lambda k: _init_dense_block(cfg, k), ks[3], cfg.encoder_layers)
        params["blocks"], axes["blocks"] = _stack_init(
            lambda k: _init_whisper_dec_block(cfg, k), ks[2], cfg.n_layers)
        params["enc_pos"] = (jax.random.normal(ks[4], (cfg.encoder_frames, cfg.d_model),
                                               jnp.float32) * 0.02).astype(cfg.param_dtype)
        params["dec_pos"] = (jax.random.normal(ks[5], (32768, cfg.d_model),
                                               jnp.float32) * 0.02).astype(cfg.param_dtype)
        axes["enc_pos"] = (None, None)
        axes["dec_pos"] = (None, None)
        pn, an = layers.init_norm(cfg.d_model, cfg.norm, cfg.param_dtype)
        params["enc_final_norm"], axes["enc_final_norm"] = pn, an
    if fam == "vlm":
        pv, av = layers.init_linear(ks[6], cfg.vit_dim, cfg.d_model,
                                    cfg.param_dtype, in_axis=None, out_axis="fsdp")
        params["vision_proj"], axes["vision_proj"] = pv, av
    return params, axes


# ---------------------------------------------------------------------------
# block bodies
# ---------------------------------------------------------------------------

def _dense_body(cfg, x, blk, positions, *, causal=True):
    h = x + attn.attention_block(
        layers.apply_norm(x, blk["norm1"], cfg.norm), blk["attn"], cfg,
        positions, causal=causal)
    hn = layers.apply_norm(h, blk["norm2"], cfg.norm)
    if cfg.family == "moe":
        y, aux = moe.moe_mlp(hn, blk["mlp"], cfg)
    else:
        y, aux = layers.mlp(hn, blk["mlp"], cfg.mlp, cfg.dtype), 0.0
    h = h + y
    h = constrain(h, "batch", "res_seq", "embed")
    return h, aux


def _rwkv_body(cfg, x, blk):
    y, _ = rwkv6.time_mix(layers.apply_norm(x, blk["norm1"], cfg.norm),
                          blk["rwkv"], cfg)
    h = x + y
    y, _ = rwkv6.channel_mix(layers.apply_norm(h, blk["norm2"], cfg.norm),
                             blk["rwkv"], cfg)
    h = h + y
    return constrain(h, "batch", "res_seq", "embed"), 0.0


def _jamba_body(cfg, x, blk, positions):
    P = cfg.attn_period
    aux_total = 0.0
    mi = 0          # mamba sublayer index
    di = 0          # dense-mlp index
    ei = 0          # moe index
    for s in range(P):
        n1 = jax.tree_util.tree_map(lambda p: p[2 * s], blk["norms"])
        n2 = jax.tree_util.tree_map(lambda p: p[2 * s + 1], blk["norms"])
        xn = layers.apply_norm(x, n1, cfg.norm)
        if s == cfg.attn_offset:
            y = attn.attention_block(xn, blk["attn"], cfg, positions, causal=True)
        else:
            mp = jax.tree_util.tree_map(lambda p: p[mi], blk["mamba"])
            y, _ = mamba.mamba_block(xn, mp, cfg)
            mi += 1
        x = x + y
        xn = layers.apply_norm(x, n2, cfg.norm)
        if s % cfg.moe_every == cfg.moe_every - 1:
            ep = jax.tree_util.tree_map(lambda p: p[ei], blk["moe"])
            y, aux = moe.moe_mlp(xn, ep, cfg)
            aux_total = aux_total + aux
            ei += 1
        else:
            dp = jax.tree_util.tree_map(lambda p: p[di], blk["mlp"])
            y = layers.mlp(xn, dp, cfg.mlp, cfg.dtype)
            di += 1
        x = x + y
    return constrain(x, "batch", "res_seq", "embed"), aux_total


def _whisper_dec_body(cfg, x, blk, positions, enc_k, enc_v):
    h = x + attn.attention_block(
        layers.apply_norm(x, blk["norm1"], cfg.norm), blk["self"], cfg,
        positions, causal=True)
    h = h + attn.cross_attention_block(
        layers.apply_norm(h, blk["norm2"], cfg.norm), blk["cross"], cfg, enc_k, enc_v)
    h = h + layers.mlp(layers.apply_norm(h, blk["norm3"], cfg.norm),
                       blk["mlp"], cfg.mlp, cfg.dtype)
    return constrain(h, "batch", "res_seq", "embed"), 0.0


def _scan_blocks(cfg, x, stacked, body):
    """Scan x through stacked blocks; body(x, blk) -> (x, aux)."""
    fn = body
    if cfg.remat:
        fn = jax.checkpoint(body)

    def step(carry, blk):
        x, aux = carry
        x, a = fn(x, blk)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)), stacked)
    return x, aux


# ---------------------------------------------------------------------------
# forward (train / prefill math)
# ---------------------------------------------------------------------------

def _encode_audio(cfg, params, frames):
    """frames (B, F, d_model) — precomputed by the stub conv frontend."""
    x = frames.astype(cfg.dtype) + params["enc_pos"][None, :frames.shape[1]].astype(cfg.dtype)
    positions = jnp.arange(frames.shape[1])
    x, _ = _scan_blocks(cfg, x, params["enc_blocks"],
                        lambda x, blk: _dense_body(cfg, x, blk, positions, causal=False))
    return layers.apply_norm(x, params["enc_final_norm"], cfg.norm)


def _logits(cfg, params, x):
    if cfg.tie_embeddings:
        w = layers._materialize(params["embed"]["w"], cfg.dtype)
        logits = jnp.einsum("bsd,vd->bsv", x, w)
    else:
        logits = layers.linear(x, params["lm_head"], cfg.dtype)
    return constrain(logits.astype(jnp.float32), "batch", "seq", "vocab")


def forward(cfg, params, batch: dict) -> tuple[jnp.ndarray, jnp.ndarray]:
    """batch: {"tokens": (B,S) int32, optional "frames"/"vision"} ->
    (logits (B,S,vocab_padded) f32, aux)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = layers.embed(tokens, params["embed"], cfg.dtype)
    x = constrain(x, "batch", "res_seq", "embed")
    positions = jnp.arange(S)
    fam = cfg.family

    if fam == "vlm":
        v = layers.linear(batch["vision"].astype(cfg.dtype), params["vision_proj"], cfg.dtype)
        x = jnp.concatenate([v, x[:, cfg.vision_tokens:]], axis=1)
    if fam == "audio":
        x = x + params["dec_pos"][None, :S].astype(cfg.dtype)
        enc_out = _encode_audio(cfg, params, batch["frames"])
        # cross K/V computed once per decoder layer inside the body (scanned)
        def body(x, blk):
            ek, ev = attn.encoder_kv(enc_out, blk["cross"], cfg)
            return _whisper_dec_body(cfg, x, blk, positions, ek, ev)
        x, aux = _scan_blocks(cfg, x, params["blocks"], body)
    elif fam in ("dense", "moe", "vlm"):
        x, aux = _scan_blocks(cfg, x, params["blocks"],
                              lambda x, blk: _dense_body(cfg, x, blk, positions))
    elif fam == "ssm":
        x, aux = _scan_blocks(cfg, x, params["blocks"],
                              lambda x, blk: _rwkv_body(cfg, x, blk))
    elif fam == "hybrid":
        x, aux = _scan_blocks(cfg, x, params["blocks"],
                              lambda x, blk: _jamba_body(cfg, x, blk, positions))
    else:
        raise ValueError(fam)
    x = layers.apply_norm(x, params["final_norm"], cfg.norm)
    return _logits(cfg, params, x), aux


def loss_fn(cfg, params, batch: dict) -> tuple[jnp.ndarray, dict]:
    """Next-token CE (labels = batch['labels'])."""
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(lse - gold)
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode with caches
# ---------------------------------------------------------------------------

def init_cache_shape(cfg, batch: int, max_len: int):
    """Abstract cache pytree (ShapeDtypeStructs) for dry-run and engine alloc."""
    fam = cfg.family
    K, hd = cfg.n_kv_heads, cfg.head_dim
    kv = lambda L: {
        "k": jax.ShapeDtypeStruct((L, batch, max_len, K, hd), cfg.dtype),
        "v": jax.ShapeDtypeStruct((L, batch, max_len, K, hd), cfg.dtype),
    }
    if fam in ("dense", "moe", "vlm"):
        return kv(cfg.n_layers)
    if fam == "ssm":
        st = rwkv6.rwkv_state_shape(batch, cfg)
        L = cfg.n_layers
        return {k: jax.ShapeDtypeStruct((L,) + v.shape, v.dtype) for k, v in st.items()}
    if fam == "hybrid":
        n_super = cfg.n_layers // cfg.attn_period
        ms = mamba.mamba_state_shape(batch, cfg)
        out = kv(n_super)
        for k, v in ms.items():
            out["mamba_" + k] = jax.ShapeDtypeStruct(
                (n_super, cfg.attn_period - 1) + v.shape, v.dtype)
        return out
    if fam == "audio":
        out = kv(cfg.n_layers)
        out["cross_k"] = jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, cfg.encoder_frames, K, hd), cfg.dtype)
        out["cross_v"] = jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, cfg.encoder_frames, K, hd), cfg.dtype)
        return out
    raise ValueError(fam)


def zeros_cache(cfg, batch: int, max_len: int):
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                                  init_cache_shape(cfg, batch, max_len))


def decode_step(cfg, params, cache, token: jnp.ndarray, pos: jnp.ndarray):
    """token (B,1) int32; pos scalar int32. Returns (logits (B, vocab_padded),
    new cache). One serve_step — this is what decode_* shapes lower."""
    B = token.shape[0]
    x = layers.embed(token, params["embed"], cfg.dtype)   # (B,1,d)
    fam = cfg.family

    if fam in ("dense", "moe", "vlm", "audio"):
        def body(x, xs):
            blk, ck, cv = xs["blk"], xs["k"], xs["v"]
            xn = layers.apply_norm(x, blk["norm1"], cfg.norm)
            key_self = "self" if fam == "audio" else "attn"
            y, newc = attn.decode_attention_block(xn, blk[key_self], cfg,
                                                  attn.KVCache(ck, cv), pos)
            x = x + y
            if fam == "audio":
                x = x + attn.cross_attention_block(
                    layers.apply_norm(x, blk["norm2"], cfg.norm), blk["cross"],
                    cfg, xs["xk"], xs["xv"])
                xn = layers.apply_norm(x, blk["norm3"], cfg.norm)
                x = x + layers.mlp(xn, blk["mlp"], cfg.mlp, cfg.dtype, decode=True)
            else:
                xn = layers.apply_norm(x, blk["norm2"], cfg.norm)
                if fam == "moe":
                    y, _ = moe.moe_mlp(xn, blk["mlp"], cfg, group_size=B)
                else:
                    y = layers.mlp(xn, blk["mlp"], cfg.mlp, cfg.dtype, decode=True)
                x = x + y
            return x, (newc.k, newc.v)

        if fam == "audio":
            x = x + params["dec_pos"][None, pos].astype(cfg.dtype)
        xs = {"blk": params["blocks"], "k": cache["k"], "v": cache["v"]}
        if fam == "audio":
            xs["xk"], xs["xv"] = cache["cross_k"], cache["cross_v"]
        x, (nk, nv) = jax.lax.scan(body, x, xs)
        cache = dict(cache, k=nk, v=nv)
    elif fam == "ssm":
        def body(x, xs):
            blk = xs["blk"]
            y, (xtm, wkv) = rwkv6.time_mix(
                layers.apply_norm(x, blk["norm1"], cfg.norm), blk["rwkv"], cfg,
                xprev_last=xs["x_tm"], state=xs["wkv"])
            x = x + y
            y, xcm = rwkv6.channel_mix(
                layers.apply_norm(x, blk["norm2"], cfg.norm), blk["rwkv"], cfg,
                xprev_last=xs["x_cm"])
            return x + y, (wkv, xtm, xcm)

        xs = {"blk": params["blocks"], "wkv": cache["wkv"],
              "x_tm": cache["x_tm"], "x_cm": cache["x_cm"]}
        x, (wkv, xtm, xcm) = jax.lax.scan(body, x, xs)
        cache = {"wkv": wkv, "x_tm": xtm, "x_cm": xcm}
    elif fam == "hybrid":
        P = cfg.attn_period

        def body(x, xs):
            blk = xs["blk"]
            mi = 0
            new_conv, new_ssm = [], []
            newk = newv = None
            for s in range(P):
                n1 = jax.tree_util.tree_map(lambda p: p[2 * s], blk["norms"])
                n2 = jax.tree_util.tree_map(lambda p: p[2 * s + 1], blk["norms"])
                xn = layers.apply_norm(x, n1, cfg.norm)
                if s == cfg.attn_offset:
                    y, newc = attn.decode_attention_block(
                        xn, blk["attn"], cfg, attn.KVCache(xs["k"], xs["v"]), pos)
                    newk, newv = newc.k, newc.v
                else:
                    mp = jax.tree_util.tree_map(lambda p: p[mi], blk["mamba"])
                    st = {"conv": xs["mamba_conv"][mi], "ssm": xs["mamba_ssm"][mi]}
                    y, nst = mamba.mamba_block(xn, mp, cfg, state=st)
                    new_conv.append(nst["conv"]); new_ssm.append(nst["ssm"])
                    mi += 1
                x = x + y
                xn = layers.apply_norm(x, n2, cfg.norm)
                if s % cfg.moe_every == cfg.moe_every - 1:
                    ei = s // cfg.moe_every
                    ep = jax.tree_util.tree_map(lambda p: p[ei], blk["moe"])
                    y, _ = moe.moe_mlp(xn, ep, cfg, group_size=x.shape[0])
                else:
                    dp = jax.tree_util.tree_map(lambda p: p[_dense_mlp_index(cfg, s)], blk["mlp"])
                    y = layers.mlp(xn, dp, cfg.mlp, cfg.dtype)
                x = x + y
            return x, (newk, newv, jnp.stack(new_conv), jnp.stack(new_ssm))

        xs = {"blk": params["blocks"], "k": cache["k"], "v": cache["v"],
              "mamba_conv": cache["mamba_conv"], "mamba_ssm": cache["mamba_ssm"]}
        x, (nk, nv, nconv, nssm) = jax.lax.scan(body, x, xs)
        cache = {"k": nk, "v": nv, "mamba_conv": nconv, "mamba_ssm": nssm}
    else:
        raise ValueError(fam)

    x = layers.apply_norm(x, params["final_norm"], cfg.norm)
    logits = _logits(cfg, params, x)[:, 0]
    return logits, cache


def _dense_mlp_index(cfg, s: int) -> int:
    """Index into the dense-mlp stack for sublayer s (non-MoE slots)."""
    return sum(1 for t in range(s) if t % cfg.moe_every != cfg.moe_every - 1)


def prefill(cfg, params, batch: dict):
    """Single-pass prompt processing: forward math + decode-cache
    materialization in the same layer scan.  Returns
    (last-position logits (B, vocab_padded), cache)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    fam = cfg.family
    x = layers.embed(tokens, params["embed"], cfg.dtype)
    x = constrain(x, "batch", "res_seq", "embed")
    positions = jnp.arange(S)

    if fam in ("dense", "moe", "vlm"):
        if fam == "vlm":
            v = layers.linear(batch["vision"].astype(cfg.dtype),
                              params["vision_proj"], cfg.dtype)
            x = jnp.concatenate([v, x[:, cfg.vision_tokens:]], axis=1)

        def body(x, blk):
            xn = layers.apply_norm(x, blk["norm1"], cfg.norm)
            q, k, v = attn._qkv(xn, blk["attn"], cfg, positions)
            o = attn.causal_attention(q, k, v, q_chunk=min(cfg.q_chunk, S))
            o = layers.linear(o.reshape(B, S, -1), blk["attn"]["wo"], cfg.dtype)
            h = x + o
            hn = layers.apply_norm(h, blk["norm2"], cfg.norm)
            if cfg.family == "moe":
                y, _ = moe.moe_mlp(hn, blk["mlp"], cfg)
            else:
                y = layers.mlp(hn, blk["mlp"], cfg.mlp, cfg.dtype)
            h = constrain(h + y, "batch", "res_seq", "embed")
            return h, (k.astype(cfg.dtype), v.astype(cfg.dtype))

        x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
        cache = {"k": ks, "v": vs}
    elif fam == "ssm":
        def body(x, blk):
            y, (xtm, wkv) = rwkv6.time_mix(
                layers.apply_norm(x, blk["norm1"], cfg.norm), blk["rwkv"], cfg)
            h = x + y
            y, xcm = rwkv6.channel_mix(
                layers.apply_norm(h, blk["norm2"], cfg.norm), blk["rwkv"], cfg)
            return h + y, (wkv.astype(jnp.float32), xtm.astype(cfg.dtype),
                           xcm.astype(cfg.dtype))

        x, (wkv, xtm, xcm) = jax.lax.scan(body, x, params["blocks"])
        cache = {"wkv": wkv, "x_tm": xtm, "x_cm": xcm}
    elif fam == "hybrid":
        P = cfg.attn_period

        def body(x, blk):
            mi = 0
            convs, ssms = [], []
            kk = vv = None
            for s in range(P):
                n1 = jax.tree_util.tree_map(lambda p: p[2 * s], blk["norms"])
                n2 = jax.tree_util.tree_map(lambda p: p[2 * s + 1], blk["norms"])
                xn = layers.apply_norm(x, n1, cfg.norm)
                if s == cfg.attn_offset:
                    q, k, v = attn._qkv(xn, blk["attn"], cfg, positions)
                    o = attn.causal_attention(q, k, v, q_chunk=min(cfg.q_chunk, S))
                    y = layers.linear(o.reshape(x.shape[0], S, -1),
                                      blk["attn"]["wo"], cfg.dtype)
                    kk, vv = k.astype(cfg.dtype), v.astype(cfg.dtype)
                else:
                    mp = jax.tree_util.tree_map(lambda p: p[mi], blk["mamba"])
                    y, nst = mamba.mamba_block(xn, mp, cfg)
                    convs.append(nst["conv"]); ssms.append(nst["ssm"])
                    mi += 1
                x = x + y
                xn = layers.apply_norm(x, n2, cfg.norm)
                if s % cfg.moe_every == cfg.moe_every - 1:
                    ep = jax.tree_util.tree_map(lambda p: p[s // cfg.moe_every], blk["moe"])
                    y, _ = moe.moe_mlp(xn, ep, cfg)
                else:
                    dp = jax.tree_util.tree_map(
                        lambda p: p[_dense_mlp_index(cfg, s)], blk["mlp"])
                    y = layers.mlp(xn, dp, cfg.mlp, cfg.dtype)
                x = x + y
            return x, (kk, vv, jnp.stack(convs).astype(cfg.dtype),
                       jnp.stack(ssms))

        x, (ks, vs, convs, ssms) = jax.lax.scan(body, x, params["blocks"])
        cache = {"k": ks, "v": vs, "mamba_conv": convs, "mamba_ssm": ssms}
    elif fam == "audio":
        x = x + params["dec_pos"][None, :S].astype(cfg.dtype)
        enc_out = _encode_audio(cfg, params, batch["frames"])

        def body(x, blk):
            ek, ev = attn.encoder_kv(enc_out, blk["cross"], cfg)
            xn = layers.apply_norm(x, blk["norm1"], cfg.norm)
            q, k, v = attn._qkv(xn, blk["self"], cfg, positions)
            o = attn.causal_attention(q, k, v, q_chunk=min(cfg.q_chunk, S))
            h = x + layers.linear(o.reshape(B, S, -1), blk["self"]["wo"], cfg.dtype)
            h = h + attn.cross_attention_block(
                layers.apply_norm(h, blk["norm2"], cfg.norm), blk["cross"], cfg, ek, ev)
            h = h + layers.mlp(layers.apply_norm(h, blk["norm3"], cfg.norm),
                               blk["mlp"], cfg.mlp, cfg.dtype)
            return h, (k.astype(cfg.dtype), v.astype(cfg.dtype), ek.astype(cfg.dtype),
                       ev.astype(cfg.dtype))

        x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["blocks"])
        cache = {"k": ks, "v": vs, "cross_k": xks, "cross_v": xvs}
    else:
        raise ValueError(fam)

    x = layers.apply_norm(x, params["final_norm"], cfg.norm)
    logits = _logits(cfg, params, x[:, -1:])
    return logits[:, -1], cache
