"""Model facade: config -> callables + abstract input specs for every shape."""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import transformer


class Model(NamedTuple):
    cfg: ArchConfig
    init: Any           # key -> (params, axes)
    forward: Any        # (params, batch) -> (logits, aux)
    loss: Any           # (params, batch) -> (loss, metrics)
    prefill: Any        # (params, batch) -> (last_logits, cache)
    decode_step: Any    # (params, cache, token, pos) -> (logits, cache)


def build(cfg: ArchConfig) -> Model:
    return Model(
        cfg=cfg,
        init=functools.partial(transformer.init_params, cfg),
        forward=functools.partial(transformer.forward, cfg),
        loss=functools.partial(transformer.loss_fn, cfg),
        prefill=functools.partial(transformer.prefill, cfg),
        decode_step=functools.partial(transformer.decode_step, cfg),
    )


def abstract_params(cfg: ArchConfig):
    """(params, axes) with ShapeDtypeStruct leaves — no allocation."""
    params = jax.eval_shape(lambda: transformer.init_params(cfg, jax.random.key(0))[0])
    _, axes = jax.eval_shape(lambda: transformer.init_params(cfg, jax.random.key(0)))
    # axes is a static pytree of tuples; recompute it concretely (cheap):
    return params, param_axes(cfg)


def param_axes(cfg: ArchConfig):
    """Logical-axes pytree without allocating parameters."""
    closed = jax.eval_shape(functools.partial(_init_with_axes, cfg))
    return closed[1]


def _init_with_axes(cfg):
    return transformer.init_params(cfg, jax.random.key(0))


def input_specs(cfg: ArchConfig, shape: ShapeSpec,
                batch_override: int | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a given shape cell
    (the dry-run's no-allocation inputs). Modality frontends are stubs per
    the assignment: frames/vision arrive as precomputed embeddings."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                 "labels": jax.ShapeDtypeStruct((B, S), i32)}
    elif shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    elif shape.kind == "decode":
        return {
            "token": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32),
            "cache": transformer.init_cache_shape(cfg, B, S),
        }
    else:
        raise ValueError(shape.kind)
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct((B, cfg.encoder_frames, cfg.d_model),
                                               cfg.dtype)
    if cfg.family == "vlm":
        specs["vision"] = jax.ShapeDtypeStruct((B, cfg.vision_tokens, cfg.vit_dim),
                                               cfg.dtype)
    return specs


def synth_batch(cfg: ArchConfig, shape: ShapeSpec, seed: int = 0,
                batch_override: int | None = None) -> dict:
    """Concrete deterministic synthetic batch matching input_specs."""
    specs = input_specs(cfg, shape, batch_override)
    key = jax.random.key(seed)

    def gen(path, s):
        k = jax.random.fold_in(key, hash(path) % (2 ** 31))
        if jnp.issubdtype(s.dtype, jnp.integer):
            hi = cfg.vocab if "token" in path or "label" in path else 2 ** 30
            return jax.random.randint(k, s.shape, 0, hi, s.dtype)
        return jax.random.normal(k, s.shape, jnp.float32).astype(s.dtype) * 0.1

    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    tdef = jax.tree_util.tree_structure(specs)
    leaves = [gen(jax.tree_util.keystr(p), s) for p, s in flat]
    out = jax.tree_util.tree_unflatten(tdef, leaves)
    if shape.kind == "decode":
        out["pos"] = jnp.asarray(shape.seq_len // 2, jnp.int32)
    return out
