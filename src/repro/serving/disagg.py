"""Disaggregated trunk/head serving: separate engine pools joined by a
feature-map cache.

smallNet's deployment shape is a heavy conv trunk feeding a light dense
head — the stage split the paper hand-codes in fabric and that PR 5
exposed in software (`smallnet.conv_trunk` / `dense_head`, the FcnSweep
quad role maps).  The monolithic sweep fuses both halves into one device
program per frame, which is optimal for a single stream of distinct
frames — but production window-query traffic is not that: many concurrent
queries land on the SAME frame (overlapping crops, re-scores under new
thresholds, fan-out to several consumers), and under the monolithic sweep
every one of them re-runs the ~99.9%-of-FLOPs trunk to reproduce feature
words the fleet just computed.

This module serves the two halves from separate pools — the prefill/decode
disaggregation pattern from the LLM serving world, applied to vision:

    frames ──> TRUNK POOL ──> FeatureMapCache ──> HEAD POOL ──> scores
               (N heavy          (bounded LRU+TTL,   (M cheap
                replicas,         single-flight)      replicas)
                megakernel-
                capable)

  * Each trunk replica is a `StageEngine` running the jitted trunk half of
    the sweep (`fcn_sweep.make_trunk_fn`: one launch per frame on the
    fixed substrates via the frame_trunk megakernel) — the level-2 role-map
    quad (I, B, R, C) in the backend's native word domain.
  * The `FeatureMapCache` holds recent quads keyed on (frame digest,
    backend, fixed-point config, megakernel route, interpret mode) — every
    axis that changes the words changes the key, so a cached quad can NEVER
    be served to a query it isn't bit-exact for.  LRU + optional TTL keep
    memory bounded; hits/misses/evictions are registry counters.
    Single-flight dedup: concurrent queries on one uncached frame elect ONE
    leader to run the trunk; followers block on its completion and are
    counted as `coalesced` — a thundering herd does exactly one trunk pass.
  * Each head replica is a `StageEngine` running the jitted head half
    (`fcn_sweep.make_head_fn`): quad -> (n_windows, 10) scores through the
    SAME traced gather + dense head as the monolithic `_sweep_fn`, so
    cached-path scores are int32 word-exact vs the one-call sweep on the
    fixed substrates (`benchmarks/stream_table --disagg` gates this).

`DisaggServer` fronts the pools with the fleet serving contract the rest
of the stack expects: bounded intake, per-request deadlines, per-reason
shed accounting, trunk failover (a faulted trunk replica's requests retry
on a healthy sibling), and the no-silent-loss ledger

    submitted == served + shed + pending          (stats()["accounted"])

Both call styles are supported: synchronous `score_frame()` (what
`StreamingPipeline` drives per frame) and open-loop `submit()` + `wait()`
+ `pop_results()` (what the goodput harness replays arrival schedules
against) — trunk and head replica counts scale independently under either.

When to prefer this over the monolithic `FcnSweep`: repeated or
overlapping queries per frame (cache hits skip the trunk entirely),
asymmetric stage costs (scale trunk replicas without paying for idle
heads), or isolation (a faulted trunk replica fails over; the monolithic
sweep has no seam to retry across).  For a single stream of all-distinct
frames the monolithic sweep's fused program wins — the cache can only add
a dictionary lookup it never hits.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import threading
import time
from typing import Any, Callable, Iterable

import jax.numpy as jnp
import numpy as np

from repro.core import backends as B
from repro.core import runtime
from repro.obs import metrics as M
from repro.obs import trace as T
from repro.streaming import fcn_sweep as fs
from repro.streaming.sources import Frame


# ---------------------------------------------------------------------------
# Cache keying
# ---------------------------------------------------------------------------

def frame_digest(frame: np.ndarray) -> str:
    """Content digest of one frame batch: blake2b-128 over shape + dtype +
    raw bytes.  Two frames share a digest iff they are the same array —
    the cache's correctness rests on this, not on object identity, so
    replayed clips and duplicated streams deduplicate across sources."""
    px = np.ascontiguousarray(frame)
    h = hashlib.blake2b(digest_size=16)
    h.update(str(px.shape).encode())
    h.update(str(px.dtype).encode())
    h.update(px.tobytes())
    return h.hexdigest()


def _cfg_token(be: B.Backend) -> str:
    """The fixed-point config as a key axis: any word-domain knob
    (total/frac bits, saturate, rounding) changes the trunk's output words
    and therefore the cache key.  Float backends have no cfg — their token
    is the empty string (backend name still separates them)."""
    cfg = getattr(be, "cfg", None)
    if cfg is None:
        return ""
    return (f"q{cfg.total_bits}.{cfg.frac_bits}"
            f".{'sat' if cfg.saturate else 'wrap'}"
            f".{'rn' if cfg.round_nearest else 'trunc'}")


@dataclasses.dataclass(frozen=True)
class FeatureMapKey:
    """Everything that determines the trunk's output words for one frame.

    `digest` pins the pixels; `backend`/`cfg` pin the word domain;
    `megakernel` pins the trunk route (None/True/False produce identical
    words on the fixed substrates, but the key keeps them separate so a
    route-comparison harness never reads the other route's words as its
    own); `interpret` pins the process-wide interpret switch (compiled and
    interpreted programs are bit-identical for the integer substrates, but
    the switch also invalidates jit caches — keying on it makes cache
    entries exactly as durable as the programs that made them)."""
    digest: str
    backend: str
    cfg: str
    megakernel: bool | None
    interpret: bool


def feature_key(frame: np.ndarray, be: B.Backend,
                megakernel: bool | None) -> FeatureMapKey:
    return FeatureMapKey(
        digest=frame_digest(frame), backend=be.name, cfg=_cfg_token(be),
        megakernel=megakernel, interpret=bool(runtime.interpret_default()))


# ---------------------------------------------------------------------------
# Feature-map cache: bounded LRU + TTL, single-flight, registry-instrumented
# ---------------------------------------------------------------------------

class FeatureMapCache:
    """Bounded LRU (+ optional TTL) cache of trunk feature-map quads with
    single-flight dedup.

    `get_or_compute(key, compute)` is the whole API: a hit returns the
    cached quad; a miss elects the FIRST caller as leader (it runs
    `compute()` outside the cache lock), and every concurrent caller for
    the same key blocks on the leader's completion instead of re-running
    the trunk (counted as `coalesced`).  A failed leader wakes its
    followers to re-elect — a crash never wedges a key.

    Eviction: LRU order on access, capacity-driven (`reason="capacity"`)
    plus lazy TTL expiry at lookup (`reason="ttl"`).  Memory is bounded by
    construction: at most `capacity` quads resident, tracked in bytes by
    the `disagg_cache_bytes` gauge (its high-water mark is the soak test's
    bounded-memory assertion).

    Thread model: one lock guards the entry map and the in-flight table;
    `compute()` runs outside it, so a slow trunk pass never blocks hits on
    other keys.  Instruments live in the process-wide registry under this
    cache's unique instance label.
    """

    def __init__(self, capacity: int = 64, ttl_s: float | None = None,
                 registry: M.Registry | None = None):
        if capacity < 1:
            raise ValueError(f"FeatureMapCache capacity must be >= 1, "
                             f"got {capacity}")
        self.capacity = int(capacity)
        self.ttl_s = None if ttl_s is None else float(ttl_s)
        self._lock = threading.Lock()
        # key -> (value, t_insert, nbytes); OrderedDict is the LRU order
        self._entries: collections.OrderedDict[
            FeatureMapKey, tuple[Any, float, int]] = collections.OrderedDict()
        self._inflight: dict[FeatureMapKey, threading.Event] = {}
        reg = registry if registry is not None else M.REGISTRY
        self._id = M.instance_label("fmcache")
        labels = {"cache": self._id}
        self._m_hits = reg.counter("disagg_cache_hits", **labels)
        self._m_misses = reg.counter("disagg_cache_misses", **labels)
        self._m_coalesced = reg.counter("disagg_cache_coalesced", **labels)
        self._m_evicted: dict[str, M.Counter] = {
            reason: reg.counter("disagg_cache_evictions", reason=reason,
                                **labels)
            for reason in ("capacity", "ttl")}
        self._m_entries = reg.gauge("disagg_cache_entries", **labels)
        self._m_bytes = reg.gauge("disagg_cache_bytes", **labels)

    @staticmethod
    def _nbytes(value: Any) -> int:
        def one(v) -> int:
            nb = getattr(v, "nbytes", None)   # numpy AND jax expose nbytes
            return int(nb) if nb is not None else int(np.asarray(v).nbytes)
        if isinstance(value, (tuple, list)):
            return sum(one(v) for v in value)
        return one(value)

    def _expired_locked(self, t_insert: float, now: float) -> bool:
        return self.ttl_s is not None and now - t_insert > self.ttl_s

    def _evict_locked(self, key: FeatureMapKey, reason: str) -> None:
        self._entries.pop(key, None)
        self._m_evicted[reason].inc()
        self._refresh_gauges_locked()

    def _refresh_gauges_locked(self) -> None:
        self._m_entries.set(len(self._entries))
        self._m_bytes.set(sum(nb for _, _, nb in self._entries.values()))

    def _lookup_locked(self, key: FeatureMapKey, now: float):
        """(value,) on a live hit, None on miss (expired entries are
        evicted in passing — lazy TTL)."""
        hit = self._entries.get(key)
        if hit is None:
            return None
        value, t_insert, _ = hit
        if self._expired_locked(t_insert, now):
            self._evict_locked(key, "ttl")
            return None
        self._entries.move_to_end(key)
        return (value,)

    def get_or_compute(self, key: FeatureMapKey,
                       compute: Callable[[], Any], *,
                       timeout: float | None = None) -> Any:
        """The single-flight read-through path (see class docstring).
        `timeout` bounds a FOLLOWER's wait on the leader (a deadline-bearing
        query must not outwait its budget on someone else's trunk pass);
        expiry raises TimeoutError.  Leader failures propagate to the
        leader's caller; followers re-elect."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        counted = False   # each call counts exactly one of hit/miss/coalesced
        while True:
            with self._lock:
                now = time.perf_counter()
                found = self._lookup_locked(key, now)
                if found is not None:
                    if not counted:
                        self._m_hits.inc()
                    return found[0]
                ev = self._inflight.get(key)
                if ev is None:
                    ev = threading.Event()
                    self._inflight[key] = ev
                    if not counted:
                        self._m_misses.inc()
                    leader = True
                else:
                    if not counted:
                        self._m_coalesced.inc()
                        counted = True
                    leader = False
            if leader:
                try:
                    value = compute()
                except BaseException:
                    with self._lock:
                        # wake followers with nothing cached: they re-elect
                        # a new leader (or time out) instead of hanging
                        self._inflight.pop(key, None)
                    ev.set()
                    raise
                self.put(key, value)
                with self._lock:
                    self._inflight.pop(key, None)
                ev.set()
                return value
            remaining = (None if deadline is None
                         else deadline - time.perf_counter())
            if remaining is not None and remaining <= 0:
                raise TimeoutError(
                    f"feature-map wait for {key.digest[:8]} exceeded its "
                    f"deadline while another query computed the trunk")
            if not ev.wait(remaining):
                raise TimeoutError(
                    f"feature-map wait for {key.digest[:8]} exceeded its "
                    f"deadline while another query computed the trunk")
            # leader finished (or failed): loop re-reads the entry map

    def put(self, key: FeatureMapKey, value: Any) -> None:
        """Insert (or refresh) an entry, evicting LRU past capacity."""
        nb = self._nbytes(value)
        with self._lock:
            self._entries[key] = (value, time.perf_counter(), nb)
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                oldest = next(iter(self._entries))
                self._evict_locked(oldest, "capacity")
            self._refresh_gauges_locked()

    def get(self, key: FeatureMapKey) -> Any | None:
        """Plain lookup (hit/miss counted); None on miss."""
        with self._lock:
            found = self._lookup_locked(key, time.perf_counter())
            if found is not None:
                self._m_hits.inc()
                return found[0]
            self._m_misses.inc()
            return None

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    @property
    def hit_rate(self) -> float:
        h, m = self._m_hits.value, self._m_misses.value
        return h / (h + m) if h + m else 0.0

    def stats(self) -> dict:
        with self._lock:
            entries = len(self._entries)
            resident = sum(nb for _, _, nb in self._entries.values())
        return {
            "capacity": self.capacity,
            "ttl_s": self.ttl_s,
            "entries": entries,
            "resident_bytes": resident,
            "resident_bytes_hwm": int(self._m_bytes.hwm),
            "hits": self._m_hits.value,
            "misses": self._m_misses.value,
            "coalesced": self._m_coalesced.value,
            "hit_rate": self.hit_rate,
            "evictions": {r: c.value for r, c in self._m_evicted.items()},
        }


# ---------------------------------------------------------------------------
# Stage engine: the continuous serving loop for one disagg stage
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StageRequest:
    uid: int
    payload: Any
    t_submit: float = 0.0
    deadline: float | None = None
    parent_span: Any = None


@dataclasses.dataclass
class StageResult:
    uid: int
    value: Any
    t_submit: float
    t_done: float
    deadline: float | None = None

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    @property
    def within_deadline(self) -> bool:
        return self.deadline is None or self.t_done <= self.deadline


class StageEngine:
    """One disagg-stage replica: a continuously-served queue over an
    arbitrary compute callable (trunk: frame batch -> role-map quad; head:
    quad -> window scores).

    The serving discipline is `VisionEngine`'s, specialized to one request
    per step (the trunk megakernel is a batch-1 program; a head request
    already carries its whole window lattice): bounded intake
    (`max_queue`, shed reason "queue_depth"), deadline shedding at
    batch-forming time ("deadline"), fault containment (a raising compute
    sheds its request as "fault" and kills the serving thread — the
    `DisaggServer` fails the work over to a sibling replica), a
    deterministic `min_step_s` service floor for overload harnesses, and
    registry-backed accounting with the engine ledger invariant

        submitted == served + shed + pending

    Throughput is measured over BUSY time; `service_rate_qps()` is the
    observed rate (None before history) and `seed_rate_qps()` the
    deterministic floor-derived rate — the dispatch signals the disagg
    router shares with `serving/router.py`.
    """

    def __init__(self, compute: Callable[[Any], Any], *, name: str,
                 min_step_s: float = 0.0, max_queue: int | None = None,
                 default_deadline_ms: float | None = None):
        self._compute = compute
        self.name = name
        self.min_step_s = float(min_step_s)
        self.max_queue = None if max_queue is None else int(max_queue)
        self.default_deadline_ms = (None if default_deadline_ms is None
                                    else float(default_deadline_ms))
        self._cond = threading.Condition()
        self._queue: collections.deque[StageRequest] = collections.deque()
        self._results: dict[int, StageResult] = {}
        self._shed: dict[int, str] = {}
        self._next_uid = 0
        self._in_flight = 0
        self._thread: threading.Thread | None = None
        self._stop_flag = False
        self._fault: BaseException | None = None
        self._id = M.instance_label(f"stage-{name}")
        reg = M.REGISTRY
        labels = {"stage": self._id}
        self._m_submitted = reg.counter("stage_submitted", **labels)
        self._m_served = reg.counter("stage_served", **labels)
        self._m_shed: dict[str, M.Counter] = {}
        self._m_busy = reg.counter("stage_busy_seconds", **labels)
        self._m_queue = reg.gauge("stage_queue_depth", **labels)
        self._lat_hist = reg.histogram("stage_latency_seconds", **labels)

    # -- request side -------------------------------------------------------

    def submit(self, payload: Any, *, deadline_ms: float | None = None,
               t_submit: float | None = None, parent_span: Any = None) -> int:
        with self._cond:
            uid = self._next_uid
            self._next_uid += 1
            self._m_submitted.inc()
            now = time.perf_counter() if t_submit is None else float(t_submit)
            dl_ms = (deadline_ms if deadline_ms is not None
                     else self.default_deadline_ms)
            if self._fault is not None:
                self._shed_locked(uid, "fault", now, now,
                                  parent_span=parent_span)
            elif (self.max_queue is not None
                    and len(self._queue) >= self.max_queue):
                self._shed_locked(uid, "queue_depth", now, now,
                                  parent_span=parent_span)
            else:
                deadline = now + dl_ms / 1e3 if dl_ms is not None else None
                self._queue.append(StageRequest(
                    uid=uid, payload=payload, t_submit=now,
                    deadline=deadline, parent_span=parent_span))
                self._m_queue.set(len(self._queue))
                self._cond.notify_all()
            return uid

    def _shed_locked(self, uid: int, reason: str, t_submit: float,
                     t_end: float, *, parent_span: Any = None) -> None:
        self._shed[uid] = reason
        c = self._m_shed.get(reason)
        if c is None:
            c = M.REGISTRY.counter("stage_shed", reason=reason,
                                   stage=self._id)
            self._m_shed[reason] = c
        c.inc()
        tr = T.get()
        if tr is not None:
            tid = (parent_span.trace_id if parent_span is not None
                   else f"stage-{self._id}-{uid}")
            tr.emit("stage_request", tid, t_submit, t_end,
                    f"shed:{reason}", parent=parent_span, uid=uid,
                    stage=self._id)
        self._cond.notify_all()

    # -- serving side -------------------------------------------------------

    def step(self) -> int:
        """Serve ONE request (shedding expired ones in passing); returns
        the number served (0 or 1)."""
        with self._cond:
            req = None
            now = time.perf_counter()
            while self._queue:
                r = self._queue.popleft()
                if r.deadline is not None and now > r.deadline:
                    self._shed_locked(r.uid, "deadline", r.t_submit, now,
                                      parent_span=r.parent_span)
                else:
                    req = r
                    break
            self._m_queue.set(len(self._queue))
            if req is None:
                return 0
            self._in_flight = 1
        t0 = time.perf_counter()
        try:
            with T.device_step_annotation(f"stage_step/{self.name}"):
                value = self._compute(req.payload)
        except Exception as e:
            with self._cond:
                self._in_flight = 0
                # a faulted compute kills this replica in BOTH serving
                # modes: the threaded loop exits, and inline drivers see
                # the door close — dispatch must fail over, not retry a
                # replica whose program is broken
                self._fault = e
                self._shed_locked(req.uid, "fault", req.t_submit,
                                  time.perf_counter(),
                                  parent_span=req.parent_span)
            raise
        t_done = time.perf_counter()
        if self.min_step_s > 0.0 and t_done - t0 < self.min_step_s:
            time.sleep(self.min_step_s - (t_done - t0))
            t_done = time.perf_counter()     # the floor IS the service time
        with self._cond:
            res = StageResult(uid=req.uid, value=value,
                              t_submit=req.t_submit, t_done=t_done,
                              deadline=req.deadline)
            self._results[req.uid] = res
            self._lat_hist.observe(res.latency_s)
            self._m_served.inc()
            self._m_busy.inc(t_done - t0)
            self._in_flight = 0
            self._cond.notify_all()
        tr = T.get()
        if tr is not None:
            tid = (req.parent_span.trace_id if req.parent_span is not None
                   else f"stage-{self._id}-{req.uid}")
            tr.emit("stage_request", tid, req.t_submit, t_done, "served",
                    parent=req.parent_span, uid=req.uid, stage=self._id)
        return 1

    def start(self) -> "StageEngine":
        with self._cond:
            if self._thread is not None:
                return self
            self._stop_flag = False
            self._thread = threading.Thread(
                target=self._serve_loop, daemon=True,
                name=f"stage-engine-{self.name}")
            self._thread.start()
        return self

    def _serve_loop(self) -> None:
        while True:
            with self._cond:
                while not self._queue and not self._stop_flag:
                    self._cond.wait(timeout=0.05)
                if self._stop_flag and not self._queue:
                    return
            try:
                self.step()
            except Exception as e:   # noqa: BLE001 — any fault kills serving
                with self._cond:
                    self._fault = e
                    now = time.perf_counter()
                    while self._queue:
                        r = self._queue.popleft()
                        self._shed_locked(r.uid, "fault", r.t_submit, now,
                                          parent_span=r.parent_span)
                    self._cond.notify_all()
                return

    def stop(self, drain: bool = True) -> None:
        with self._cond:
            thread = self._thread
            self._stop_flag = True
            if not drain:
                now = time.perf_counter()
                while self._queue:
                    r = self._queue.popleft()
                    self._shed_locked(r.uid, "stopped", r.t_submit, now,
                                      parent_span=r.parent_span)
            self._cond.notify_all()
        if thread is not None:
            thread.join(timeout=60.0)
            with self._cond:
                self._thread = None
                self._stop_flag = False

    # -- client / signals ---------------------------------------------------

    @property
    def fault(self) -> BaseException | None:
        return self._fault

    def load(self) -> int:
        with self._cond:
            return len(self._queue) + self._in_flight

    def service_rate_qps(self) -> float | None:
        with self._cond:
            if self._m_busy.value <= 0 or self._m_served.value == 0:
                return None
            return self._m_served.value / self._m_busy.value

    def seed_rate_qps(self) -> float | None:
        """Deterministic service-rate floor before any history exists:
        one request per `min_step_s` step.  None when no floor is set."""
        return 1.0 / self.min_step_s if self.min_step_s > 0 else None

    def wait(self, uids: Iterable[int],
             timeout: float | None = None) -> None:
        uids = list(uids)

        def unresolved_locked():
            return [u for u in uids
                    if u not in self._results and u not in self._shed]

        t_end = None if timeout is None else time.perf_counter() + timeout
        with self._cond:
            while unresolved_locked():
                if self._thread is None and self._fault is None:
                    break   # drive inline below
                if self._fault is not None and not self._queue \
                        and not self._in_flight:
                    # serving died and shed everything it knew about; what
                    # is still unresolved never will be
                    return
                remaining = (None if t_end is None
                             else t_end - time.perf_counter())
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"{len(unresolved_locked())} of {len(uids)} stage "
                        f"requests unresolved after {timeout}s")
                self._cond.wait(remaining if remaining is not None else 0.1)
            else:
                return
        while True:   # no serving thread: drive synchronously
            with self._cond:
                if not unresolved_locked():
                    return
            if self.step() == 0:
                with self._cond:
                    missing = unresolved_locked()
                    if missing and not self._queue and not self._in_flight:
                        raise KeyError(
                            f"stage uids {missing[:4]} are not queued, "
                            "served, or shed")

    def pop_results(self, uids: Iterable[int] | None = None
                    ) -> dict[int, StageResult]:
        with self._cond:
            if uids is None:
                out, self._results = self._results, {}
                return out
            return {u: self._results.pop(u) for u in list(uids)
                    if u in self._results}

    def pop_shed(self, uids: Iterable[int] | None = None) -> dict[int, str]:
        with self._cond:
            if uids is None:
                out, self._shed = self._shed, {}
                return out
            return {u: self._shed.pop(u) for u in list(uids)
                    if u in self._shed}

    def stats(self) -> dict:
        with self._cond:
            submitted = self._m_submitted.value
            served = self._m_served.value
            shed_by = {r: c.value for r, c in sorted(self._m_shed.items())}
            shed_total = sum(shed_by.values())
            pending = len(self._queue) + self._in_flight
            busy = self._m_busy.value
            out = {
                "stage": self.name,
                "submitted": submitted,
                "n": served,
                "shed": shed_total,
                "shed_by_reason": shed_by,
                "pending": pending,
                "accounted": submitted == served + shed_total + pending,
                "queue_hwm": int(self._m_queue.hwm),
                "busy_s": busy,
            }
            if served:
                out.update(M.summarize_latency(self._lat_hist.samples(),
                                               busy))
                out["throughput_qps"] = served / busy if busy > 0 else 0.0
            return out


# ---------------------------------------------------------------------------
# The disaggregated server
# ---------------------------------------------------------------------------

class DisaggShedError(RuntimeError):
    """A synchronous `score_frame` query was shed; `.reason` carries the
    ledger reason ("queue_depth" / "deadline" / "fault" / "stopped")."""

    def __init__(self, reason: str, detail: str = ""):
        super().__init__(f"disagg query shed ({reason})"
                         + (f": {detail}" if detail else ""))
        self.reason = reason


@dataclasses.dataclass
class DisaggResult:
    uid: int
    scores: np.ndarray                # (n_windows, 10) backend-native
    t_submit: float
    t_done: float
    cache_hit: bool
    deadline: float | None = None

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    @property
    def within_deadline(self) -> bool:
        return self.deadline is None or self.t_done <= self.deadline


class DisaggServer:
    """Disaggregated trunk/head window-scoring fleet (module docstring has
    the topology).  Pipeline-compatible: exposes `.params` / `.backend` /
    `.score_frame(frames)` so `StreamingPipeline` can drive it exactly
    where it drives the monolithic sweep, and the open-loop
    `submit`/`wait`/`pop_results`/`stats` contract so the goodput harness
    can replay arrival schedules against it.

    Dispatch is least-loaded over each pool with trunk failover: a query
    whose trunk request dies on a faulted replica retries on the next
    healthy one (the cache's single-flight leader re-election makes this
    safe under concurrency); only when EVERY replica of a pool has faulted
    is the query shed with reason "fault".
    """

    def __init__(self, params: Any, *,
                 backend: str | B.Backend = "fixed",
                 frame_shape: tuple[int, int] = (112, 112),
                 patch: int = 28, stride: int = 8,
                 megakernel: bool | None = None,
                 n_trunk: int = 2, n_head: int = 1,
                 cache_capacity: int = 64, cache_ttl_s: float | None = None,
                 cache: FeatureMapCache | None = None,
                 trunk_floor_s: float = 0.0, head_floor_s: float = 0.0,
                 max_queue: int | None = None,
                 default_deadline_ms: float | None = None,
                 n_workers: int | None = None,
                 warmup: bool = True):
        if n_trunk < 1 or n_head < 1:
            raise ValueError(f"DisaggServer needs at least one replica per "
                             f"pool, got n_trunk={n_trunk} n_head={n_head}")
        self.backend = B.get_backend(backend)
        self.params = params
        self.frame_shape = tuple(frame_shape)
        self.patch = int(patch)
        self.stride = int(stride)
        self.megakernel = megakernel
        self.default_deadline_ms = (None if default_deadline_ms is None
                                    else float(default_deadline_ms))
        self.max_queue = None if max_queue is None else int(max_queue)
        # the window lattice is the sweep's own (geometry contract included)
        sweep = fs.FcnSweep(patch=self.patch, stride=self.stride,
                            megakernel=megakernel)
        self.positions = tuple(sweep.positions(self.frame_shape))
        self._trunk_fn = fs.make_trunk_fn(self.backend.name, megakernel)
        self._head_fn = fs.make_head_fn(self.backend.name, self.patch,
                                        self.positions)
        self.cache = (cache if cache is not None
                      else FeatureMapCache(capacity=cache_capacity,
                                           ttl_s=cache_ttl_s))

        def run_trunk(frames: np.ndarray):
            # cache entries stay backend-native DEVICE arrays: a cache hit
            # must skip the trunk's FLOPs without buying a host->device
            # round-trip per head call (re-uploading the quad costs more
            # than the head itself at smallNet scale).  The pinned device
            # memory is exactly what capacity/TTL bound.
            return tuple(self._trunk_fn(self.params, jnp.asarray(frames)))

        def run_head(quad) -> np.ndarray:
            return np.asarray(self._head_fn(self.params, tuple(quad)))

        self._run_trunk = run_trunk
        self._run_head = run_head
        self.trunks = [StageEngine(run_trunk, name=f"trunk{i}",
                                   min_step_s=trunk_floor_s,
                                   max_queue=max_queue)
                       for i in range(n_trunk)]
        self.heads = [StageEngine(run_head, name=f"head{i}",
                                  min_step_s=head_floor_s,
                                  max_queue=max_queue)
                      for i in range(n_head)]
        # fleet-level intake + worker pool for the open-loop interface
        self._cond = threading.Condition()
        self._intake: collections.deque = collections.deque()
        self._results: dict[int, DisaggResult] = {}
        self._shed: dict[int, str] = {}
        self._next_uid = 0
        self._n_busy_workers = 0
        self._workers: list[threading.Thread] = []
        self._stop_flag = False
        self.n_workers = int(n_workers) if n_workers else max(2, n_trunk)
        self._id = M.instance_label(f"disagg-{self.backend.name}")
        reg = M.REGISTRY
        labels = {"server": self._id, "backend": self.backend.name}
        self._m_submitted = reg.counter("disagg_submitted", **labels)
        self._m_served = reg.counter("disagg_served", **labels)
        self._m_shed: dict[str, M.Counter] = {}
        self._lat_hist = reg.histogram("disagg_latency_seconds", **labels)
        self._m_queue = reg.gauge("disagg_intake_depth", **labels)
        self._t_first_submit: float | None = None
        self._t_last_done: float | None = None
        self._deadline_total = 0
        self._deadline_ok = 0
        if warmup:
            # compile both halves outside the serving clock (the trunk
            # program doubles as the frame-geometry check)
            zeros = np.zeros((1,) + self.frame_shape + (1,), np.float32)
            self._run_head(self._run_trunk(zeros))

    # -- dispatch core ------------------------------------------------------

    @staticmethod
    def _healthy(pool: list[StageEngine]) -> list[StageEngine]:
        return [e for e in pool if e.fault is None]

    def _dispatch(self, pool: list[StageEngine], payload: Any,
                  deadline: float | None, parent_span: Any) -> Any:
        """Least-loaded dispatch with failover: submit to the least-loaded
        healthy replica, wait; a "fault" shed retries on the next healthy
        sibling.  Returns the stage result value; raises DisaggShedError
        when the request cannot be served."""
        tried: set[int] = set()
        while True:
            healthy = [e for e in self._healthy(pool)
                       if id(e) not in tried]
            if not healthy:
                raise DisaggShedError(
                    "fault", f"all {len(pool)} replicas faulted or tried")
            eng = min(healthy, key=lambda e: e.load())
            remaining_ms = None
            if deadline is not None:
                remaining_ms = (deadline - time.perf_counter()) * 1e3
                if remaining_ms <= 0:
                    raise DisaggShedError("deadline")
            uid = eng.submit(payload, deadline_ms=remaining_ms,
                             parent_span=parent_span)
            try:
                eng.wait([uid])
            except Exception:   # noqa: BLE001 — shed table is the truth
                # inline driving (no serving thread) re-raises the stage
                # compute's own exception after shedding the request as
                # "fault"; the threaded loop contains it instead.  Either
                # way the request's fate is in the shed table below.
                pass
            res = eng.pop_results([uid])
            if uid in res:
                return res[uid].value
            reason = eng.pop_shed([uid]).get(uid, "fault")
            if reason == "fault":
                tried.add(id(eng))      # failover to a sibling
                continue
            raise DisaggShedError(reason)

    def _trunk_quad(self, frames: np.ndarray, deadline: float | None,
                    parent_span: Any) -> tuple[Any, bool]:
        """(quad, cache_hit) through the cache's single-flight path."""
        key = feature_key(frames, self.backend, self.megakernel)
        hit = True

        def compute():
            nonlocal hit
            hit = False
            return self._dispatch(self.trunks, frames, deadline,
                                  parent_span)

        timeout = (None if deadline is None
                   else max(0.0, deadline - time.perf_counter()))
        try:
            quad = self.cache.get_or_compute(key, compute, timeout=timeout)
        except TimeoutError as e:
            raise DisaggShedError("deadline", str(e)) from e
        return quad, hit

    def _score(self, frames: np.ndarray, deadline: float | None,
               parent_span: Any) -> tuple[np.ndarray, bool]:
        """The full chain: trunk (through the cache) then head."""
        quad, hit = self._trunk_quad(frames, deadline, parent_span)
        scores = self._dispatch(self.heads, quad, deadline, parent_span)
        return scores, hit

    # -- synchronous interface (what the pipeline drives) -------------------

    def score_frame(self, frames: np.ndarray, *,
                    deadline_ms: float | None = None,
                    parent_span: Any = None) -> np.ndarray:
        """One (1, H, W, 1) float frame batch -> (n_windows, 10)
        backend-native window scores in `positions` order — the monolithic
        `FcnSweep.score` contract, served disaggregated.  Raises
        `DisaggShedError` when the query is shed."""
        frames = np.asarray(frames, np.float32)
        if frames.ndim == 3:
            frames = frames[None]
        if frames.shape[0] != 1:
            raise ValueError(
                f"score_frame takes one frame per call (the trunk is a "
                f"per-frame program), got batch {frames.shape[0]}")
        if frames.shape[1:3] != self.frame_shape:
            raise ValueError(
                f"frame {frames.shape[1:3]} does not match the server's "
                f"compiled geometry {self.frame_shape}")
        with self._cond:
            uid = self._next_uid
            self._next_uid += 1
            self._m_submitted.inc()
        t0 = time.perf_counter()
        with self._cond:
            if self._t_first_submit is None:
                self._t_first_submit = t0
        dl_ms = (deadline_ms if deadline_ms is not None
                 else self.default_deadline_ms)
        deadline = t0 + dl_ms / 1e3 if dl_ms is not None else None
        if dl_ms is not None:
            with self._cond:
                self._deadline_total += 1
        try:
            scores, hit = self._score(frames, deadline, parent_span)
        except DisaggShedError as e:
            self._record_shed(uid, e.reason, t0, parent_span)
            raise
        self._record_served(uid, scores, t0, deadline, hit, parent_span)
        return scores

    # -- open-loop interface (what the goodput harness drives) --------------

    def submit(self, image: np.ndarray, *, deadline_ms: float | None = None,
               t_submit: float | None = None,
               parent_span: Any = None) -> int:
        """Queue one frame for asynchronous disagg scoring; returns its uid
        immediately.  Intake past `max_queue` is shed ("queue_depth") —
        the fleet is its own admission controller, like `VisionEngine`."""
        frames = np.asarray(image, np.float32)
        if frames.ndim == 2:
            frames = frames[..., None]
        if frames.ndim == 3:
            frames = frames[None]
        with self._cond:
            uid = self._next_uid
            self._next_uid += 1
            self._m_submitted.inc()
            now = time.perf_counter() if t_submit is None else float(t_submit)
            if self._t_first_submit is None:
                self._t_first_submit = now
            dl_ms = (deadline_ms if deadline_ms is not None
                     else self.default_deadline_ms)
            if dl_ms is not None:
                self._deadline_total += 1
            deadline = now + dl_ms / 1e3 if dl_ms is not None else None
            if self.max_queue is not None \
                    and len(self._intake) >= self.max_queue:
                self._shed_locked(uid, "queue_depth", now,
                                  time.perf_counter(), parent_span)
            elif self._stop_flag or not self._workers:
                # submits before start() (or after stop) queue up only if
                # workers will exist to drain them; otherwise they shed
                if self._workers:
                    self._shed_locked(uid, "stopped", now,
                                      time.perf_counter(), parent_span)
                else:
                    self._intake.append(
                        (uid, frames, now, deadline, parent_span))
                    self._m_queue.set(len(self._intake))
            else:
                self._intake.append((uid, frames, now, deadline, parent_span))
                self._m_queue.set(len(self._intake))
                self._cond.notify_all()
            return uid

    def _worker_loop(self) -> None:
        while True:
            with self._cond:
                while not self._intake and not self._stop_flag:
                    self._cond.wait(timeout=0.05)
                if self._stop_flag and not self._intake:
                    return
                uid, frames, t_submit, deadline, parent_span = \
                    self._intake.popleft()
                self._m_queue.set(len(self._intake))
                self._n_busy_workers += 1
            try:
                if deadline is not None and time.perf_counter() > deadline:
                    self._record_shed(uid, "deadline", t_submit, parent_span)
                    continue
                try:
                    scores, hit = self._score(frames, deadline, parent_span)
                except DisaggShedError as e:
                    self._record_shed(uid, e.reason, t_submit, parent_span)
                    continue
                self._record_served(uid, scores, t_submit, deadline, hit,
                                    parent_span)
            finally:
                with self._cond:
                    self._n_busy_workers -= 1
                    self._cond.notify_all()

    def _record_served(self, uid: int, scores: np.ndarray, t_submit: float,
                       deadline: float | None, hit: bool,
                       parent_span: Any) -> None:
        t_done = time.perf_counter()
        with self._cond:
            res = DisaggResult(uid=uid, scores=scores, t_submit=t_submit,
                               t_done=t_done, cache_hit=hit,
                               deadline=deadline)
            self._results[uid] = res
            self._m_served.inc()
            self._lat_hist.observe(res.latency_s)
            self._t_last_done = t_done
            if deadline is not None and t_done <= deadline:
                self._deadline_ok += 1
            self._cond.notify_all()
        tr = T.get()
        if tr is not None:
            tid = (parent_span.trace_id if parent_span is not None
                   else f"disagg-{self._id}-{uid}")
            tr.emit("disagg_query", tid, t_submit, t_done, "served",
                    parent=parent_span, uid=uid, server=self._id,
                    cache_hit=hit)

    def _record_shed(self, uid: int, reason: str, t_submit: float,
                     parent_span: Any) -> None:
        t_end = time.perf_counter()
        with self._cond:
            self._shed_locked(uid, reason, t_submit, t_end, parent_span)

    def _shed_locked(self, uid: int, reason: str, t_submit: float,
                     t_end: float, parent_span: Any) -> None:
        self._shed[uid] = reason
        c = self._m_shed.get(reason)
        if c is None:
            c = M.REGISTRY.counter("disagg_shed", reason=reason,
                                   server=self._id,
                                   backend=self.backend.name)
            self._m_shed[reason] = c
        c.inc()
        tr = T.get()
        if tr is not None:
            tid = (parent_span.trace_id if parent_span is not None
                   else f"disagg-{self._id}-{uid}")
            tr.emit("disagg_query", tid, t_submit, t_end,
                    f"shed:{reason}", parent=parent_span, uid=uid,
                    server=self._id)
        self._cond.notify_all()

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "DisaggServer":
        """Start every stage replica and the fleet worker pool."""
        for eng in self.trunks + self.heads:
            eng.start()
        with self._cond:
            if self._workers:
                return self
            self._stop_flag = False
            for i in range(self.n_workers):
                t = threading.Thread(target=self._worker_loop, daemon=True,
                                     name=f"disagg-worker-{i}")
                t.start()
                self._workers.append(t)
        return self

    def stop(self, drain: bool = True) -> None:
        with self._cond:
            workers = list(self._workers)
            self._stop_flag = True
            if not drain:
                now = time.perf_counter()
                while self._intake:
                    uid, _, t_submit, _, span = self._intake.popleft()
                    self._shed_locked(uid, "stopped", t_submit, now, span)
                self._m_queue.set(0)
            self._cond.notify_all()
        for t in workers:
            t.join(timeout=60.0)
        for eng in self.trunks + self.heads:
            eng.stop(drain=drain)
        with self._cond:
            self._workers = []
            self._stop_flag = False

    # -- client loop --------------------------------------------------------

    def wait(self, uids: Iterable[int], timeout: float | None = None) -> None:
        uids = list(uids)
        t_end = None if timeout is None else time.perf_counter() + timeout
        with self._cond:
            while any(u not in self._results and u not in self._shed
                      for u in uids):
                remaining = (None if t_end is None
                             else t_end - time.perf_counter())
                if remaining is not None and remaining <= 0:
                    n = sum(1 for u in uids if u not in self._results
                            and u not in self._shed)
                    raise TimeoutError(
                        f"{n} of {len(uids)} disagg queries unresolved "
                        f"after {timeout}s")
                self._cond.wait(remaining if remaining is not None else 0.1)

    def pop_results(self, uids: Iterable[int] | None = None
                    ) -> dict[int, DisaggResult]:
        with self._cond:
            if uids is None:
                out, self._results = self._results, {}
                return out
            return {u: self._results.pop(u) for u in list(uids)
                    if u in self._results}

    def pop_shed(self, uids: Iterable[int] | None = None) -> dict[int, str]:
        with self._cond:
            if uids is None:
                out, self._shed = self._shed, {}
                return out
            return {u: self._shed.pop(u) for u in list(uids)
                    if u in self._shed}

    # -- reporting ----------------------------------------------------------

    def pending(self) -> int:
        with self._cond:
            return len(self._intake) + self._n_busy_workers

    def load(self) -> int:
        return self.pending()

    def stats(self) -> dict:
        """Fleet ledger + per-stage + cache stats.  The fleet invariant is
        over DISAGG queries (each may fan into several stage requests —
        stage ledgers reconcile per replica underneath)."""
        per_stage = {e.name: e.stats() for e in self.trunks + self.heads}
        with self._cond:
            submitted = self._m_submitted.value
            served = self._m_served.value
            shed_by = {r: c.value for r, c in sorted(self._m_shed.items())}
            shed_total = sum(shed_by.values())
            pending = len(self._intake) + self._n_busy_workers
            wall = ((self._t_last_done or 0.0)
                    - (self._t_first_submit or 0.0)) if served else 0.0
            accounted = submitted == served + shed_total + pending
            out = {
                "backend": self.backend.name,
                "topology": {"trunk": len(self.trunks),
                             "head": len(self.heads),
                             "workers": self.n_workers},
                "submitted": submitted,
                "n": served,
                "shed": shed_total,
                "shed_by_reason": shed_by,
                "pending": pending,
                "accounted": accounted,
                "queue_hwm": int(self._m_queue.hwm),
                "wall_s": wall,
                "cache": self.cache.stats(),
                "per_stage": per_stage,
            }
            if self._deadline_total:
                out["deadline_total"] = self._deadline_total
                out["served_within_deadline"] = self._deadline_ok
                out["goodput"] = self._deadline_ok / self._deadline_total
            if served:
                out.update(M.summarize_latency(self._lat_hist.samples(),
                                               wall))
                out["throughput_qps"] = served / wall if wall > 0 else 0.0
        if not accounted:
            tr = T.get()
            if tr is not None:
                tr.recorder.trip(
                    "ledger_invariant",
                    f"disagg {self._id}: submitted={submitted} != "
                    f"served={served} + shed={shed_total} + "
                    f"pending={pending}")
        return out

    # -- detection-parity helper (benchmarks, tests) ------------------------

    def detect(self, frame: "Frame | np.ndarray", *,
               tiler: fs.FcnSweep | None = None) -> list:
        """Detections from one frame through the disagg path, with the
        SAME aggregation semantics as the monolithic sweep (`Tiler
        .aggregate` over the identical window lattice) — the parity gates
        compare this output against `FcnSweep.detect`.  Pass the exact
        `tiler` being compared against to share its threshold/dedup
        settings; the default matches `FcnSweep`'s defaults."""
        sweep = tiler if tiler is not None else fs.FcnSweep(
            patch=self.patch, stride=self.stride,
            megakernel=self.megakernel)
        px = frame.pixels if isinstance(frame, Frame) else np.asarray(frame)
        if px.ndim == 2:
            px = px[..., None]
        scores = self.score_frame(px[None])
        return sweep.aggregate(scores, list(self.positions))
