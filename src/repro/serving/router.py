"""Replica router: fleet-level serving over N vision engines.

The survey line of FPGA accelerator work (Guo et al.; ZynqNet) scales
throughput by REPLICATING the compute unit and partitioning the data path;
`VisionEngine` already scales one step across a mesh, and this module adds
the second axis: a router that owns several engines ("replicas" — distinct
backends, devices, or mesh slices), dispatches each incoming request to the
least-loaded healthy replica, drains all replicas concurrently, and
aggregates per-replica stats into fleet-level throughput and latency
percentiles.

Dispatch is deferred: `submit()` assigns a request to a replica's pending
lane immediately (so queue depths — the load signal — are visible), but the
images only enter the engine's own queue inside `run()`.  That makes
failover clean: if a replica dies mid-drain (its jitted step raises), the
router collects whatever that engine already completed, re-dispatches the
unserved remainder across the survivors (re-arming drained survivors via
`VisionEngine.reopen`), and only raises if NO replica is left healthy.  One
bad backend never poisons the fleet.

Usage:

    router = ReplicaRouter.from_backends(params, ["pallas", "fixed_pallas"])
    uids = [router.submit(img) for img in images]
    router.run()                       # concurrent drain + failover
    res = router.results()             # uid -> RoutedResult
    print(router.stats())              # fleet + per-replica
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Iterable, Sequence

import numpy as np

from repro.serving.vision_engine import (VisionEngine, VisionResult,
                                         latency_stats)


class FleetExhaustedError(RuntimeError):
    """Every replica failed: there is nobody left to serve the remainder."""


@dataclasses.dataclass
class RoutedResult:
    """One served request as the ROUTER's client sees it: global uid,
    which replica served it, and latency measured from router submit (queue
    wait in the router's pending lane included)."""
    uid: int
    replica: int
    pred: int
    scores: np.ndarray
    t_submit: float                   # router-side submit time
    t_done: float

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit


@dataclasses.dataclass
class _Pending:
    uid: int
    image: np.ndarray
    t_submit: float


class ReplicaRouter:
    """Least-loaded request router over a fleet of `VisionEngine` replicas."""

    POLICIES = ("least_loaded", "round_robin")

    def __init__(self, replicas: Sequence[VisionEngine], *,
                 policy: str = "least_loaded"):
        if not replicas:
            raise ValueError("ReplicaRouter needs at least one replica")
        if policy not in self.POLICIES:
            raise ValueError(f"unknown policy {policy!r}; one of {self.POLICIES}")
        self.replicas = list(replicas)
        self.policy = policy
        self._pending: list[list[_Pending]] = [[] for _ in self.replicas]
        self._errors: dict[int, BaseException] = {}
        self._results: dict[int, RoutedResult] = {}
        self._assignment: dict[int, int] = {}      # uid -> replica index
        self._next_uid = 0
        self._rr_clock = 0
        # reentrant: _pick (under the submit lock) reads queue_depths, which
        # locks again for its own public callers
        self._lock = threading.RLock()

    @classmethod
    def from_backends(cls, params: Any, backends: Iterable[str], *,
                      batch_size: int = 32, mesh: Any = None,
                      warmup: bool = True, policy: str = "least_loaded",
                      **engine_kw) -> "ReplicaRouter":
        """Build one replica per backend name over shared float params (each
        engine quantizes its own copy — the paper's per-substrate bake)."""
        return cls([VisionEngine(params, backend=b, batch_size=batch_size,
                                 mesh=mesh, warmup=warmup, **engine_kw)
                    for b in backends], policy=policy)

    # -- request side -------------------------------------------------------

    def healthy_replicas(self) -> list[int]:
        # snapshot under the GIL; callers needing consistency vs concurrent
        # drains hold self._lock (as _pick/run/_redistribute do)
        errors = set(self._errors)
        return [i for i in range(len(self.replicas)) if i not in errors]

    def queue_depths(self) -> list[int]:
        """Per-replica load: router pending lane + engine's own queue."""
        with self._lock:
            return [len(self._pending[i]) + self.replicas[i].queue_depth()
                    for i in range(len(self.replicas))]

    def _pick(self) -> int:
        healthy = self.healthy_replicas()
        if not healthy:
            raise FleetExhaustedError(
                f"all {len(self.replicas)} replicas have failed: "
                f"{ {i: repr(e) for i, e in self._errors.items()} }")
        if self.policy == "round_robin":
            i = healthy[self._rr_clock % len(healthy)]
            self._rr_clock += 1
            return i
        depths = self.queue_depths()
        return min(healthy, key=lambda i: depths[i])

    def submit(self, image: np.ndarray) -> int:
        """Route one image to the least-loaded healthy replica; returns a
        fleet-global uid immediately."""
        with self._lock:
            i = self._pick()
            uid = self._next_uid
            self._next_uid += 1
            self._assignment[uid] = i
            self._pending[i].append(_Pending(
                uid=uid, image=np.asarray(image, np.float32),
                t_submit=time.perf_counter()))
            return uid

    def submit_many(self, images: Iterable[np.ndarray]) -> list[int]:
        return [self.submit(img) for img in images]

    # -- serving side -------------------------------------------------------

    def _drain_replica(self, i: int) -> list[_Pending]:
        """Feed replica i its pending lane and drain it.  Returns the
        requests that did NOT complete (empty when healthy); on failure the
        replica is marked dead and partial results are still harvested."""
        eng = self.replicas[i]
        with self._lock:              # vs concurrent submit() to this lane
            lane, self._pending[i] = self._pending[i], []
        if not lane:
            return []
        local: dict[int, _Pending] = {}
        res: dict[int, VisionResult] = {}
        error: BaseException | None = None
        try:
            if eng.drained:
                eng.reopen()          # failover onto a finished survivor
            for p in lane:
                local[eng.submit(p.image)] = p
            eng.run()
            res = eng.results()
        except Exception as e:        # noqa: BLE001 — any replica fault fails over
            error = e
            try:
                res = eng.results()   # harvest whatever completed pre-fault
            except Exception:
                res = {}
        done: set[int] = set()
        routed = {}
        for luid, p in local.items():
            r = res.get(luid)
            if r is None:
                continue
            routed[p.uid] = RoutedResult(
                uid=p.uid, replica=i, pred=r.pred, scores=r.scores,
                t_submit=p.t_submit, t_done=r.t_done)
            done.add(p.uid)
        with self._lock:
            self._results.update(routed)
            if error is not None:
                self._errors[i] = error
        # unserved from the LANE (not the submitted map): a fault inside
        # eng.submit itself must not drop the never-submitted remainder
        return [p for p in lane if p.uid not in done]

    def run(self) -> int:
        """Drain every replica concurrently; fail unserved requests over to
        survivors until everything is served or the fleet is exhausted.
        Returns total #requests served this call."""
        served_before = len(self._results)
        while True:
            with self._lock:
                # reclaim lanes stranded on dead replicas: a concurrent
                # submit() can route to a replica in the window before its
                # fault is recorded — those requests must fail over too,
                # not sit invisible on a lane nothing will ever drain
                stranded = []
                for i in self._errors:
                    if self._pending[i]:
                        stranded.extend(self._pending[i])
                        self._pending[i] = []
                self._redistribute(stranded)
                busy = [i for i in self.healthy_replicas() if self._pending[i]]
            if not busy:
                break
            with ThreadPoolExecutor(max_workers=len(busy)) as pool:
                unserved_lists = list(pool.map(self._drain_replica, busy))
            unserved = [p for lane in unserved_lists for p in lane]
            if not unserved:
                continue              # loop once more in case of re-routes
            with self._lock:
                self._redistribute(unserved)
        return len(self._results) - served_before

    def _redistribute(self, orphans: list[_Pending]) -> None:
        """Spread failed-over requests across the survivors, shallowest lane
        first.  Caller holds self._lock."""
        if not orphans:
            return
        healthy = self.healthy_replicas()
        if not healthy:
            raise FleetExhaustedError(
                f"{len(orphans)} requests unserved and every replica "
                f"failed: { {i: repr(e) for i, e in self._errors.items()} }")
        for p in orphans:
            i = min(healthy, key=lambda j: len(self._pending[j]))
            self._assignment[p.uid] = i
            self._pending[i].append(p)

    def serve(self, images: Iterable[np.ndarray]) -> list[RoutedResult]:
        """Submit a workload, drain the fleet, return results in submission
        order."""
        uids = self.submit_many(images)
        self.run()
        return [self._results[u] for u in uids]

    # -- reporting ----------------------------------------------------------

    def results(self) -> dict[int, RoutedResult]:
        with self._lock:
            return dict(self._results)

    def errors(self) -> dict[int, BaseException]:
        with self._lock:
            return dict(self._errors)

    def stats(self) -> dict:
        """Fleet-level latency/throughput + the per-replica engine stats."""
        with self._lock:
            res = list(self._results.values())
            failed = sorted(self._errors)
        per_replica = [eng.stats() for eng in self.replicas]
        out = {
            "replicas": len(self.replicas),
            "healthy": len(self.replicas) - len(failed),
            "failed": failed,
            "policy": self.policy,
            "n": len(res),
            "per_replica": per_replica,
            "served_by": {i: sum(1 for r in res if r.replica == i)
                          for i in range(len(self.replicas))},
        }
        if not res:
            return out
        wall = max(r.t_done for r in res) - min(r.t_submit for r in res)
        out.update(latency_stats([r.latency_s for r in res], wall))
        return out
