"""Replica router: SLO-aware fleet-level serving over N vision engines.

The survey line of FPGA accelerator work (Guo et al.; ZynqNet) scales
throughput by REPLICATING the compute unit and partitioning the data path;
`VisionEngine` already scales one step across a mesh, and this module adds
the second axis: a router that owns several engines ("replicas" — distinct
backends, devices, or mesh slices), dispatches each incoming request to a
replica, drains all replicas concurrently, and aggregates per-replica stats
into fleet-level throughput, latency percentiles, and goodput.

Dispatch policies:

  least_loaded  shallowest lane+queue (depth only)
  round_robin   rotate over the healthy set
  slo           minimum PROJECTED WAIT — per-replica depth divided by the
                replica's OBSERVED service rate (`service_rate_qps()`, qps
                over busy time; cold replicas borrow the fleet median, then
                the deterministic `min_step_s` seed rate, then the fleet
                median of seeds), so a slow replica with a short queue
                loses to a fast replica with a longer one.  A replica with
                NO rate from any source and a full batch already backlogged
                projects an infinite wait (a cold fleet must door-shed a
                burst, not queue it into a blown p99).  When even the best
                projected wait exceeds the request's deadline headroom the
                request is SHED at the door (reason "slo_wait") instead of
                being queued — goodput over graveyard latency.

Every request can carry a deadline (default: the router's `slo_ms`); sheds
— at the router door or inside an engine (admission bound, expired
deadline) — are counted per reason, and the fleet ledger mirrors the
engine's:  submitted == served + shed + pending  (stats()["accounted"]).

Dispatch is deferred: `submit()` assigns a request to a replica's pending
lane immediately (so queue depths — the load signal — are visible), but the
images only enter the engine's own queue inside `run()`.  That makes
failover clean: if a replica dies mid-drain (its jitted step raises), the
router collects whatever that engine already completed, re-dispatches the
unserved remainder across the survivors, and only raises if NO replica is
left healthy.  One bad backend never poisons the fleet.

Elastic scaling: construct with `spawn=` (a zero-arg engine factory) and
call `autoscale()` between waves — or `start()` the serving thread, which
drains continuously and autoscales by itself.  Scale-up triggers when the
fleet's backlog exceeds `scale_up_depth` waves of capacity; scale-down
retires the idlest replica after `scale_down_idle` consecutive idle checks
(never below `min_replicas`; retired replicas stay in `replicas` so
indices — and per-replica stats — remain stable).

Usage:

    router = ReplicaRouter.from_backends(params, ["pallas", "fixed_pallas"],
                                         policy="slo", slo_ms=50)
    uids = [router.submit(img) for img in images]
    router.run()                       # concurrent drain + failover
    res = router.pop_results(uids)     # uid -> RoutedResult
    print(router.stats())              # fleet + per-replica
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Iterable, Sequence

import numpy as np

from repro.obs import metrics as M
from repro.obs import trace as T
from repro.serving.vision_engine import (VisionEngine, VisionResult,
                                         latency_stats)


class FleetExhaustedError(RuntimeError):
    """Every replica failed: there is nobody left to serve the remainder."""


@dataclasses.dataclass
class RoutedResult:
    """One served request as the ROUTER's client sees it: global uid,
    which replica served it, and latency measured from router submit (queue
    wait in the router's pending lane included)."""
    uid: int
    replica: int
    pred: int
    scores: np.ndarray
    t_submit: float                   # router-side submit time
    t_done: float

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit


@dataclasses.dataclass
class _Pending:
    uid: int
    image: np.ndarray
    t_submit: float
    deadline_ms: float | None = None
    parent_span: object = None        # caller's trace context (frame span)


class ReplicaRouter:
    """SLO-aware request router over an elastic fleet of `VisionEngine`s."""

    POLICIES = ("least_loaded", "round_robin", "slo")

    def __init__(self, replicas: Sequence[VisionEngine], *,
                 policy: str = "least_loaded", slo_ms: float | None = None,
                 shed_headroom: float = 1.0,
                 spawn: Callable[[], VisionEngine] | None = None,
                 min_replicas: int = 1, max_replicas: int | None = None,
                 scale_up_depth: float = 2.0, scale_down_idle: int = 3):
        if not replicas:
            raise ValueError("ReplicaRouter needs at least one replica")
        if policy not in self.POLICIES:
            raise ValueError(f"unknown policy {policy!r}; one of {self.POLICIES}")
        self.replicas = list(replicas)
        self.policy = policy
        self.slo_ms = None if slo_ms is None else float(slo_ms)
        self.shed_headroom = float(shed_headroom)
        self._spawn = spawn
        self.min_replicas = int(min_replicas)
        self.max_replicas = None if max_replicas is None else int(max_replicas)
        self.scale_up_depth = float(scale_up_depth)
        self.scale_down_idle = int(scale_down_idle)
        self._pending: list[list[_Pending]] = [[] for _ in self.replicas]
        self._errors: dict[int, BaseException] = {}
        self._retired: set[int] = set()
        self._results: dict[int, RoutedResult] = {}
        self._assignment: dict[int, int] = {}      # uid -> replica (pending)
        self._shed: dict[int, str] = {}            # uid -> reason (unfetched)
        # registry-backed fleet ledger + BOUNDED latency reservoir (the raw
        # per-request list used to grow forever — same retention class as
        # the engine's); see repro/obs/metrics.py
        self._id = M.instance_label("router")
        reg = M.REGISTRY
        self._m_submitted = reg.counter("router_submitted", router=self._id)
        self._m_served = reg.counter("router_served", router=self._id)
        self._m_shed: dict[str, M.Counter] = {}    # reason -> Counter
        self._lat_hist = reg.histogram("router_latency_seconds",
                                       router=self._id)
        self._served_by: dict[int, int] = {i: 0 for i in range(len(replicas))}
        self._deadline_total = 0
        self._deadline_ok = 0
        self._idle_ticks = 0
        self._next_uid = 0
        self._rr_last = -1            # last-dispatched STABLE replica id
        self._thread: threading.Thread | None = None
        self._stop_flag = False
        # reentrant condition: _pick (under the submit lock) reads
        # queue_depths, which locks again for its own public callers
        self._lock = threading.Condition(threading.RLock())

    @classmethod
    def from_backends(cls, params: Any, backends: Iterable[str], *,
                      batch_size: int = 32, mesh: Any = None,
                      warmup: bool = True, policy: str = "least_loaded",
                      engine_kw: dict | None = None,
                      **router_kw) -> "ReplicaRouter":
        """Build one replica per backend name over shared float params (each
        engine quantizes its own copy — the paper's per-substrate bake)."""
        return cls([VisionEngine(params, backend=b, batch_size=batch_size,
                                 mesh=mesh, warmup=warmup,
                                 **(engine_kw or {}))
                    for b in backends], policy=policy, **router_kw)

    # -- request side -------------------------------------------------------

    def healthy_replicas(self) -> list[int]:
        # snapshot under the GIL; callers needing consistency vs concurrent
        # drains hold self._lock (as _pick/run/_redistribute do)
        dead = set(self._errors) | self._retired
        return [i for i in range(len(self.replicas)) if i not in dead]

    def queue_depths(self) -> list[int]:
        """Per-replica load: router pending lane + engine queue+in-flight."""
        with self._lock:
            return [len(self._pending[i]) + self.replicas[i].load()
                    for i in range(len(self.replicas))]

    def _load_snapshot(self, healthy: list[int]
                       ) -> dict[int, tuple[int, float | None,
                                            float | None, int]]:
        """ONE consistent read of every dispatch signal, taken under the
        router lock: replica -> (depth, observed rate, seed rate,
        batch_size).  The slo pick derives both the wait map and its depth
        tiebreaker from this single snapshot — reading them in two separate
        locked passes let a concurrent submit land between the reads, so
        the wait map and the tiebreaker could describe different fleets
        mid-pick."""
        with self._lock:
            return {i: (len(self._pending[i]) + self.replicas[i].load(),
                        self.replicas[i].service_rate_qps(),
                        self.replicas[i].seed_rate_qps(),
                        self.replicas[i].batch_size)
                    for i in healthy}

    @staticmethod
    def _projected_waits_from(snapshot: dict[int, tuple[int, float | None,
                                                        float | None, int]]
                              ) -> dict[int, float]:
        """Seconds until a request dispatched NOW would be served, per
        replica: depth / service rate, as a pure function of one load
        snapshot (deterministic given frozen inputs — tested as such).

        Rate fallback chain, most- to least-informed:
          1. the replica's OBSERVED rate (qps over busy time),
          2. the fleet median of observed rates,
          3. the replica's deterministic seed rate (`seed_rate_qps()`: the
             min_step_s capacity floor, known before any traffic),
          4. the fleet median of seed rates.
        A replica with no rate from ANY source projects an INFINITE wait
        once a full batch is already pending on it (depth >= batch_size) —
        the pessimistic reading of "a whole wave is backlogged and there is
        no evidence anybody serves it".  That lets the slo door shed during
        a cold-start burst instead of projecting 0.0 and queueing
        everything into a blown p99 (the cold-fleet SLO hole).  Below one
        batch the wait stays 0.0: a cold replica absorbs its first wave in
        a single step, and serving it is exactly what establishes the
        observed rate."""
        observed = [r for _, r, _, _ in snapshot.values() if r]
        med_obs = float(np.median(observed)) if observed else None
        seeds = [s for _, _, s, _ in snapshot.values() if s]
        med_seed = float(np.median(seeds)) if seeds else None
        waits = {}
        for i, (depth, obs, seed, batch) in snapshot.items():
            rate = obs or med_obs or seed or med_seed
            if rate:
                waits[i] = depth / rate
            else:
                waits[i] = float("inf") if depth >= max(batch, 1) else 0.0
        return waits

    def _projected_waits(self, healthy: list[int]) -> dict[int, float]:
        return self._projected_waits_from(self._load_snapshot(healthy))

    def _pick(self, deadline_ms: float | None = None
              ) -> tuple[int, str | None]:
        """(replica index, shed reason) — reason is non-None when even the
        best replica's projected wait blows the deadline headroom."""
        healthy = self.healthy_replicas()
        if not healthy:
            raise FleetExhaustedError(
                f"all {len(self.replicas)} replicas have failed or retired: "
                f"{ {i: repr(e) for i, e in self._errors.items()} }")
        if self.policy == "round_robin":
            # rotate over STABLE replica ids, not positions in the healthy
            # list: `clock % len(healthy)` re-aliases every time the healthy
            # set churns (failover, autoscale spawn/retire), double-hitting
            # one replica while starving another.  Advancing to the next
            # healthy id past the last-dispatched one is churn-proof — ids
            # never move.
            nxt = [i for i in healthy if i > self._rr_last]
            i = nxt[0] if nxt else healthy[0]
            self._rr_last = i
            return i, None
        if self.policy == "least_loaded":
            depths = self.queue_depths()
            return min(healthy, key=lambda i: depths[i]), None
        snapshot = self._load_snapshot(healthy)
        waits = self._projected_waits_from(snapshot)
        i = min(healthy, key=lambda j: (waits[j], snapshot[j][0]))
        if (deadline_ms is not None
                and waits[i] * 1e3 > deadline_ms * self.shed_headroom):
            return i, "slo_wait"
        return i, None

    def submit(self, image: np.ndarray, *,
               deadline_ms: float | None = None,
               t_submit: float | None = None,
               parent_span: object = None) -> int:
        """Route one image per the dispatch policy; returns a fleet-global
        uid immediately.  Under the "slo" policy a request the fleet cannot
        plausibly serve in time is shed at the door (reason "slo_wait").
        `t_submit` lets an open-loop replay harness stamp the request with
        its scheduled arrival time (the engine deadline then counts from
        intended arrival, not generator lag).  With tracing on, every
        routing decision emits a point span "dispatch" — chosen replica,
        policy, projected wait — nested under `parent_span` when given, so
        a frame's waterfall shows WHERE it was sent and a door-shed request
        carries the span where it died."""
        tr = T.get()
        with self._lock:
            dl = deadline_ms if deadline_ms is not None else self.slo_ms
            i, shed = self._pick(dl)   # may raise FleetExhaustedError:
            uid = self._next_uid       # counters move only once admitted
            self._next_uid += 1
            self._m_submitted.inc()
            if dl is not None:
                self._deadline_total += 1
            if shed is not None:
                if tr is not None:
                    tr.point("dispatch", (parent_span.trace_id
                                          if parent_span is not None
                                          else f"rreq-{self._id}-{uid}"),
                             f"shed:{shed}", parent=parent_span,
                             uid=uid, policy=self.policy, router=self._id)
                self._shed_uid_locked(uid, shed)
                return uid
            if tr is not None:
                tr.point("dispatch", (parent_span.trace_id
                                      if parent_span is not None
                                      else f"rreq-{self._id}-{uid}"),
                         parent=parent_span, uid=uid, replica=i,
                         policy=self.policy, router=self._id)
            self._assignment[uid] = i
            now = (time.perf_counter() if t_submit is None
                   else float(t_submit))
            self._pending[i].append(_Pending(
                uid=uid, image=np.asarray(image, np.float32),
                t_submit=now, deadline_ms=dl, parent_span=parent_span))
            self._lock.notify_all()
            return uid

    def submit_many(self, images: Iterable[np.ndarray], *,
                    deadline_ms: float | None = None,
                    parent_span: object = None) -> list[int]:
        return [self.submit(img, deadline_ms=deadline_ms,
                            parent_span=parent_span) for img in images]

    def _shed_uid_locked(self, uid: int, reason: str) -> None:
        self._shed[uid] = reason
        c = self._m_shed.get(reason)
        if c is None:
            c = M.REGISTRY.counter("router_shed", reason=reason,
                                   router=self._id)
            self._m_shed[reason] = c
        c.inc()
        self._assignment.pop(uid, None)
        self._lock.notify_all()

    # -- serving side -------------------------------------------------------

    def _drain_replica(self, i: int) -> list[_Pending]:
        """Feed replica i its pending lane and drain it.  Returns the
        requests that did NOT complete (empty when healthy); on failure the
        replica is marked dead and partial results are still harvested.
        Engine-side sheds (expired deadline, admission bound) are recorded
        as fleet sheds, NOT failed over — their deadline already lapsed."""
        eng = self.replicas[i]
        with self._lock:              # vs concurrent submit() to this lane
            lane, self._pending[i] = self._pending[i], []
        if not lane:
            return []
        local: dict[int, _Pending] = {}
        res: dict[int, VisionResult] = {}
        eng_shed: dict[int, str] = {}
        error: BaseException | None = None
        try:
            for p in lane:
                # stamp the engine request with the ROUTER submit time so
                # engine latency/deadlines measure what the client observes
                local[eng.submit(p.image, deadline_ms=p.deadline_ms,
                                 t_submit=p.t_submit,
                                 parent_span=p.parent_span)] = p
            eng.run()
        except Exception as e:        # noqa: BLE001 — any replica fault fails over
            error = e
        try:                          # harvest whatever completed pre-fault
            res = eng.pop_results(list(local))
            eng_shed = eng.pop_shed(list(local))
        except Exception:
            res, eng_shed = {}, {}
        done: set[int] = set()
        routed: dict[int, RoutedResult] = {}
        shed_here: dict[int, str] = {}
        for luid, p in local.items():
            r = res.get(luid)
            if r is not None:
                routed[p.uid] = RoutedResult(
                    uid=p.uid, replica=i, pred=r.pred, scores=r.scores,
                    t_submit=p.t_submit, t_done=r.t_done)
                done.add(p.uid)
                continue
            reason = eng_shed.get(luid)
            if reason is not None and reason != "fault":
                shed_here[p.uid] = reason    # lapsed in queue: not re-run
                done.add(p.uid)
        with self._lock:
            self._results.update(routed)
            for uid, rr in routed.items():
                self._m_served.inc()
                self._served_by[i] = self._served_by.get(i, 0) + 1
                self._lat_hist.observe(rr.latency_s)
                self._assignment.pop(uid, None)
            for uid, reason in shed_here.items():
                self._shed_uid_locked(uid, reason)
            # deadline bookkeeping needs the pending records, not the uids
            for luid, p in local.items():
                if p.uid in routed and p.deadline_ms is not None:
                    rr = routed[p.uid]
                    if rr.t_done <= p.t_submit + p.deadline_ms / 1e3:
                        self._deadline_ok += 1
            if error is not None:
                self._errors[i] = error
            self._lock.notify_all()
        # unserved from the LANE (not the submitted map): a fault inside
        # eng.submit itself must not drop the never-submitted remainder
        return [p for p in lane if p.uid not in done]

    def run(self) -> int:
        """Drain every replica concurrently; fail unserved requests over to
        survivors until everything is served (or shed) or the fleet is
        exhausted.  Returns total #requests served this call."""
        served_before = self._m_served.value
        while True:
            with self._lock:
                # reclaim lanes stranded on dead replicas: a concurrent
                # submit() can route to a replica in the window before its
                # fault is recorded — those requests must fail over too,
                # not sit invisible on a lane nothing will ever drain
                stranded = []
                for i in list(self._errors) + sorted(self._retired):
                    if self._pending[i]:
                        stranded.extend(self._pending[i])
                        self._pending[i] = []
                self._redistribute(stranded)
                busy = [i for i in self.healthy_replicas() if self._pending[i]]
            if not busy:
                break
            with ThreadPoolExecutor(max_workers=len(busy)) as pool:
                unserved_lists = list(pool.map(self._drain_replica, busy))
            unserved = [p for lane in unserved_lists for p in lane]
            if not unserved:
                continue              # loop once more in case of re-routes
            with self._lock:
                self._redistribute(unserved)
        return self._m_served.value - served_before

    def _redistribute(self, orphans: list[_Pending]) -> None:
        """Spread failed-over requests across the survivors, shallowest lane
        first.  Caller holds self._lock."""
        if not orphans:
            return
        healthy = self.healthy_replicas()
        if not healthy:
            raise FleetExhaustedError(
                f"{len(orphans)} requests unserved and every replica "
                f"failed: { {i: repr(e) for i, e in self._errors.items()} }")
        for p in orphans:
            i = min(healthy, key=lambda j: len(self._pending[j]))
            self._assignment[p.uid] = i
            self._pending[i].append(p)

    # -- continuous serving + elastic scaling -------------------------------

    def start(self) -> "ReplicaRouter":
        """Spawn the fleet serving loop: drain whatever is pending, wave
        after wave (continuous batching at fleet granularity — each drain
        takes exactly what accumulated during the last), autoscaling when a
        `spawn` factory was provided.  Idempotent."""
        with self._lock:
            if self._thread is not None:
                return self
            self._stop_flag = False
            self._thread = threading.Thread(
                target=self._serve_loop, daemon=True, name="replica-router")
            self._thread.start()
        return self

    def _serve_loop(self) -> None:
        while True:
            with self._lock:
                has_work = any(self._pending[i]
                               for i in self.healthy_replicas())
                if not has_work:
                    if self._stop_flag:
                        return
                    self._lock.wait(timeout=0.01)
            if has_work:
                try:
                    self.run()
                except FleetExhaustedError:
                    with self._lock:
                        for lane in self._pending:
                            while lane:
                                self._shed_uid_locked(lane.pop().uid,
                                                      "fleet_exhausted")
                    return
            if self._spawn is not None:
                self.autoscale()

    def stop(self, drain: bool = True) -> None:
        """Stop the fleet serving loop (draining pending work first unless
        `drain=False`, which sheds it)."""
        with self._lock:
            thread = self._thread
            self._stop_flag = True
            if not drain:
                for lane in self._pending:
                    while lane:
                        self._shed_uid_locked(lane.pop().uid, "stopped")
            self._lock.notify_all()
        if thread is not None:
            thread.join(timeout=120.0)
            with self._lock:
                self._thread = None
                self._stop_flag = False

    def autoscale(self) -> str | None:
        """One elastic-sizing decision against depth + goodput signals.
        Scale UP (via the `spawn` factory) when the fleet backlog exceeds
        `scale_up_depth` waves of current batch capacity; RETIRE the
        emptiest replica after `scale_down_idle` consecutive idle checks.
        Returns "spawn:<i>" / "retire:<i>" / None.  Meant to be called from
        one place (the serving loop or the harness) — concurrent callers
        may overshoot the bounds by a replica."""
        with self._lock:
            healthy = self.healthy_replicas()
            if not healthy:
                return None
            depth = sum(len(self._pending[i]) + self.replicas[i].load()
                        for i in healthy)
            capacity = sum(self.replicas[i].batch_size for i in healthy)
            self._idle_ticks = self._idle_ticks + 1 if depth == 0 else 0
            can_grow = (self._spawn is not None
                        and (self.max_replicas is None
                             or len(healthy) < self.max_replicas))
            if can_grow and depth > self.scale_up_depth * capacity:
                grow = True
            else:
                grow = False
                if (len(healthy) > self.min_replicas
                        and self._idle_ticks >= self.scale_down_idle):
                    i = min(healthy,
                            key=lambda j: len(self._pending[j])
                            + self.replicas[j].load())
                    if not self._pending[i] and self.replicas[i].load() == 0:
                        self._retired.add(i)
                        self._idle_ticks = 0
                        self.replicas[i].stop(drain=True)
                        return f"retire:{i}"
                return None
        eng = self._spawn()           # build OUTSIDE the lock: warmup compiles
        with self._lock:
            self.replicas.append(eng)
            self._pending.append([])
            i = len(self.replicas) - 1
            self._served_by.setdefault(i, 0)
            self._idle_ticks = 0
            return f"spawn:{i}"

    # -- client loop --------------------------------------------------------

    def wait(self, uids: Iterable[int], timeout: float | None = None) -> None:
        """Block until every uid is resolved (served or shed).  With the
        serving thread running this waits on its completions; without it,
        pending waves are drained inline via run()."""
        uids = list(uids)

        def unresolved_locked():
            return [u for u in uids
                    if u not in self._results and u not in self._shed]

        if self._thread is None:
            while True:
                with self._lock:
                    missing = unresolved_locked()
                    if not missing:
                        return
                    pending = sum(len(lane) for lane in self._pending)
                if pending == 0:
                    raise KeyError(
                        f"uids {missing[:4]} are not pending, served, or "
                        "shed — were their results already popped?")
                self.run()
            return
        t_end = None if timeout is None else time.perf_counter() + timeout
        with self._lock:
            while unresolved_locked():
                remaining = (None if t_end is None
                             else t_end - time.perf_counter())
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"{len(unresolved_locked())} of {len(uids)} requests "
                        f"unresolved after {timeout}s")
                self._lock.wait(remaining if remaining is not None else 0.1)

    def pop_results(self, uids: Iterable[int] | None = None
                    ) -> dict[int, RoutedResult]:
        """Hand over (and forget) completed results — bounded retention at
        fleet level (assignment records go with them)."""
        with self._lock:
            if uids is None:
                out, self._results = self._results, {}
                self._assignment = {u: i for u, i in self._assignment.items()
                                    if u not in out}
                return out
            out = {}
            for u in list(uids):
                if u in self._results:
                    out[u] = self._results.pop(u)
                    self._assignment.pop(u, None)
            return out

    def pop_shed(self, uids: Iterable[int] | None = None) -> dict[int, str]:
        """Hand over (and forget) shed records (uid -> reason)."""
        with self._lock:
            if uids is None:
                out, self._shed = self._shed, {}
                return out
            return {u: self._shed.pop(u) for u in list(uids)
                    if u in self._shed}

    def serve(self, images: Iterable[np.ndarray], *,
              deadline_ms: float | None = None
              ) -> list["RoutedResult | None"]:
        """Submit a workload, drain the fleet, return results in submission
        order (None where a request was shed)."""
        uids = self.submit_many(images, deadline_ms=deadline_ms)
        self.wait(uids)
        res = self.pop_results(uids)
        self.pop_shed(uids)
        return [res.get(u) for u in uids]

    # -- reporting ----------------------------------------------------------

    def results(self) -> dict[int, RoutedResult]:
        """Currently-retained (not yet popped) results."""
        with self._lock:
            return dict(self._results)

    def errors(self) -> dict[int, BaseException]:
        with self._lock:
            return dict(self._errors)

    def stats(self) -> dict:
        """Fleet-level goodput/latency/throughput + per-replica engine
        stats.  Fleet throughput is the SUM of per-replica observed service
        rates (replicas serve in parallel), each measured over that
        replica's busy time — idle gaps never deflate it."""
        with self._lock:
            submitted = self._m_submitted.value
            served = self._m_served.value
            shed_by = {r: c.value for r, c in sorted(self._m_shed.items())}
            shed_total = sum(shed_by.values())
            # lanes (incl. ones stranded on dead replicas — run() reclaims
            # those) + live engines' queues.  A DEAD replica's engine queue
            # is excluded: whatever it still holds was already failed over.
            pending = (sum(len(lane) for lane in self._pending)
                       + sum(self.replicas[i].load()
                             for i in range(len(self.replicas))
                             if i not in self._errors))
            failed = sorted(self._errors)
            accounted = submitted == served + shed_total + pending
            out = {
                "replicas": len(self.replicas),
                "healthy": len(self.healthy_replicas()),
                "retired": sorted(self._retired),
                "failed": failed,
                "policy": self.policy,
                "slo_ms": self.slo_ms,
                "n": served,
                "submitted": submitted,
                "shed": shed_total,
                "shed_by_reason": shed_by,
                "pending": pending,
                # the fleet-level no-silent-loss invariant
                "accounted": accounted,
                "per_replica": [eng.stats() for eng in self.replicas],
                "served_by": dict(sorted(self._served_by.items())),
            }
            if self._deadline_total:
                out["deadline_total"] = self._deadline_total
                out["served_within_deadline"] = self._deadline_ok
                out["goodput"] = self._deadline_ok / self._deadline_total
            if served:
                busy = sum(r["busy_s"] for r in out["per_replica"])
                out.update(latency_stats(self._lat_hist.samples(), busy))
                rates = [eng.service_rate_qps() for eng in self.replicas]
                out["throughput_qps"] = float(sum(r for r in rates if r))
        if not accounted:
            tr = T.get()
            if tr is not None:
                tr.recorder.trip(
                    "ledger_invariant",
                    f"router {self._id}: submitted={submitted} != "
                    f"served={served} + shed={shed_total} + "
                    f"pending={pending}")
        return out
