"""Batched serving engine: prefill + decode with continuous slot reuse.

The TPU analogue of the paper's DMA-FIFO deployment loop: requests stream in,
a batch slot is assigned, prefill fills the slot's KV/state, decode steps the
whole batch in lockstep (one serve_step per token), finished slots are freed
and refilled without draining the batch ("continuous batching lite").

Supports the paper's quantized-deployment flow: pass `quantized_params`
produced by core.ptq.quantize_tree and the engine dequantizes weights on-use
(the int8 serving path; bakeable via core.deploy.bake).
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.models import transformer


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg: ArchConfig, params, *, batch_size: int = 4,
                 max_len: int = 256, greedy: bool = True):
        self.cfg, self.params = cfg, params
        self.B, self.T = batch_size, max_len
        self.model = M.build(cfg)
        self.decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        self.cache = transformer.zeros_cache(cfg, batch_size, max_len)
        self.pos = np.zeros(batch_size, np.int32)       # per-slot next pos
        self.slot_req: list[Request | None] = [None] * batch_size
        self.greedy = greedy

    def submit_and_run(self, requests: list[Request]) -> list[Request]:
        """Run a workload of requests to completion with continuous batching."""
        queue = list(requests)
        active: list[Request] = []
        tokens = np.zeros((self.B, 1), np.int32)
        pending_prompt: dict[int, list[int]] = {}

        def assign(slot: int, req: Request):
            self.slot_req[slot] = req
            self.pos[slot] = 0
            pending_prompt[slot] = list(req.prompt)

        # initial fill
        for slot in range(self.B):
            if queue:
                assign(slot, queue.pop(0))

        steps = 0
        vocab = self.cfg.vocab
        while any(r is not None for r in self.slot_req):
            # choose this step's token per slot: next prompt token (prefill
            # phase) or the last generated token (decode phase)
            for slot, req in enumerate(self.slot_req):
                if req is None:
                    tokens[slot, 0] = 0
                elif pending_prompt[slot]:
                    tokens[slot, 0] = pending_prompt[slot].pop(0)
                else:
                    tokens[slot, 0] = req.out[-1] if req.out else 0
            # lockstep batch decode at per-slot positions: the engine uses a
            # shared pos (max) with per-slot masking handled by cache zeros;
            # reference implementation keeps slots position-aligned by
            # assigning work in waves.
            pos = int(max(self.pos))
            logits, self.cache = self.decode(self.params, self.cache,
                                             jnp.asarray(tokens),
                                             jnp.asarray(pos, jnp.int32))
            nxt = np.asarray(jnp.argmax(logits[:, :vocab], axis=-1))
            for slot, req in enumerate(self.slot_req):
                if req is None:
                    continue
                self.pos[slot] += 1
                if not pending_prompt[slot]:            # generating
                    req.out.append(int(nxt[slot]))
                    if len(req.out) >= req.max_new_tokens:
                        req.done = True
                        self.slot_req[slot] = None      # free slot
                        if queue:                        # continuous refill
                            assign(slot, queue.pop(0))
            steps += 1
            if steps > 16384:
                raise RuntimeError("engine wedged")
        return requests
