"""Streaming vision serving engine: continuous batching over async requests.

The TPU analogue of the paper's deployment loop — there, pixels stream from
the PS over a DMA-FIFO into the fabric and classifications stream back; here,
single-image classification requests stream into a queue and every `step()`
forms one batch from WHATEVER is queued at that instant (continuous
batching: no wave boundaries, no drain/reopen churn), zero-pads it to the
engine's fixed `batch_size` (one compiled program, no recompilation churn —
the FIFO depth is the batch size), runs one jitted step of `smallnet.apply`
on any registered backend, and streams per-request results back with latency
accounting.

Under real load the engine is also the ADMISSION CONTROLLER: `max_queue`
bounds the intake (an arrival past the bound is shed immediately, reason
"queue_depth"), `max_age_ms` and per-request deadlines shed stale requests
at batch-forming time (reasons "age"/"deadline"), and a faulted step sheds
its batch (reason "fault") instead of losing it.  Every shed is counted per
reason and the pipeline's no-silent-loss invariant extends to the engine:

    submitted == served + shed + pending        (stats()["accounted"])

Serving runs either synchronously (`step()`/`run()` on the caller's thread)
or continuously (`start()` spawns a serving thread that batches whatever
arrives; `submit()` + `wait()` + `pop_results()` is the client loop —
`serve()` wraps all three).  Results are handed over by `pop_results()`, so
memory stays O(inflight), not O(stream length); latency/throughput stats
accumulate in O(1)-per-request accumulators independent of retention.

Throughput is reported over BUSY time (the sum of per-step serving windows),
not the submit-to-done wall clock, so an engine reused across separated
bursts reports its real service rate instead of one deflated by idle gaps —
`service_rate_qps()` is the router's load signal.

Pass a `jax.sharding.Mesh` and the jitted step shards the batch dim across
the mesh's data axes (the vision rules preset in `distributed/sharding.py`):
inputs/outputs carry a `NamedSharding`, the padded batch size is rounded up
to a multiple of the mesh batch axes, and on 1 device the whole thing
degenerates to the unsharded program — same engine code on a laptop CPU and
a pod slice.  For scaling across *separate* engines (distinct backends or
mesh slices) see `serving/router.py`.

Sibling of `serving/engine.py` (the LM continuous-batching engine); this one
is the image-classification half of the serving story.

Usage:

    eng = VisionEngine(params, backend="pallas", batch_size=32,
                       max_queue=128)
    eng.start()                                      # continuous batching
    uids = [eng.submit(img, deadline_ms=50) for img in images]
    eng.wait(uids)
    res = eng.pop_results(uids)                      # uid -> VisionResult
    print(eng.stats())                               # latency + goodput
    eng.stop()
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
import time
from typing import Any, Iterable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import backends as B
from repro.core import smallnet
from repro.distributed import sharding as shd
from repro.obs import metrics as M
from repro.obs import trace as T


def latency_stats(latencies_s, window_s: float) -> dict:
    """The shared latency/throughput block of engine AND fleet stats():
    mean/p50/p95/p99/max in ms + qps over the `window_s`-second serving
    window.  A zero-length window yields 0.0 qps (a single instantaneous
    batch has no measurable rate — never inf); an empty latency set raises
    (callers must guard the n == 0 case explicitly).  Percentiles are
    NEAREST-RANK via the one shared helper (`obs.metrics.percentile`) —
    the same semantics as every other latency summary in the repo."""
    return M.summarize_latency(latencies_s, window_s)


class EngineFaultError(RuntimeError):
    """The serving thread died: the jitted step raised.  Queued and future
    submits are shed with reason "fault" (accounting still reconciles); the
    original exception is chained as __cause__."""


@dataclasses.dataclass
class VisionRequest:
    uid: int
    image: np.ndarray                 # (28, 28, 1) float32
    t_submit: float = 0.0
    deadline: float | None = None     # absolute perf_counter time, or None
    parent_span: Any = None           # caller's trace context (traced runs)


@dataclasses.dataclass
class VisionResult:
    uid: int
    pred: int                         # Max Finder output
    scores: np.ndarray                # (10,) backend-native class scores
    t_submit: float
    t_done: float
    batch_index: int                  # which engine step served it
    deadline: float | None = None     # absolute deadline it was held to

    @property
    def latency_s(self) -> float:
        """Queue wait + batch compute (what the client observes)."""
        return self.t_done - self.t_submit

    @property
    def within_deadline(self) -> bool:
        """True when served in time (vacuously true without a deadline)."""
        return self.deadline is None or self.t_done <= self.deadline


class VisionEngine:
    """Continuously-batched streaming classifier over any smallNet backend.

    Requests submitted via `submit()` queue up (or are shed at the
    admission bound); each `step()` pops up to `batch_size` of them —
    shedding any whose deadline/age already expired — zero-pads to exactly
    `batch_size` (static shape -> a single XLA executable per engine), runs
    the jitted forward, and timestamps completions after
    `block_until_ready` so reported latency is honest wall clock.

    With `mesh=` the step is traced under the vision sharding rules and the
    batch axis is split across the mesh (batch_size is rounded UP to the
    nearest multiple of the mesh batch axes so every device gets equal full
    shards).  The ambient mesh context is part of jax's jit cache key on
    the versions we support, so the engine re-enters it around every step.

    Thread model: all bookkeeping lives under one condition variable; the
    jitted compute runs outside it, so submitters never block on the
    accelerator.  `start()`/`stop()` run the step loop on a daemon thread
    (continuous batching); without it, `step()`/`run()`/`wait()` drive
    serving synchronously on the caller's thread.
    """

    def __init__(self, params: Any, *, backend: str | B.Backend = "ref",
                 batch_size: int = 32, image_shape=(28, 28, 1),
                 warmup: bool = True, mesh: Any = None,
                 max_queue: int | None = None,
                 max_age_ms: float | None = None,
                 default_deadline_ms: float | None = None,
                 min_step_s: float = 0.0):
        self.backend = B.get_backend(backend)
        self.image_shape = tuple(image_shape)
        self.mesh = mesh
        self.batch_size = int(batch_size)
        self.max_queue = None if max_queue is None else int(max_queue)
        self.max_age_ms = None if max_age_ms is None else float(max_age_ms)
        self.default_deadline_ms = (None if default_deadline_ms is None
                                    else float(default_deadline_ms))
        # service-time floor per step: a deterministic rate limiter
        # (capacity = batch_size / min_step_s) so overload harnesses can
        # drive a known capacity regardless of host speed; 0 disables
        self.min_step_s = float(min_step_s)
        if mesh is not None:
            mult = shd.vision_batch_multiple(mesh)
            self.batch_size = -(-self.batch_size // mult) * mult  # ceil to mult
            self._rules = shd.make_vision_rules(mesh)
            batch_spec = self._rules["batch"]
            self._in_sharding = NamedSharding(
                mesh, P(batch_spec, *(None,) * len(self.image_shape)))
            self._out_sharding = NamedSharding(mesh, P(batch_spec, None))
        # quantize once at engine build (the paper bakes weights at synthesis)
        self.params = self.backend.prepare_params(params)
        self._step_fn = self._build_step()
        self._cond = threading.Condition()
        self._queue: collections.deque[VisionRequest] = collections.deque()
        self._results: dict[int, VisionResult] = {}
        self._shed: dict[int, str] = {}            # uid -> reason (unfetched)
        # -- registry-backed accounting (repro/obs/metrics.py): the ledger
        # counters, queue-depth gauge, and latency histogram live in the
        # process-wide registry under this engine's unique instance label
        # (Prometheus-exportable, bounded memory — the latency list used to
        # grow per request forever).  stats() reads these back; the ledger
        # invariant submitted == served + shed + pending is computed over
        # the counter values.
        self._id = M.instance_label(f"eng-{self.backend.name}")
        reg = M.REGISTRY
        labels = {"engine": self._id, "backend": self.backend.name}
        self._m_submitted = reg.counter("engine_submitted", **labels)
        self._m_served = reg.counter("engine_served", **labels)
        self._m_shed: dict[str, M.Counter] = {}    # reason -> Counter
        self._m_batches = reg.counter("engine_batches", **labels)
        self._m_padded = reg.counter("engine_padded_slots", **labels)
        self._m_busy = reg.counter("engine_busy_seconds", **labels)
        self._m_queue = reg.gauge("engine_queue_depth", **labels)
        self._m_occupancy = reg.gauge("engine_batch_occupancy", **labels)
        self._lat_hist = reg.histogram("engine_latency_seconds", **labels)
        self._next_uid = 0
        self._in_flight = 0
        self._deadline_total = 0                   # submits that carried one
        self._deadline_ok = 0                      # ...served in time
        self._t_first_submit: float | None = None
        self._t_last_done: float | None = None
        self._thread: threading.Thread | None = None
        self._stop_flag = False
        self._fault: BaseException | None = None
        if warmup:                    # compile outside the serving clock
            zeros = jnp.zeros((self.batch_size,) + self.image_shape, jnp.float32)
            with self._mesh_ctx():
                self._step_fn(self.params, zeros).block_until_ready()

    def _mesh_ctx(self):
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    def _build_step(self):
        be = self.backend
        if self.mesh is None:
            return jax.jit(lambda p, x: smallnet.apply(p, x, backend=be))
        rules = self._rules

        def fwd(p, x):
            # the rules context is live during TRACE, which is when the
            # logical->physical constraint specs are resolved
            with shd.sharding_rules(rules):
                return smallnet.apply(p, x, backend=be)

        # params replicated (510 params ~ 2 KB; a pytree-prefix sharding
        # broadcasts to every leaf), batch split across the mesh data axes
        return jax.jit(fwd,
                       in_shardings=(NamedSharding(self.mesh, P()),
                                     self._in_sharding),
                       out_shardings=self._out_sharding)

    # -- request side -------------------------------------------------------

    def submit(self, image: np.ndarray, *, deadline_ms: float | None = None,
               t_submit: float | None = None, parent_span: Any = None) -> int:
        """Queue one image; returns its uid immediately (async).  A request
        past the admission bound (or to a faulted engine) is SHED — the uid
        resolves via `pop_shed()` instead of `pop_results()`, so accounting
        always reconciles.  `t_submit` lets an open-loop replay harness
        stamp the request with its scheduled arrival time (latency and
        deadlines then measure from intended arrival, not generator lag).
        With tracing on, the request yields a root "request" span (exactly
        one terminal state, served/shed:<reason>) nested under
        `parent_span` when the caller supplies its own trace context (the
        streaming pipeline passes the frame's root span).  The span is
        materialized at the request's terminal point from the timestamps
        the engine records anyway — submit itself does no tracer work."""
        img = np.asarray(image, np.float32).reshape(self.image_shape)
        with self._cond:
            uid = self._next_uid
            self._next_uid += 1
            self._m_submitted.inc()
            now = time.perf_counter() if t_submit is None else float(t_submit)
            if self._t_first_submit is None:
                self._t_first_submit = now
            dl_ms = (deadline_ms if deadline_ms is not None
                     else self.default_deadline_ms)
            if dl_ms is not None:
                self._deadline_total += 1
            # Tracing adds NOTHING here: the request path records plain
            # floats (t_submit) and the caller's span ref; the "request" /
            # "queue_wait" spans are materialized at their terminal point
            # (step completion or shed) via Tracer.emit, keeping the
            # submit critical path span-free.
            if self._fault is not None:
                self._shed_locked(uid, "fault", now, now,
                                  parent_span=parent_span)
            elif (self.max_queue is not None
                    and len(self._queue) >= self.max_queue):
                self._shed_locked(uid, "queue_depth", now, now,
                                  parent_span=parent_span)
            else:
                deadline = now + dl_ms / 1e3 if dl_ms is not None else None
                self._queue.append(VisionRequest(
                    uid=uid, image=img, t_submit=now, deadline=deadline,
                    parent_span=parent_span))
                self._m_queue.set(len(self._queue))
                self._cond.notify_all()
            return uid

    def submit_many(self, images: Iterable[np.ndarray], *,
                    deadline_ms: float | None = None,
                    parent_span: Any = None) -> list[int]:
        return [self.submit(img, deadline_ms=deadline_ms,
                            parent_span=parent_span) for img in images]

    def _shed_locked(self, uid: int, reason: str,
                     t_submit: float, t_end: float, *,
                     parent_span: Any = None, queued: bool = False) -> None:
        self._shed[uid] = reason
        c = self._m_shed.get(reason)
        if c is None:
            c = M.REGISTRY.counter("engine_shed", reason=reason,
                                   engine=self._id,
                                   backend=self.backend.name)
            self._m_shed[reason] = c
        c.inc()
        tr = T.get()
        if tr is not None:
            tid = (parent_span.trace_id if parent_span is not None
                   else f"req-{self._id}-{uid}")
            span = tr.emit("request", tid, t_submit, t_end,
                           f"shed:{reason}", parent=parent_span, uid=uid,
                           engine=self._id)
            if queued:   # the request sat in the queue before being shed
                tr.emit("queue_wait", tid, t_submit, t_end,
                        "expired" if reason in ("deadline", "age") else "ok",
                        parent=span)
        self._cond.notify_all()

    # -- serving side -------------------------------------------------------

    def _form_batch_locked(self) -> list[VisionRequest]:
        """Pop up to batch_size live requests; shed expired ones in passing
        (their deadline already lapsed or they outlived max_age_ms — serving
        them would burn a slot on an answer nobody can use)."""
        reqs: list[VisionRequest] = []
        now = time.perf_counter()
        while self._queue and len(reqs) < self.batch_size:
            r = self._queue.popleft()
            if r.deadline is not None and now > r.deadline:
                self._shed_locked(r.uid, "deadline", r.t_submit, now,
                                  parent_span=r.parent_span, queued=True)
            elif (self.max_age_ms is not None
                    and (now - r.t_submit) * 1e3 > self.max_age_ms):
                self._shed_locked(r.uid, "age", r.t_submit, now,
                                  parent_span=r.parent_span, queued=True)
            else:
                reqs.append(r)
        self._m_queue.set(len(self._queue))
        return reqs

    def step(self) -> int:
        """Serve one continuous batch: coalesce whatever is queued (up to
        batch_size), pad, run the jitted step, record results. Returns
        #requests served (sheds don't count)."""
        tr = T.get()
        batch_idx = self._m_batches.value
        bf = (tr.start("batch_form", f"step-{self._id}-{batch_idx}",
                       batch_index=batch_idx, engine=self._id)
              if tr is not None else None)
        with self._cond:
            reqs = self._form_batch_locked()
            if not reqs:
                if bf is not None:
                    tr.end(bf, n_formed=0)
                return 0
            self._in_flight = len(reqs)
        if bf is not None:
            tr.end(bf, n_formed=len(reqs))
        t0 = time.perf_counter()
        ds = (tr.start("device_step", f"step-{self._id}-{batch_idx}",
                       batch_index=batch_idx, engine=self._id,
                       n_real=len(reqs),
                       padded=self.batch_size - len(reqs))
              if tr is not None else None)
        try:
            batch = np.zeros((self.batch_size,) + self.image_shape, np.float32)
            for i, r in enumerate(reqs):
                batch[i] = r.image
            with self._mesh_ctx(), T.device_step_annotation(
                    f"vision_step/{self.backend.name}"):
                scores = self._step_fn(self.params, jnp.asarray(batch))
                scores.block_until_ready()
        except Exception:
            # a faulted step sheds its batch (reason "fault") rather than
            # losing it: submitted == served + shed + pending must survive
            # replica death (the router treats "fault" sheds as unserved
            # and fails them over)
            if ds is not None:
                tr.end(ds, "error")
            with self._cond:
                self._in_flight = 0
                now = time.perf_counter()
                for r in reqs:
                    self._shed_locked(r.uid, "fault", r.t_submit, now,
                                      parent_span=r.parent_span, queued=True)
            raise
        t_done = time.perf_counter()
        if self.min_step_s > 0.0 and t_done - t0 < self.min_step_s:
            time.sleep(self.min_step_s - (t_done - t0))
            t_done = time.perf_counter()     # the floor IS the service time
        if ds is not None:
            tr.end(ds)
        preds = np.asarray(smallnet.predict(scores))
        scores_np = np.asarray(scores)
        with self._cond:
            self._m_busy.inc(t_done - t0)
            self._t_last_done = t_done
            for i, r in enumerate(reqs):
                res = VisionResult(
                    uid=r.uid, pred=int(preds[i]), scores=scores_np[i],
                    t_submit=r.t_submit, t_done=t_done,
                    batch_index=batch_idx, deadline=r.deadline)
                self._results[r.uid] = res
                self._lat_hist.observe(res.latency_s)
                if r.deadline is not None and t_done <= r.deadline:
                    self._deadline_ok += 1
            self._m_served.inc(len(reqs))
            self._m_batches.inc()
            self._m_padded.inc(self.batch_size - len(reqs))
            slots = self._m_batches.value * self.batch_size
            self._m_occupancy.set((slots - self._m_padded.value) / slots)
            self._in_flight = 0
            self._cond.notify_all()
        if tr is not None:
            # materialize the batch's request/queue_wait spans AFTER the
            # waiters are released, from timestamps the engine recorded
            # anyway (t_submit, batch formation, t_done): the traced submit
            # path allocates nothing, and t_done precedes the frame root's
            # end so parent-window nesting still holds
            t_formed = bf.t_end if bf is not None else t0
            for r in reqs:
                tid = (r.parent_span.trace_id if r.parent_span is not None
                       else f"req-{self._id}-{r.uid}")
                span = tr.emit("request", tid, r.t_submit, t_done, "served",
                               parent=r.parent_span, uid=r.uid,
                               batch_index=batch_idx)
                tr.emit("queue_wait", tid, r.t_submit, t_formed,
                        parent=span)
        return len(reqs)

    def run(self) -> int:
        """Synchronously drain the current queue in continuous batches;
        returns #requests served.  The intake stays open — submits during
        and after the drain serve on the next step (no wave lifecycle)."""
        served = 0
        while True:
            n = self.step()
            served += n
            if n == 0:
                with self._cond:
                    if not self._queue:
                        return served

    # -- continuous serving thread ------------------------------------------

    def start(self) -> "VisionEngine":
        """Spawn the continuous-batching loop: a daemon thread that forms a
        batch from whatever is queued whenever work exists.  Idempotent."""
        with self._cond:
            if self._thread is not None:
                return self
            self._stop_flag = False
            self._thread = threading.Thread(
                target=self._serve_loop, daemon=True,
                name=f"vision-engine-{self.backend.name}")
            self._thread.start()
        return self

    def _serve_loop(self) -> None:
        while True:
            with self._cond:
                while not self._queue and not self._stop_flag:
                    self._cond.wait(timeout=0.05)
                if self._stop_flag and not self._queue:
                    return
            try:
                self.step()
            except Exception as e:   # noqa: BLE001 — any step fault kills serving
                with self._cond:
                    self._fault = e
                    now = time.perf_counter()
                    while self._queue:     # nothing will ever serve these
                        r = self._queue.popleft()
                        self._shed_locked(r.uid, "fault", r.t_submit, now,
                                          parent_span=r.parent_span,
                                          queued=True)
                    self._cond.notify_all()
                return

    def stop(self, drain: bool = True) -> None:
        """Stop the serving thread.  `drain=True` serves what's queued
        first; `drain=False` sheds it (reason "stopped").  No-op when no
        thread is running."""
        with self._cond:
            thread = self._thread
            self._stop_flag = True
            if not drain:
                now = time.perf_counter()
                while self._queue:
                    r = self._queue.popleft()
                    self._shed_locked(r.uid, "stopped", r.t_submit, now,
                                      parent_span=r.parent_span, queued=True)
            self._cond.notify_all()
        if thread is not None:
            thread.join(timeout=60.0)
            with self._cond:
                self._thread = None
                self._stop_flag = False

    @property
    def started(self) -> bool:
        return self._thread is not None

    @property
    def fault(self) -> BaseException | None:
        return self._fault

    def queue_depth(self) -> int:
        return len(self._queue)

    def load(self) -> int:
        """Queued + in-flight requests: the router's depth signal."""
        with self._cond:
            return len(self._queue) + self._in_flight

    # -- client loop --------------------------------------------------------

    def wait(self, uids: Iterable[int], timeout: float | None = None) -> None:
        """Block until every uid is resolved (served or shed).  With the
        serving thread running this waits on its completions; without it,
        serving is driven inline on the caller's thread."""
        uids = list(uids)

        def unresolved_locked():
            return [u for u in uids
                    if u not in self._results and u not in self._shed]

        if self._thread is None:
            while True:
                with self._cond:
                    missing = unresolved_locked()
                    if not missing:
                        return
                if self.step() == 0:
                    with self._cond:
                        missing = unresolved_locked()
                        if missing and not self._queue and not self._in_flight:
                            raise KeyError(
                                f"uids {missing[:4]} are not queued, served, "
                                "or shed — were their results already "
                                "popped by another caller?")
        t_end = None if timeout is None else time.perf_counter() + timeout
        with self._cond:
            while unresolved_locked():
                if self._fault is not None:
                    # the serving thread is dead and shed everything it
                    # knew about — what's still unresolved never will be
                    raise EngineFaultError(
                        f"serving thread died; {len(unresolved_locked())} "
                        "uids will never resolve") from self._fault
                remaining = (None if t_end is None
                             else t_end - time.perf_counter())
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"{len(unresolved_locked())} of {len(uids)} requests "
                        f"unresolved after {timeout}s")
                self._cond.wait(remaining if remaining is not None else 0.1)

    def pop_results(self, uids: Iterable[int] | None = None
                    ) -> dict[int, VisionResult]:
        """Hand over (and forget) completed results — the bounded-retention
        contract: a pipeline popping per wave keeps the engine's resident
        result set O(batch) over an unbounded stream.  `None` pops all."""
        with self._cond:
            if uids is None:
                out, self._results = self._results, {}
                return out
            return {u: self._results.pop(u) for u in list(uids)
                    if u in self._results}

    def pop_shed(self, uids: Iterable[int] | None = None) -> dict[int, str]:
        """Hand over (and forget) shed records (uid -> reason).  Aggregate
        per-reason counts in stats() are unaffected."""
        with self._cond:
            if uids is None:
                out, self._shed = self._shed, {}
                return out
            return {u: self._shed.pop(u) for u in list(uids)
                    if u in self._shed}

    def serve(self, images: Iterable[np.ndarray], *,
              deadline_ms: float | None = None, parent_span: Any = None
              ) -> list["VisionResult | None"]:
        """Convenience client loop: submit a workload, wait for it, pop the
        results, return them in submission order (None where a request was
        shed).  Works with or without the serving thread."""
        uids = self.submit_many(images, deadline_ms=deadline_ms,
                                parent_span=parent_span)
        self.wait(uids)
        res = self.pop_results(uids)
        self.pop_shed(uids)
        return [res.get(u) for u in uids]

    # -- reporting ----------------------------------------------------------

    def results(self) -> dict[int, VisionResult]:
        """Currently-retained (not yet popped) results."""
        with self._cond:
            return dict(self._results)

    def service_rate_qps(self) -> float | None:
        """Observed service rate: requests served per second of BUSY time
        (idle gaps excluded).  None before any serving history exists —
        the router's dispatch falls back to fleet statistics then."""
        with self._cond:
            if self._m_busy.value <= 0 or self._m_served.value == 0:
                return None
            return self._m_served.value / self._m_busy.value

    def seed_rate_qps(self) -> float | None:
        """Deterministic service-rate bound available BEFORE any serving
        history: the `min_step_s` floor admits at most one batch per floor
        period, so capacity is batch_size / min_step_s.  None when no floor
        is configured.  This is the router's cold-start dispatch signal —
        without it a cold fleet projects 0.0 wait for any backlog and the
        slo door never sheds (the cold-fleet SLO hole)."""
        if self.min_step_s > 0.0:
            return self.batch_size / self.min_step_s
        return None

    def stats(self) -> dict:
        """Per-request latency distribution + engine throughput + the
        admission ledger (submitted == served + shed + pending), read back
        from the registry instruments.  A broken ledger trips the flight
        recorder (when tracing is on) before it is reported."""
        with self._cond:
            submitted = self._m_submitted.value
            served = self._m_served.value
            shed_by = {r: c.value for r, c in sorted(self._m_shed.items())}
            shed_total = sum(shed_by.values())
            pending = len(self._queue) + self._in_flight
            batches = self._m_batches.value
            padded = self._m_padded.value
            busy = self._m_busy.value
            slots = batches * self.batch_size
            wall = ((self._t_last_done or 0.0)
                    - (self._t_first_submit or 0.0)) if served else 0.0
            accounted = submitted == served + shed_total + pending
            out = {
                "backend": self.backend.name,
                "n": served,
                "submitted": submitted,
                "shed": shed_total,
                "shed_by_reason": shed_by,
                "pending": pending,
                # the engine-level no-silent-loss invariant
                "accounted": accounted,
                "batch_size": self.batch_size,
                "batches": batches,
                "padded_slots": padded,
                # real images / total slots across every step: the fraction
                # of compute spent on real work vs zero padding (stream
                # benchmarks report this as pad waste)
                "batch_occupancy":
                    (slots - padded) / slots if slots else 0.0,
                "queue_hwm": int(self._m_queue.hwm),
                "mesh_devices": (int(self.mesh.devices.size)
                                 if self.mesh is not None else 1),
                # busy = sum of per-step serving windows; wall spans idle
                # gaps too, so throughput is reported over busy time (an
                # engine serving two bursts an hour apart still reports its
                # real service rate, not served/3600)
                "busy_s": busy,
                "wall_s": wall,
            }
            if self._deadline_total:
                out["deadline_total"] = self._deadline_total
                out["served_within_deadline"] = self._deadline_ok
                # goodput under the latency SLO: requests answered in time
                # over everything that asked (sheds count against it)
                out["goodput"] = self._deadline_ok / self._deadline_total
            if served:
                out.update(latency_stats(self._lat_hist.samples(), busy))
                # percentiles come from the bounded reservoir (recent
                # window), but throughput must count EVERY served request —
                # recompute it from the exact counters
                out["throughput_qps"] = served / busy if busy > 0 else 0.0
        if not accounted:
            tr = T.get()
            if tr is not None:
                tr.recorder.trip(
                    "ledger_invariant",
                    f"engine {self._id}: submitted={submitted} != "
                    f"served={served} + shed={shed_total} + "
                    f"pending={pending}")
        return out
