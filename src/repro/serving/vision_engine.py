"""Streaming vision serving engine: async single-image requests, batched steps.

The TPU analogue of the paper's deployment loop — there, pixels stream from
the PS over a DMA-FIFO into the fabric and classifications stream back; here,
single-image classification requests stream into a queue, the engine
coalesces them into FIXED-SIZE padded batches (one compiled program, no
recompilation churn — the FIFO depth is the batch size), runs one jitted
step of `smallnet.apply` on any registered backend, and streams per-request
results back with latency accounting.

Sibling of `serving/engine.py` (the LM continuous-batching engine); this one
is the image-classification half of the serving story.

Usage:

    eng = VisionEngine(params, backend="pallas", batch_size=32)
    uids = [eng.submit(img) for img in images]       # async: queue only
    eng.run()                                        # drain in batched steps
    res = eng.results()                              # uid -> VisionResult
    print(eng.stats())                               # latency + throughput
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backends as B
from repro.core import smallnet


@dataclasses.dataclass
class VisionRequest:
    uid: int
    image: np.ndarray                 # (28, 28, 1) float32
    t_submit: float = 0.0


@dataclasses.dataclass
class VisionResult:
    uid: int
    pred: int                         # Max Finder output
    scores: np.ndarray                # (10,) backend-native class scores
    t_submit: float
    t_done: float
    batch_index: int                  # which engine step served it

    @property
    def latency_s(self) -> float:
        """Queue wait + batch compute (what the client observes)."""
        return self.t_done - self.t_submit


class VisionEngine:
    """Batched streaming classifier over any registered smallNet backend.

    Requests submitted via `submit()` queue up; each `step()` pops up to
    `batch_size` of them, zero-pads to exactly `batch_size` (static shape ->
    a single XLA executable per engine), runs the jitted forward, and
    timestamps completions after `block_until_ready` so reported latency is
    honest wall clock.
    """

    def __init__(self, params: Any, *, backend: str | B.Backend = "ref",
                 batch_size: int = 32, image_shape=(28, 28, 1),
                 warmup: bool = True):
        self.backend = B.get_backend(backend)
        self.batch_size = int(batch_size)
        self.image_shape = tuple(image_shape)
        # quantize once at engine build (the paper bakes weights at synthesis)
        self.params = self.backend.prepare_params(params)
        be = self.backend
        self._step_fn = jax.jit(lambda p, x: smallnet.apply(p, x, backend=be))
        self._queue: collections.deque[VisionRequest] = collections.deque()
        self._results: dict[int, VisionResult] = {}
        self._next_uid = 0
        self._batches_run = 0
        self._padded_slots = 0
        self._t_first_submit: float | None = None
        self._t_last_done: float | None = None
        if warmup:                    # compile outside the serving clock
            zeros = jnp.zeros((self.batch_size,) + self.image_shape, jnp.float32)
            self._step_fn(self.params, zeros).block_until_ready()

    # -- request side -------------------------------------------------------

    def submit(self, image: np.ndarray) -> int:
        """Queue one image; returns its uid immediately (async)."""
        img = np.asarray(image, np.float32).reshape(self.image_shape)
        uid = self._next_uid
        self._next_uid += 1
        now = time.perf_counter()
        if self._t_first_submit is None:
            self._t_first_submit = now
        self._queue.append(VisionRequest(uid=uid, image=img, t_submit=now))
        return uid

    def submit_many(self, images: Iterable[np.ndarray]) -> list[int]:
        return [self.submit(img) for img in images]

    # -- serving side -------------------------------------------------------

    def step(self) -> int:
        """Serve one batch: coalesce up to batch_size queued requests, pad,
        run the jitted step, record results. Returns #requests served."""
        if not self._queue:
            return 0
        reqs = [self._queue.popleft()
                for _ in range(min(self.batch_size, len(self._queue)))]
        batch = np.zeros((self.batch_size,) + self.image_shape, np.float32)
        for i, r in enumerate(reqs):
            batch[i] = r.image
        scores = self._step_fn(self.params, jnp.asarray(batch))
        scores.block_until_ready()
        t_done = time.perf_counter()
        self._t_last_done = t_done
        preds = np.asarray(smallnet.predict(scores))
        scores_np = np.asarray(scores)
        for i, r in enumerate(reqs):
            self._results[r.uid] = VisionResult(
                uid=r.uid, pred=int(preds[i]), scores=scores_np[i],
                t_submit=r.t_submit, t_done=t_done,
                batch_index=self._batches_run)
        self._batches_run += 1
        self._padded_slots += self.batch_size - len(reqs)
        return len(reqs)

    def run(self) -> int:
        """Drain the queue; returns total #requests served."""
        served = 0
        while self._queue:
            served += self.step()
        return served

    def serve(self, images: Iterable[np.ndarray]) -> list[VisionResult]:
        """Convenience: submit a workload, drain it, return results in
        submission order."""
        uids = self.submit_many(images)
        self.run()
        return [self._results[u] for u in uids]

    # -- reporting ----------------------------------------------------------

    def results(self) -> dict[int, VisionResult]:
        return dict(self._results)

    def stats(self) -> dict:
        """Per-request latency distribution + engine throughput."""
        res = list(self._results.values())
        if not res:
            return {"backend": self.backend.name, "n": 0}
        lat = np.array([r.latency_s for r in res])
        wall = (self._t_last_done or 0.0) - (self._t_first_submit or 0.0)
        return {
            "backend": self.backend.name,
            "n": len(res),
            "batch_size": self.batch_size,
            "batches": self._batches_run,
            "padded_slots": self._padded_slots,
            "latency_mean_ms": float(lat.mean() * 1e3),
            "latency_p50_ms": float(np.percentile(lat, 50) * 1e3),
            "latency_p95_ms": float(np.percentile(lat, 95) * 1e3),
            "latency_max_ms": float(lat.max() * 1e3),
            "throughput_qps": float(len(res) / wall) if wall > 0 else float("inf"),
        }
