"""Streaming vision serving engine: async single-image requests, batched steps.

The TPU analogue of the paper's deployment loop — there, pixels stream from
the PS over a DMA-FIFO into the fabric and classifications stream back; here,
single-image classification requests stream into a queue, the engine
coalesces them into FIXED-SIZE padded batches (one compiled program, no
recompilation churn — the FIFO depth is the batch size), runs one jitted
step of `smallnet.apply` on any registered backend, and streams per-request
results back with latency accounting.

Pass a `jax.sharding.Mesh` and the jitted step shards the batch dim across
the mesh's data axes (the vision rules preset in `distributed/sharding.py`):
inputs/outputs carry a `NamedSharding`, the padded batch size is rounded up
to a multiple of the mesh batch axes, and on 1 device the whole thing
degenerates to the unsharded program — same engine code on a laptop CPU and
a pod slice.  For scaling across *separate* engines (distinct backends or
mesh slices) see `serving/router.py`.

Lifecycle: `submit()`/`step()` interleave freely; `run()` drains the queue
and CLOSES the intake — a submit after the drain raises `EngineDrainedError`
instead of silently queueing a request nothing will ever serve (the stats
window is also frozen at drain time).  `reopen()` explicitly re-arms the
engine for another serving wave (the replica router uses this to fail
requests over onto survivors).

Sibling of `serving/engine.py` (the LM continuous-batching engine); this one
is the image-classification half of the serving story.

Usage:

    eng = VisionEngine(params, backend="pallas", batch_size=32)
    uids = [eng.submit(img) for img in images]       # async: queue only
    eng.run()                                        # drain in batched steps
    res = eng.results()                              # uid -> VisionResult
    print(eng.stats())                               # latency + throughput
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Any, Iterable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import backends as B
from repro.core import smallnet
from repro.distributed import sharding as shd


def latency_stats(latencies_s, wall_s: float) -> dict:
    """The shared latency/throughput block of engine AND fleet stats():
    mean/p50/p95/max in ms + wall-clock qps over `wall_s` seconds."""
    lat = np.asarray(latencies_s)
    return {
        "latency_mean_ms": float(lat.mean() * 1e3),
        "latency_p50_ms": float(np.percentile(lat, 50) * 1e3),
        "latency_p95_ms": float(np.percentile(lat, 95) * 1e3),
        "latency_max_ms": float(lat.max() * 1e3),
        "throughput_qps": float(len(lat) / wall_s) if wall_s > 0 else float("inf"),
    }


class EngineDrainedError(RuntimeError):
    """submit() after run() has drained the queue: the serving wave is over
    and nothing would ever serve the request.  Call `reopen()` (or build a
    fresh engine) to start another wave."""


@dataclasses.dataclass
class VisionRequest:
    uid: int
    image: np.ndarray                 # (28, 28, 1) float32
    t_submit: float = 0.0


@dataclasses.dataclass
class VisionResult:
    uid: int
    pred: int                         # Max Finder output
    scores: np.ndarray                # (10,) backend-native class scores
    t_submit: float
    t_done: float
    batch_index: int                  # which engine step served it

    @property
    def latency_s(self) -> float:
        """Queue wait + batch compute (what the client observes)."""
        return self.t_done - self.t_submit


class VisionEngine:
    """Batched streaming classifier over any registered smallNet backend.

    Requests submitted via `submit()` queue up; each `step()` pops up to
    `batch_size` of them, zero-pads to exactly `batch_size` (static shape ->
    a single XLA executable per engine), runs the jitted forward, and
    timestamps completions after `block_until_ready` so reported latency is
    honest wall clock.

    With `mesh=` the step is traced under the vision sharding rules and the
    batch axis is split across the mesh (batch_size is rounded UP to the
    nearest multiple of the mesh batch axes so every device gets equal full
    shards).  The ambient mesh context is part of jax's jit cache key on
    the versions we support, so the engine re-enters it around every step.
    """

    def __init__(self, params: Any, *, backend: str | B.Backend = "ref",
                 batch_size: int = 32, image_shape=(28, 28, 1),
                 warmup: bool = True, mesh: Any = None):
        self.backend = B.get_backend(backend)
        self.image_shape = tuple(image_shape)
        self.mesh = mesh
        self.batch_size = int(batch_size)
        if mesh is not None:
            mult = shd.vision_batch_multiple(mesh)
            self.batch_size = -(-self.batch_size // mult) * mult  # ceil to mult
            self._rules = shd.make_vision_rules(mesh)
            batch_spec = self._rules["batch"]
            self._in_sharding = NamedSharding(
                mesh, P(batch_spec, *(None,) * len(self.image_shape)))
            self._out_sharding = NamedSharding(mesh, P(batch_spec, None))
        # quantize once at engine build (the paper bakes weights at synthesis)
        self.params = self.backend.prepare_params(params)
        self._step_fn = self._build_step()
        self._queue: collections.deque[VisionRequest] = collections.deque()
        self._results: dict[int, VisionResult] = {}
        self._next_uid = 0
        self._batches_run = 0
        self._padded_slots = 0
        self._drained = False
        self._t_first_submit: float | None = None
        self._t_last_done: float | None = None
        if warmup:                    # compile outside the serving clock
            zeros = jnp.zeros((self.batch_size,) + self.image_shape, jnp.float32)
            with self._mesh_ctx():
                self._step_fn(self.params, zeros).block_until_ready()

    def _mesh_ctx(self):
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    def _build_step(self):
        be = self.backend
        if self.mesh is None:
            return jax.jit(lambda p, x: smallnet.apply(p, x, backend=be))
        rules = self._rules

        def fwd(p, x):
            # the rules context is live during TRACE, which is when the
            # logical->physical constraint specs are resolved
            with shd.sharding_rules(rules):
                return smallnet.apply(p, x, backend=be)

        # params replicated (510 params ~ 2 KB; a pytree-prefix sharding
        # broadcasts to every leaf), batch split across the mesh data axes
        return jax.jit(fwd,
                       in_shardings=(NamedSharding(self.mesh, P()),
                                     self._in_sharding),
                       out_shardings=self._out_sharding)

    # -- request side -------------------------------------------------------

    def submit(self, image: np.ndarray) -> int:
        """Queue one image; returns its uid immediately (async)."""
        if self._drained:
            raise EngineDrainedError(
                f"VisionEngine(backend={self.backend.name!r}) has drained: "
                "run() already completed this serving wave, so this request "
                "would queue forever.  Call reopen() for another wave or "
                "build a fresh engine.")
        img = np.asarray(image, np.float32).reshape(self.image_shape)
        uid = self._next_uid
        self._next_uid += 1
        now = time.perf_counter()
        if self._t_first_submit is None:
            self._t_first_submit = now
        self._queue.append(VisionRequest(uid=uid, image=img, t_submit=now))
        return uid

    def submit_many(self, images: Iterable[np.ndarray]) -> list[int]:
        return [self.submit(img) for img in images]

    # -- serving side -------------------------------------------------------

    def step(self) -> int:
        """Serve one batch: coalesce up to batch_size queued requests, pad,
        run the jitted step, record results. Returns #requests served."""
        if not self._queue:
            return 0
        reqs = [self._queue.popleft()
                for _ in range(min(self.batch_size, len(self._queue)))]
        batch = np.zeros((self.batch_size,) + self.image_shape, np.float32)
        for i, r in enumerate(reqs):
            batch[i] = r.image
        with self._mesh_ctx():
            scores = self._step_fn(self.params, jnp.asarray(batch))
            scores.block_until_ready()
        t_done = time.perf_counter()
        self._t_last_done = t_done
        preds = np.asarray(smallnet.predict(scores))
        scores_np = np.asarray(scores)
        for i, r in enumerate(reqs):
            self._results[r.uid] = VisionResult(
                uid=r.uid, pred=int(preds[i]), scores=scores_np[i],
                t_submit=r.t_submit, t_done=t_done,
                batch_index=self._batches_run)
        self._batches_run += 1
        self._padded_slots += self.batch_size - len(reqs)
        return len(reqs)

    def run(self) -> int:
        """Drain the queue, then close the intake (see EngineDrainedError);
        returns total #requests served."""
        served = 0
        while self._queue:
            served += self.step()
        self._drained = True
        return served

    def reopen(self) -> None:
        """Re-arm a drained engine for another serving wave (results and
        stats accumulate across waves)."""
        self._drained = False

    @property
    def drained(self) -> bool:
        return self._drained

    def queue_depth(self) -> int:
        return len(self._queue)

    def serve(self, images: Iterable[np.ndarray]) -> list[VisionResult]:
        """Convenience: submit a workload, drain it, return results in
        submission order."""
        uids = self.submit_many(images)
        self.run()
        return [self._results[u] for u in uids]

    # -- reporting ----------------------------------------------------------

    def results(self) -> dict[int, VisionResult]:
        return dict(self._results)

    def stats(self) -> dict:
        """Per-request latency distribution + engine throughput."""
        res = list(self._results.values())
        if not res:
            return {"backend": self.backend.name, "n": 0}
        wall = (self._t_last_done or 0.0) - (self._t_first_submit or 0.0)
        slots = self._batches_run * self.batch_size
        return {
            "backend": self.backend.name,
            "n": len(res),
            "batch_size": self.batch_size,
            "batches": self._batches_run,
            "padded_slots": self._padded_slots,
            # real images / total slots across every step: the fraction of
            # compute spent on real work vs zero padding (stream benchmarks
            # report this as pad waste)
            "batch_occupancy": (slots - self._padded_slots) / slots if slots else 0.0,
            "mesh_devices": int(self.mesh.devices.size) if self.mesh is not None else 1,
            **latency_stats([r.latency_s for r in res], wall),
        }
