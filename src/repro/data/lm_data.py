"""Deterministic, host-sharded synthetic token pipeline.

Straggler/fault property (runtime/fault.py): batch content is a pure
function of (seed, step, host_index, n_hosts) — a replacement host
regenerates exactly the shard of the machine it replaces, and no data-server
state exists to lose.  The same construction works for a real corpus by
mapping (step, host) -> deterministic record ranges.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_index: int = 0


def host_batch(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    """This host's slice of the global batch for `step` (markov-ish tokens so
    the LM loss is learnable, not uniform noise)."""
    assert cfg.global_batch % cfg.n_hosts == 0
    b = cfg.global_batch // cfg.n_hosts
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_index]))
    # order-1 structure: next token = (prev * a + noise) % vocab
    a = 31
    x0 = rng.integers(0, cfg.vocab, size=(b, 1))
    noise = rng.integers(0, 17, size=(b, cfg.seq_len + 1))
    toks = np.empty((b, cfg.seq_len + 1), np.int64)
    toks[:, 0:1] = x0
    for t in range(1, cfg.seq_len + 1):
        toks[:, t] = (toks[:, t - 1] * a + noise[:, t]) % cfg.vocab
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}


def batches(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield host_batch(cfg, step)
        step += 1
