"""Procedural 28x28 grayscale digit dataset (offline MNIST proxy).

The container has no network access and no MNIST copy, so we render digits
procedurally: a 5x7 seven-segment-style glyph per class, upscaled to 20x20,
placed on a 28x28 canvas with random translation, per-stroke intensity
jitter, gaussian blur-ish smoothing and background noise.  The task is the
same 10-class grayscale 28x28 classification problem; EXPERIMENTS.md labels
every accuracy number as "MNIST-proxy".
"""
from __future__ import annotations

import numpy as np

_GLYPHS = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00110", "01000", "10000", "11111"],
    3: ["11110", "00001", "00001", "01110", "00001", "00001", "11110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}


def _glyph_array(d: int) -> np.ndarray:
    return np.array([[int(c) for c in row] for row in _GLYPHS[d]], np.float32)


def _smooth(img: np.ndarray) -> np.ndarray:
    """3x3 box blur (cheap anti-aliasing, makes strokes MNIST-soft)."""
    p = np.pad(img, 1)
    return (p[:-2, :-2] + p[:-2, 1:-1] + p[:-2, 2:] +
            p[1:-1, :-2] + p[1:-1, 1:-1] + p[1:-1, 2:] +
            p[2:, :-2] + p[2:, 1:-1] + p[2:, 2:]) / 9.0


def make_dataset(n: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Returns (images (n,28,28,1) float32 in [0,1], labels (n,) int32)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    imgs = np.zeros((n, 28, 28), np.float32)
    for i, d in enumerate(labels):
        g = _glyph_array(int(d))
        # upscale 5x7 -> 15x21/20x24 via per-axis kron (never crop the glyph)
        sy = rng.integers(3, 4)             # 3 rows/cell -> 21 px tall
        sx = rng.integers(3, 5)             # 3-4 cols/cell -> 15-20 px wide
        big = np.kron(g, np.ones((sy, sx), np.float32))
        h, w = big.shape
        big = big * rng.uniform(0.8, 1.0)   # intensity jitter
        dy = rng.integers(0, 28 - h + 1)
        dx = rng.integers(0, 28 - w + 1)
        canvas = np.zeros((28, 28), np.float32)
        canvas[dy:dy + h, dx:dx + w] = big
        canvas = _smooth(canvas)
        canvas += rng.normal(0, 0.03, (28, 28)).astype(np.float32)
        imgs[i] = np.clip(canvas, 0.0, 1.0)
    return imgs[..., None], labels


def batches(images: np.ndarray, labels: np.ndarray, batch_size: int,
            seed: int = 0, epochs: int = 1):
    """Deterministic shuffled minibatch iterator."""
    n = images.shape[0]
    rng = np.random.default_rng(seed)
    for _ in range(epochs):
        idx = rng.permutation(n)
        for s in range(0, n - batch_size + 1, batch_size):
            sel = idx[s:s + batch_size]
            yield images[sel], labels[sel]
