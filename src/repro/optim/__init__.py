from repro.optim.adam import (AdamConfig, adam_init, adam_update,
                              cosine_schedule, clip_by_global_norm)
