"""Hand-rolled Adam/AdamW (no optax in this container), pytree-generic.

Moment dtype is configurable: production configs for >=100B-param models use
bf16 moments to fit HBM (documented trade-off in DESIGN.md §5); smaller
models default to f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float | None = 1.0
    moment_dtype: Any = jnp.float32
    # Apply the update layer-by-layer (lax.map over the stacked-layer dim) for
    # rank>=3 leaves: bounds the f32 update temporaries to one layer's worth.
    # Off by default: while-loop outputs cannot alias donated input buffers,
    # which costs more than the temporaries save (measured on llama3-405b).
    layer_chunked: bool = False


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adam_init(params: Any, cfg: AdamConfig = AdamConfig()) -> AdamState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return AdamState(step=jnp.zeros((), jnp.int32),
                     mu=jax.tree_util.tree_map(zeros, params),
                     nu=jax.tree_util.tree_map(zeros, params))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jnp.ndarray]:
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), grads), gn


def adam_update(grads: Any, state: AdamState, params: Any,
                cfg: AdamConfig = AdamConfig(),
                lr: jnp.ndarray | float | None = None):
    """Returns (new_params, new_state, metrics)."""
    def _sumsq(g):
        # layer-stacked leaves reduce slice-by-slice: keeps the f32 upcast
        # at one layer's footprint instead of the whole 126-layer stack
        if g.ndim >= 3 and g.shape[0] > 1:
            return jax.lax.fori_loop(
                0, g.shape[0],
                lambda i, acc: acc + jnp.sum(jnp.square(g[i].astype(jnp.float32))),
                jnp.zeros((), jnp.float32))
        return jnp.sum(jnp.square(g.astype(jnp.float32)))

    if cfg.clip_norm is not None:
        # fold the clip scale into the update (never materialize a scaled
        # copy of the full gradient tree)
        gnorm = jnp.sqrt(sum(_sumsq(g) for g in jax.tree_util.tree_leaves(grads)))
        gscale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    else:
        gnorm = jnp.zeros(())
        gscale = jnp.ones(())
    step = state.step + 1
    lr_t = cfg.lr if lr is None else lr
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * gscale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        update = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        if cfg.weight_decay:
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr_t * update
        return newp.astype(p.dtype), m32.astype(cfg.moment_dtype), v32.astype(cfg.moment_dtype)

    def upd_leaf(p, g, m, v):
        if cfg.layer_chunked and p.ndim >= 3 and p.shape[0] > 1:
            return jax.lax.map(lambda a: upd(*a), (p, g, m, v))
        return upd(p, g, m, v)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    new = [upd_leaf(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [t[0] for t in new])
    new_m = jax.tree_util.tree_unflatten(tdef, [t[1] for t in new])
    new_v = jax.tree_util.tree_unflatten(tdef, [t[2] for t in new])
    return new_p, AdamState(step, new_m, new_v), {"grad_norm": gnorm}


def cosine_schedule(base_lr: float, warmup_steps: int, total_steps: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup_steps)
        t = jnp.clip((step - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr
