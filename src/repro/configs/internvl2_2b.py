"""internvl2-2b [vlm] — InternViT (stub) + InternLM2 backbone [arXiv:2404.16821; hf].
input_specs() supplies precomputed patch embeddings (B, 256, vit_dim=1024),
projected into the first 256 token positions."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92553, head_dim=128,
    rope_theta=1000000.0, norm="rmsnorm", mlp="gated",
    vision_tokens=256, vit_dim=1024,
    micro_batch=128,
    source="arXiv:2404.16821",
)
