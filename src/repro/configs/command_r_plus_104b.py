"""command-r-plus-104b [dense] — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from repro.configs.base import ArchConfig
import jax.numpy as jnp

CONFIG = ArchConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=33792, vocab=256000, head_dim=128,
    qkv_bias=False, rope_theta=75000000.0, norm="layernorm", mlp="gated",
    param_dtype=jnp.bfloat16, micro_batch=32,
    source="hf:CohereForAI/c4ai-command-r-v01",
)
