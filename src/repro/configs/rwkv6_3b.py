"""rwkv6-3b [ssm] — Finch, data-dependent decay, attn-free [arXiv:2404.05892; hf].
head_dim fixed at 64 (RWKV convention) -> 40 heads; runs long_500k."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab=65536, head_dim=64,
    use_rope=False, norm="layernorm", mlp="vanilla",
    micro_batch=64,
    source="arXiv:2404.05892",
)
