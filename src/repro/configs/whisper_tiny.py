"""whisper-tiny [audio] — enc-dec, conv frontend STUB [arXiv:2212.04356; unverified].
4L = 4 encoder + 4 decoder (whisper-tiny). input_specs() supplies precomputed
frame embeddings (B, 1500, d_model); seq shapes apply to the decoder."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865, head_dim=64,
    use_rope=False, norm="layernorm", mlp="vanilla",
    encoder_layers=4, encoder_frames=1500,
    micro_batch=256,
    source="arXiv:2212.04356",
)
