"""smallNet — the paper's own architecture (28x28x1 MNIST, 510 params)."""
SMALLNET = dict(
    input_shape=(28, 28, 1), n_classes=10,
    conv_filters=(1, 1), kernel=(2, 2), pool=2,
    params=510, weight_bytes=2040,
    source="smallNet paper §III-A",
)
