"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7, MoE 16e top-2 [arXiv:2403.19887; hf].
72 layers = 9 super-blocks x 8 sublayers; attention at sublayer 3 of each
super-block; MoE MLP on every 2nd sublayer. Runs long_500k (states + KV only
in 9 attention layers)."""
from repro.configs.base import ArchConfig
import jax.numpy as jnp

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab=65536, head_dim=128,
    n_experts=16, top_k=2, moe_every=2,
    attn_period=8, attn_offset=3,
    use_rope=False, norm="rmsnorm", mlp="gated",
    param_dtype=jnp.bfloat16, micro_batch=16,
    source="arXiv:2403.19887",
)
