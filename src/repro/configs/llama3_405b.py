"""llama3-405b [dense] — GQA, 128k vocab [arXiv:2407.21783; unverified]."""
from repro.configs.base import ArchConfig
import jax.numpy as jnp

CONFIG = ArchConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
    d_ff=53248, vocab=128256, head_dim=128,
    rope_theta=500000.0, norm="rmsnorm", mlp="gated",
    param_dtype=jnp.bfloat16,          # HBM fit: bf16 params+moments >=100B (DESIGN.md §5)
    micro_batch=32,
    source="arXiv:2407.21783",
)
