"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].
d_ff=1536 is the per-expert FFN width."""
from repro.configs.base import ArchConfig
import jax.numpy as jnp

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab=151936, head_dim=64,
    n_experts=128, top_k=8,
    rope_theta=1000000.0, norm="rmsnorm", mlp="gated",
    param_dtype=jnp.bfloat16, micro_batch=32,
    source="hf:Qwen/Qwen3-30B-A3B",
)
