"""ArchConfig + the assigned input-shape sets + the config registry."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any

import jax.numpy as jnp


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1           # jamba: MoE MLP on every 2nd sublayer
    # attention
    qkv_bias: bool = False
    use_rope: bool = True
    rope_theta: float = 500000.0
    q_chunk: int = 512
    # layer kinds
    norm: str = "rmsnorm"
    mlp: str = "gated"
    tie_embeddings: bool = False
    # hybrid (jamba): one attention sublayer per `attn_period` sublayers
    attn_period: int = 0
    attn_offset: int = 3
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_frames: int = 0
    # vlm
    vision_tokens: int = 0
    vit_dim: int = 0
    # numerics / execution
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    micro_batch: int = 64        # per-train-step microbatch size (global)
    remat: bool = True
    # provenance
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return _round_up(self.vocab, 256)

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 8 if self.family == "hybrid" else 2),
            d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            d_ff=128, vocab=512,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_frames=min(self.encoder_frames, 16) if self.encoder_frames else 0,
            vision_tokens=min(self.vision_tokens, 8) if self.vision_tokens else 0,
            vit_dim=min(self.vit_dim, 32) if self.vit_dim else 0,
            q_chunk=16, micro_batch=4,
            dtype=jnp.float32, param_dtype=jnp.float32,
        )

    def supports_long_context(self) -> bool:
        """Sub-quadratic archs only (ssm / hybrid) — DESIGN.md §4 skip rule."""
        return self.family in ("ssm", "hybrid")


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "llama3-405b", "granite-3-2b", "command-r-plus-104b", "qwen2.5-14b",
    "rwkv6-3b", "qwen3-moe-235b-a22b", "moonshot-v1-16b-a3b",
    "whisper-tiny", "internvl2-2b", "jamba-1.5-large-398b",
]


def list_archs() -> list[str]:
    return list(ARCH_IDS)


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_"))
    return mod.CONFIG


def cells(include_smallnet: bool = False):
    """Every (arch, shape) cell per the assignment (with documented skips)."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            if s.name == "long_500k" and not cfg.supports_long_context():
                continue
            out.append((a, s.name))
    return out
