"""qwen2.5-14b [dense] — GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B; hf].
40 heads % 16 != 0 -> attention core falls back to dim-sharded TP (DESIGN.md §4)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=13824, vocab=152064, head_dim=128,
    qkv_bias=True, rope_theta=1000000.0, norm="rmsnorm", mlp="gated",
    micro_batch=64,
    source="hf:Qwen/Qwen2.5-0.5B",
)
