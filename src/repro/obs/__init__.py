"""Runtime observability: spans, metrics, and the flight recorder.

Three small, dependency-free layers the whole serving stack threads
through:

  obs.metrics   process-wide registry of counters / gauges / fixed-bucket
                histograms (bounded memory by construction) + the ONE
                nearest-rank percentile helper every latency summary in
                the repo routes through, and a Prometheus text exporter.
  obs.trace     lightweight spans (monotonic clock, parent ids, frame /
                request trace ids, tags).  Disabled by default: every
                instrumentation site costs one `trace.get()` + None check
                until `trace.enable()` flips it on.
  obs.recorder  a bounded ring of recently finished spans that dumps
                itself (JSONL) when tripped — SLO violation, ledger
                invariant failure — plus the span/ledger reconciliation
                check the CI trace smoke gates on.

See README "Observability" for the span taxonomy and artifact formats.
"""
from repro.obs import metrics, recorder, trace  # noqa: F401
