"""Lightweight runtime spans: the per-frame waterfall of the serving stack.

A `Span` is one timed region on the monotonic clock (`perf_counter` — the
same clock every latency number in the repo is measured on, so span
durations and stats() latencies are directly comparable): name, trace id
(which frame / request it belongs to), span id + parent id (nesting),
tags, and a TERMINAL STATUS.  The status convention is the contract the
CI trace smoke reconciles against the serving ledgers:

  root spans ("frame", "request") end in exactly one terminal state —
  "served", "dropped:<stage>/<reason>", or "shed:<reason>" — matching the
  component's own accounting (pipeline `frames_in == served + dropped`,
  engine `submitted == served + shed + pending`).  Interior spans
  ("tile", "infer", "queue_wait", "device_step", ...) end "ok" unless the
  work they cover failed.

Tracing is OFF by default and costs one `trace.get()` (a module attribute
read) + None check per instrumentation site until `trace.enable()` turns
it on; enabling installs a process-wide `Tracer` whose finished spans land
in a bounded `recorder.FlightRecorder` ring.  The `--trace` flag on
`stream_table` / `goodput_table` / `stream_demo` is a thin wrapper around
`enable()` + a JSONL dump of the ring.

The opt-in jax.profiler bridge (`profile_device_steps()`) annotates every
engine device step with a `jax.profiler.TraceAnnotation`, so a real-device
profile (XProf/TensorBoard) shows the same step boundaries the spans do.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import time
from typing import TYPE_CHECKING

if TYPE_CHECKING:                                      # pragma: no cover
    from repro.obs.recorder import FlightRecorder


@dataclasses.dataclass(slots=True)
class Span:
    """One timed region.  `t_start`/`t_end` are perf_counter seconds;
    `status` is "open" until ended.  Slotted: span construction sits on
    the traced hot path (two spans per engine request), and the dict-free
    layout is worth ~0.5 µs per span there."""
    name: str
    trace_id: str
    span_id: int
    parent_id: int | None
    t_start: float
    t_end: float | None = None
    status: str = "open"
    tags: dict = dataclasses.field(default_factory=dict)

    @property
    def duration_s(self) -> float | None:
        return None if self.t_end is None else self.t_end - self.t_start

    @property
    def terminal(self) -> bool:
        """True for a span that records a request's FATE (the states the
        ledger reconciliation counts), not just a timed region."""
        return (self.status == "served" or self.status.startswith("shed:")
                or self.status.startswith("dropped:"))

    def to_dict(self) -> dict:
        d = {"name": self.name, "trace_id": self.trace_id,
             "span_id": self.span_id, "parent_id": self.parent_id,
             "t_start": self.t_start, "t_end": self.t_end,
             "status": self.status}
        if self.tags:
            d["tags"] = self.tags
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Span":
        return cls(name=d["name"], trace_id=d["trace_id"],
                   span_id=d["span_id"], parent_id=d.get("parent_id"),
                   t_start=d["t_start"], t_end=d.get("t_end"),
                   status=d.get("status", "open"),
                   tags=d.get("tags", {}))


class Tracer:
    """Hands out spans and pushes finished ones to the flight recorder.
    Span ids are process-unique (an itertools counter — thread-safe under
    the GIL for the single `next()` bytecode); starting/ending a span
    never blocks on anything but the recorder ring append."""

    def __init__(self, recorder: "FlightRecorder"):
        self.recorder = recorder
        self._ids = itertools.count(1)
        self._record = recorder.record        # bound once: end() hot path

    def start(self, name: str, trace_id: str, *,
              parent: Span | None = None, **tags) -> Span:
        return Span(name=name, trace_id=trace_id,
                    span_id=next(self._ids),
                    parent_id=parent.span_id if parent is not None else None,
                    t_start=time.perf_counter(), tags=tags)

    def end(self, span: Span, status: str = "ok", **tags) -> Span:
        if span.t_end is not None:
            raise RuntimeError(f"span {span.name}#{span.span_id} already "
                               f"ended ({span.status!r})")
        span.t_end = time.perf_counter()
        span.status = status
        if tags:
            span.tags.update(tags)
        self._record(span)
        return span

    def emit(self, name: str, trace_id: str, t_start: float, t_end: float,
             status: str = "ok", *, parent: Span | None = None,
             **tags) -> Span:
        """Materialize an already-finished span from timestamps recorded
        elsewhere: one allocation + one ring append, no clock reads.  The
        engine's per-request spans use this — the request path records
        plain floats (t_submit, batch formation, step completion) and the
        spans are built once, at batch completion, OFF the submit critical
        path."""
        # manual slot assignment instead of the dataclass __init__: this
        # runs twice per engine request and the generated __init__'s call
        # overhead is measurable there (~0.7 us/span)
        s = object.__new__(Span)
        s.name = name
        s.trace_id = trace_id
        s.span_id = next(self._ids)
        s.parent_id = parent.span_id if parent is not None else None
        s.t_start = t_start
        s.t_end = t_end
        s.status = status
        s.tags = tags
        self._record(s)
        return s

    def end_at(self, span: Span, t: float, status: str = "ok") -> Span:
        """Fast-path end with a pre-read clock value: hot loops (the engine
        ending a whole batch's request spans at one step boundary) pay one
        perf_counter read and no tag kwargs for the lot.  Tags can be set
        directly on `span.tags` before the call."""
        if span.t_end is not None:
            raise RuntimeError(f"span {span.name}#{span.span_id} already "
                               f"ended ({span.status!r})")
        span.t_end = t
        span.status = status
        self._record(span)
        return span

    def point(self, name: str, trace_id: str, status: str = "ok", *,
              parent: Span | None = None, **tags) -> Span:
        """A zero-duration event span (a dispatch decision, an
        at-the-door shed): started and ended at the same instant."""
        return self.end(self.start(name, trace_id, parent=parent, **tags),
                        status)

    @contextlib.contextmanager
    def span(self, name: str, trace_id: str, *,
             parent: Span | None = None, **tags):
        s = self.start(name, trace_id, parent=parent, **tags)
        try:
            yield s
        except BaseException:
            self.end(s, "error")
            raise
        self.end(s)


# -- the process-wide switch --------------------------------------------------

_TRACER: Tracer | None = None


def enable(capacity: int = 65536, *,
           dump_dir: str | None = None) -> Tracer:
    """Install (or replace) the process-wide tracer over a fresh bounded
    flight-recorder ring.  Returns the tracer (its `.recorder` is where
    dumps come from).  Idempotent in effect — calling again starts a new
    ring."""
    from repro.obs.recorder import FlightRecorder
    global _TRACER
    _TRACER = Tracer(FlightRecorder(capacity=capacity, dump_dir=dump_dir))
    return _TRACER


def disable() -> None:
    global _TRACER
    _TRACER = None


def get() -> Tracer | None:
    """The process-wide tracer, or None when tracing is off.  Every
    instrumentation site is `tr = trace.get()` + `if tr is not None` — the
    whole cost of the subsystem when disabled."""
    return _TRACER


# -- jax.profiler bridge ------------------------------------------------------

_PROFILE_STEPS = False


def profile_device_steps(on: bool = True) -> None:
    """Opt in to wrapping every engine device step in a
    `jax.profiler.TraceAnnotation` so spans and XProf timelines line up.
    Off by default: annotations cost a TraceMe even without a live
    profiler session."""
    global _PROFILE_STEPS
    _PROFILE_STEPS = bool(on)


def device_step_annotation(name: str):
    """Context manager for the engine's jitted step: a profiler
    annotation when `profile_device_steps()` is on, a nullcontext
    otherwise (and a nullcontext if this jax build lacks the API)."""
    if _PROFILE_STEPS:
        try:
            from jax.profiler import TraceAnnotation
            return TraceAnnotation(name)
        except ImportError:                            # pragma: no cover
            pass
    return contextlib.nullcontext()
