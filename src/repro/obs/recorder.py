"""Flight recorder: a bounded ring of recent spans + crash-dump triggers.

The recorder is the retention policy of the tracing layer: finished spans
land in a `deque(maxlen=capacity)` — a year of serving retains exactly as
many spans as the last `capacity` finished ones — and the ring dumps
itself to JSONL when something goes wrong:

  trip("slo_violation", ...)      a frame blew its deadline
  trip("ledger_invariant", ...)   an accounting identity broke
                                  (submitted != served + shed + pending)

Trips are rate-limited per reason (`trip_limit` dumps each; the first
failures are the diagnosable ones, the ten-thousandth is noise) and write
`flight_<reason>_<n>.jsonl` under `dump_dir` (default: cwd).  `stream_table
--trace` also dumps the ring unconditionally at end of run — the committed
observability artifact next to `BENCH_<pr>.json`.

Dump format: one span per line (see `trace.Span.to_dict`), sorted by
`t_start`, preceded by one header line `{"flight_recorder": {...}}` with
the dump reason/detail/capacity.  `load_jsonl` round-trips it.

`reconcile()` is the span/ledger cross-check the CI trace smoke gates on:
every root span ends in exactly ONE terminal state, terminal counts equal
the component ledger's served/dropped/shed counters, and clocks are sane
(end >= start, children nested inside their parent's window).
"""
from __future__ import annotations

import collections
import json
import os
import threading

from repro.obs.trace import Span


class FlightRecorder:
    """Bounded span ring + rate-limited auto-dump."""

    def __init__(self, capacity: int = 65536, *,
                 dump_dir: str | None = None, trip_limit: int = 3):
        self.capacity = int(capacity)
        self.dump_dir = dump_dir
        self.trip_limit = int(trip_limit)
        self._ring: collections.deque[Span] = collections.deque(
            maxlen=self.capacity)
        self._lock = threading.Lock()
        self._trips: dict[str, int] = {}
        self.dumps: list[str] = []            # paths written by trips/dumps
        self._recorded = 0                    # total spans ever recorded

    def record(self, span: Span) -> None:
        # Lock-free hot path: a bounded deque append is thread-safe under
        # the GIL, and the eviction count is DERIVED (recorded - len) in
        # the `evicted` property instead of tracked here, so the serving
        # threads never contend on a lock per finished span.
        self._recorded += 1
        self._ring.append(span)

    def spans(self) -> list[Span]:
        """Snapshot of the ring, oldest first."""
        with self._lock:
            return list(self._ring)

    def __len__(self) -> int:
        return len(self._ring)

    @property
    def evicted(self) -> int:
        """Spans pushed out by the capacity bound (0 means the ring still
        holds the whole run — reconciliation is only meaningful then)."""
        return max(0, self._recorded - len(self._ring))

    # -- dumping ------------------------------------------------------------

    def dump_jsonl(self, path: str, *, reason: str = "manual",
                   detail: str = "") -> str:
        """Write the ring to `path`: a header line, then one span per
        line sorted by start time."""
        spans = sorted(self.spans(), key=lambda s: s.t_start)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(json.dumps({"flight_recorder": {
                "reason": reason, "detail": detail, "n_spans": len(spans),
                "capacity": self.capacity, "evicted": self.evicted}}) + "\n")
            for s in spans:
                f.write(json.dumps(s.to_dict()) + "\n")
        self.dumps.append(path)
        return path

    def trip(self, reason: str, detail: str = "") -> str | None:
        """Auto-dump on a fault condition.  Rate-limited: only the first
        `trip_limit` trips per reason write a file; later ones are counted
        but silent.  Returns the path written, or None when suppressed."""
        with self._lock:
            n = self._trips.get(reason, 0)
            self._trips[reason] = n + 1
            if n >= self.trip_limit:
                return None
        d = self.dump_dir or "."
        path = os.path.join(d, f"flight_{reason}_{n}.jsonl")
        return self.dump_jsonl(path, reason=reason, detail=detail)

    def trip_counts(self) -> dict[str, int]:
        with self._lock:
            return dict(self._trips)


def load_jsonl(path: str) -> tuple[dict, list[Span]]:
    """Read a dump back: (header dict, spans)."""
    with open(path) as f:
        lines = [json.loads(ln) for ln in f if ln.strip()]
    if not lines or "flight_recorder" not in lines[0]:
        raise ValueError(f"{path}: not a flight-recorder dump "
                         "(missing header line)")
    return lines[0]["flight_recorder"], [Span.from_dict(d) for d in lines[1:]]


def dump_prometheus(path: str, registry=None) -> str:
    """Write the registry's Prometheus text exposition next to the trace
    dump (the other half of the `--trace` artifact pair)."""
    from repro.obs import metrics
    reg = registry if registry is not None else metrics.REGISTRY
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        f.write(reg.to_prometheus())
    return path


# -- span/ledger reconciliation ----------------------------------------------

ROOT_NAMES = ("frame", "request")


def reconcile(spans: list[Span], *, frames_served: int | None = None,
              frames_dropped: int | None = None,
              served: int | None = None, shed: int | None = None,
              root_name: str = "frame") -> list[str]:
    """Cross-check a span set against a component ledger.  Returns a list
    of human-readable failures (empty == reconciled).

    Checks, in order:
      1. every `root_name` span ended in a terminal state, and every
         trace_id carries exactly ONE such root (no double-fates),
      2. terminal counts match the ledger: #served roots == frames_served
         (or `served`), #dropped+#shed roots == frames_dropped (or `shed`),
      3. clock sanity: every ended span has t_end >= t_start, and every
         child lies inside its parent's [t_start, t_end] window (1 µs
         grace for clock-read ordering at the boundaries).
    """
    failures: list[str] = []
    by_id = {s.span_id: s for s in spans}
    roots = [s for s in spans if s.name == root_name]

    # 1. one terminal root per trace.  The uniqueness check applies only to
    # true trace roots (parent_id is None): request spans nested under a
    # frame legitimately share the frame's trace_id, one per tile wave.
    seen: dict[str, Span] = {}
    for r in roots:
        if not r.terminal:
            failures.append(f"root span {r.trace_id} ended non-terminally: "
                            f"{r.status!r}")
        if r.parent_id is not None:
            continue
        prev = seen.get(r.trace_id)
        if prev is not None:
            failures.append(f"trace {r.trace_id} has more than one root "
                            f"span ({prev.status!r} and {r.status!r})")
        seen[r.trace_id] = r

    # 2. ledger counts
    n_served = sum(1 for r in roots if r.status == "served")
    n_lost = sum(1 for r in roots if r.status.startswith("dropped:")
                 or r.status.startswith("shed:"))
    want_served = frames_served if frames_served is not None else served
    want_lost = frames_dropped if frames_dropped is not None else shed
    if want_served is not None and n_served != want_served:
        failures.append(f"{n_served} served root spans != ledger "
                        f"served={want_served}")
    if want_lost is not None and n_lost != want_lost:
        failures.append(f"{n_lost} dropped/shed root spans != ledger "
                        f"dropped+shed={want_lost}")

    # 3. clock sanity + nesting
    grace = 1e-6
    for s in spans:
        if s.t_end is None:
            if s.name == root_name:
                failures.append(f"root span {s.trace_id} never ended")
            continue
        if s.t_end < s.t_start:
            failures.append(f"span {s.name}#{s.span_id} runs backwards: "
                            f"{s.t_start} -> {s.t_end}")
        p = by_id.get(s.parent_id) if s.parent_id is not None else None
        if p is not None and p.t_end is not None:
            if (s.t_start < p.t_start - grace
                    or s.t_end > p.t_end + grace):
                failures.append(
                    f"span {s.name}#{s.span_id} escapes its parent "
                    f"{p.name}#{p.span_id}'s window")
    return failures


def waterfall(spans: list[Span], trace_id: str, *, width: int = 48,
              max_spans: int | None = None) -> str:
    """Render one trace as an ASCII waterfall (the stream_demo view):
    each span a bar positioned on the trace's own clock.  `max_spans`
    truncates busy traces (a frame fans out into dozens of request spans)
    with an explicit "+N more" line."""
    ts = [s for s in spans if s.trace_id == trace_id and s.t_end is not None]
    if not ts:
        return f"(no spans for trace {trace_id})"
    ts.sort(key=lambda s: (s.t_start, s.span_id))
    hidden = 0
    if max_spans is not None and len(ts) > max_spans:
        hidden = len(ts) - max_spans
        ts = ts[:max_spans]
    t0 = min(s.t_start for s in ts)
    t1 = max(s.t_end for s in ts)
    total = max(t1 - t0, 1e-9)
    depth = {}
    for s in ts:
        depth[s.span_id] = (depth.get(s.parent_id, -1) + 1
                            if s.parent_id in depth or s.parent_id is None
                            else 1)
    lines = [f"trace {trace_id}  ({total * 1e3:.1f} ms total)"]
    for s in ts:
        a = int((s.t_start - t0) / total * width)
        b = max(a + 1, int((s.t_end - t0) / total * width))
        bar = " " * a + "#" * (b - a) + " " * (width - b)
        label = "  " * depth.get(s.span_id, 0) + s.name
        lines.append(f"  {label:<22s} |{bar}| {s.duration_s * 1e3:7.2f} ms"
                     f"  {s.status}")
    if hidden:
        lines.append(f"  ... (+{hidden} more spans)")
    return "\n".join(lines)
