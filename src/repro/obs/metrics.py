"""Low-overhead metrics: counters, gauges, bounded histograms, one registry.

The serving stack used to keep ad-hoc python lists for every latency
distribution (`StreamingPipeline._stage_s`, `VisionEngine._latencies`,
`ReplicaRouter._latencies`) — each grows per event forever, the same
unbounded-retention class of bug PR 7 fixed for engine results.  This
module replaces them:

  Counter     monotonic value (int or float increments).
  Gauge       last-set value + high-water mark (queue depths).
  Histogram   fixed bucket ladder (Prometheus-style cumulative `le`
              counts) + exact count/sum/min/max + a BOUNDED reservoir of
              the most recent `reservoir` raw samples for percentile
              reporting.  Memory is O(buckets + reservoir) regardless of
              how many observations arrive; for runs shorter than the
              reservoir the reported percentiles are exact.

  Registry    process-wide get-or-create by (name, labels); the default
              `REGISTRY` is what the benchmarks' `--trace` Prometheus dump
              exports.  Components label their instruments with a unique
              instance label so fleets of engines coexist in one registry.

Percentile convention (the ONE shared helper): `percentile(xs, q)` is
NEAREST-RANK — the smallest sample whose cumulative fraction reaches q% —
so a reported p99 is always a sample that actually occurred, never an
interpolated value between two (np.percentile's default linear
interpolation invents latencies nobody measured, and did so differently
in the engine vs the pipeline).  `serving/vision_engine.latency_stats`,
the pipeline stage summaries, and the benchmark tables all route through
it.

Thread model: instrument mutation is a single `+=` / `append` under the
GIL and every serving-stack caller already holds its component lock at
the call site; the exporter takes per-instrument snapshots, so a dump
concurrent with serving sees a consistent (if momentarily stale) view.
"""
from __future__ import annotations

import itertools
import math
import threading
from typing import Iterable, Sequence

from collections import deque

# default reservoir: exact percentiles for every CI-sized run, O(16 KB)
# per histogram at the cap no matter how long the stream runs
RESERVOIR = 2048

# default bucket ladder (seconds): 0.5 ms .. 10 s, roughly x2.5 per rung —
# spans engine step times on a laptop CPU through interpret-mode megakernel
# frames; +inf is implicit
LATENCY_BUCKETS_S = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                     0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile: the smallest observed sample whose
    cumulative fraction reaches q% (ceil(q/100 * n), 1-indexed).  On tiny
    samples this is deliberately pessimistic-honest: percentile([a], 99)
    is a, percentile([1, 2, 3, 4], 50) is 2 — a value that happened, not
    an interpolation.  Raises on an empty sample set (an all-shed window
    has no distribution; callers guard n == 0 explicitly)."""
    n = len(xs)
    if n == 0:
        raise ValueError("percentile: empty sample set")
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile: q={q} outside [0, 100]")
    s = sorted(xs)
    k = max(1, math.ceil(q / 100.0 * n))
    return float(s[min(k, n) - 1])


class Counter:
    """Monotonic counter (int or float increments — busy-seconds are a
    float counter).  `inc()` must never be called with a negative delta."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, n=1) -> None:
        if n < 0:
            raise ValueError(f"Counter {self.name}: negative increment {n}")
        self.value += n


class Gauge:
    """Last-set value + high-water mark (`hwm`) — queue depths, batch
    occupancy.  `set()` keeps the mark; `reset_hwm()` re-arms it."""

    __slots__ = ("name", "labels", "value", "hwm")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self.value = 0.0
        self.hwm = 0.0

    def set(self, v: float) -> None:
        self.value = v
        if v > self.hwm:
            self.hwm = v

    def reset_hwm(self) -> None:
        self.hwm = self.value


class Histogram:
    """Fixed-bucket histogram + bounded sample reservoir.

    `observe(x)` is O(log buckets); memory is bounded by construction —
    the cumulative bucket counts never grow and the reservoir holds only
    the most recent `reservoir` samples (a deque maxlen, so a year-long
    stream retains exactly as much as a minute-long one).  Percentiles
    come from the reservoir via the shared nearest-rank `percentile()`:
    exact when the stream fits the reservoir, recent-window otherwise
    (which is what a flight recorder wants anyway).
    """

    __slots__ = ("name", "labels", "buckets", "bucket_counts", "count",
                 "sum", "min", "max", "_samples")

    def __init__(self, name: str, labels: dict,
                 buckets: Sequence[float] = LATENCY_BUCKETS_S,
                 reservoir: int = RESERVOIR):
        if list(buckets) != sorted(buckets) or len(set(buckets)) != len(buckets):
            raise ValueError(f"Histogram {name}: buckets must be strictly "
                             f"increasing, got {buckets}")
        self.name = name
        self.labels = labels
        self.buckets = tuple(float(b) for b in buckets)
        self.bucket_counts = [0] * (len(self.buckets) + 1)   # +inf last
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._samples: deque[float] = deque(maxlen=int(reservoir))

    def observe(self, x: float) -> None:
        x = float(x)
        lo, hi = 0, len(self.buckets)
        while lo < hi:                       # first bucket with le >= x
            mid = (lo + hi) // 2
            if x <= self.buckets[mid]:
                hi = mid
            else:
                lo = mid + 1
        self.bucket_counts[lo] += 1
        self.count += 1
        self.sum += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x
        self._samples.append(x)

    def samples(self) -> list[float]:
        """The bounded reservoir (most recent observations), as a list."""
        return list(self._samples)

    def percentile(self, q: float) -> float:
        return percentile(self._samples, q)

    def summary_ms(self) -> dict:
        """The pipeline's per-stage distribution block: n / mean / p50 /
        p99 / max in milliseconds.  n and mean/max are EXACT over the whole
        stream (O(1) accumulators); percentiles are over the reservoir."""
        if self.count == 0:
            return {"n": 0}
        return {"n": self.count,
                "mean_ms": self.sum / self.count * 1e3,
                "p50_ms": self.percentile(50) * 1e3,
                "p99_ms": self.percentile(99) * 1e3,
                "max_ms": self.max * 1e3}


class Registry:
    """Get-or-create instrument store keyed by (name, sorted labels).
    Re-requesting an existing key returns the SAME instrument (a metric is
    process state, not call state); requesting it as a different type
    raises.  `to_prometheus()` renders the whole registry in the text
    exposition format."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict[tuple, object] = {}

    @staticmethod
    def _key(name: str, labels: dict) -> tuple:
        return (name, tuple(sorted(labels.items())))

    def _get_or_make(self, cls, name, labels, **kw):
        key = self._key(name, labels)
        with self._lock:
            inst = self._instruments.get(key)
            if inst is None:
                inst = cls(name, dict(labels), **kw)
                self._instruments[key] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} {labels} already registered as "
                    f"{type(inst).__name__}, requested {cls.__name__}")
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get_or_make(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get_or_make(Gauge, name, labels)

    def histogram(self, name: str, *,
                  buckets: Sequence[float] = LATENCY_BUCKETS_S,
                  reservoir: int = RESERVOIR, **labels) -> Histogram:
        return self._get_or_make(Histogram, name, labels,
                                 buckets=buckets, reservoir=reservoir)

    def instruments(self) -> list:
        with self._lock:
            return list(self._instruments.values())

    def clear(self) -> None:
        """Drop every instrument (test isolation; the serving stack never
        calls this)."""
        with self._lock:
            self._instruments.clear()

    # -- export -------------------------------------------------------------

    @staticmethod
    def _fmt_labels(labels: dict, extra: dict | None = None) -> str:
        merged = {**labels, **(extra or {})}
        if not merged:
            return ""
        body = ",".join(f'{k}="{v}"' for k, v in sorted(merged.items()))
        return "{" + body + "}"

    @staticmethod
    def _fmt_val(v) -> str:
        if isinstance(v, float) and math.isinf(v):
            return "+Inf" if v > 0 else "-Inf"
        return repr(v) if isinstance(v, float) else str(v)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format.  Counters exported as
        `<name>_total`, gauges as `<name>` (+ `<name>_hwm`), histograms as
        the standard cumulative `_bucket{le=...}` / `_sum` / `_count`
        triple.  Values round-trip through `parse_prometheus` exactly
        (repr for floats)."""
        lines: list[str] = []
        for inst in sorted(self.instruments(),
                           key=lambda i: (i.name, sorted(i.labels.items()))):
            lab = self._fmt_labels(inst.labels)
            if isinstance(inst, Counter):
                lines.append(f"# TYPE {inst.name}_total counter")
                lines.append(
                    f"{inst.name}_total{lab} {self._fmt_val(inst.value)}")
            elif isinstance(inst, Gauge):
                lines.append(f"# TYPE {inst.name} gauge")
                lines.append(f"{inst.name}{lab} {self._fmt_val(inst.value)}")
                lines.append(
                    f"{inst.name}_hwm{lab} {self._fmt_val(inst.hwm)}")
            elif isinstance(inst, Histogram):
                lines.append(f"# TYPE {inst.name} histogram")
                cum = 0
                for le, n in zip(list(inst.buckets) + [math.inf],
                                 inst.bucket_counts):
                    cum += n
                    le_lab = self._fmt_labels(
                        inst.labels, {"le": self._fmt_val(float(le))})
                    lines.append(f"{inst.name}_bucket{le_lab} {cum}")
                lines.append(
                    f"{inst.name}_sum{lab} {self._fmt_val(inst.sum)}")
                lines.append(f"{inst.name}_count{lab} {inst.count}")
        return "\n".join(lines) + ("\n" if lines else "")


def parse_prometheus(text: str) -> dict[str, float]:
    """Parse the text exposition format back to {'name{labels}': value}.
    Enough of the grammar for the round-trip tests and the reconciliation
    tooling (one metric per line, no escapes inside label values)."""
    out: dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        key, _, val = line.rpartition(" ")
        if not key:
            raise ValueError(f"unparseable metric line: {line!r}")
        v = math.inf if val == "+Inf" else (-math.inf if val == "-Inf"
                                            else float(val))
        out[key] = v
    return out


# the process-wide registry: every serving component registers here unless
# handed an explicit private Registry (tests do, for isolation)
REGISTRY = Registry()

# unique instance labels so N engines / pipelines / routers coexist in the
# one process-wide registry without clobbering each other's instruments
_instance_seq = itertools.count()


def instance_label(kind: str) -> str:
    """`kind#<seq>` — a process-unique instance label for a component's
    instruments (engines die with their owner; their metrics stay
    readable in the registry until process exit)."""
    return f"{kind}#{next(_instance_seq)}"


def summarize_latency(latencies_s: Iterable[float], window_s: float) -> dict:
    """The shared latency/throughput stats block (engine, fleet, pipeline
    benches): mean/p50/p95/p99/max in ms + qps over `window_s`.  Nearest-
    rank percentiles via the one shared helper.  Empty input raises (see
    `percentile`); a zero-length window yields 0.0 qps, never inf."""
    lat = list(latencies_s)
    if not lat:
        raise ValueError(
            "summarize_latency: empty latency set — an all-shed or "
            "never-run window has no distribution; guard n == 0 at the "
            "caller")
    return {
        "latency_mean_ms": sum(lat) / len(lat) * 1e3,
        "latency_p50_ms": percentile(lat, 50) * 1e3,
        "latency_p95_ms": percentile(lat, 95) * 1e3,
        "latency_p99_ms": percentile(lat, 99) * 1e3,
        "latency_max_ms": max(lat) * 1e3,
        "throughput_qps": len(lat) / window_s if window_s > 0 else 0.0,
    }
