"""The paper's train->extract->quantize->bake flow applied to an LM — the
generalization of smallNet's deployment to the transformer zoo.

    PYTHONPATH=src python examples/quantize_deploy.py --arch granite-3-2b
"""
import argparse

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core import ptq
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.serving.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--train-steps", type=int, default=30)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    print(f"== 1. train {args.arch} (reduced) for {args.train_steps} steps ==")
    t = Trainer(cfg, TrainerConfig(total_steps=args.train_steps, seq_len=64,
                                   global_batch=8, lr=3e-3, warmup_steps=5))
    state, history = t.run()
    print(f"   loss {history[0]:.3f} -> {history[-1]:.3f}")

    print("== 2. post-training int8 quantization (per-channel, symmetric) ==")
    qparams = ptq.quantize_tree(state["params"])
    errs = ptq.quantization_error(state["params"], qparams)
    worst = max(errs.items(), key=lambda kv: kv[1])
    print(f"   quantized {len(errs)} weight tensors; worst rel-L2 err "
          f"{worst[1]:.4f} at {worst[0]}")
    deq = ptq.dequantize_tree(qparams)

    print("== 3. serve float vs int8-deployed, compare generations ==")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, size=6).astype(np.int32) for _ in range(4)]
    out_f = Engine(cfg, state["params"], batch_size=2, max_len=32).submit_and_run(
        [Request(i, p.copy(), 6) for i, p in enumerate(prompts)])
    out_q = Engine(cfg, deq, batch_size=2, max_len=32).submit_and_run(
        [Request(i, p.copy(), 6) for i, p in enumerate(prompts)])
    agree = np.mean([a == b for r1, r2 in zip(out_f, out_q)
                     for a, b in zip(r1.out, r2.out)])
    print(f"   greedy-token agreement float vs int8: {agree*100:.0f}%")
    int8_bytes = sum(l.q.size for l in jax.tree_util.tree_leaves(
        qparams, is_leaf=lambda x: isinstance(x, ptq.QuantTensor))
        if isinstance(l, ptq.QuantTensor))
    f32_bytes = sum(l.size * 4 for l in jax.tree_util.tree_leaves(state["params"]))
    print(f"   weight bytes: {f32_bytes} f32 -> ~{int8_bytes} int8 "
          f"({f32_bytes/int8_bytes:.1f}x smaller)")


if __name__ == "__main__":
    main()
