"""Streaming demo: a live synthetic clip, end to end, at frame rate.

Trains a small float smallNet (enough for confident digit scores), then
streams a 50-frame synthetic video — digits drifting and scaling over a
112x112 canvas — through the real-time pipeline: paced source -> sliding-
window tiler -> batched engine waves on the chosen backend -> thresholded,
deduplicated detections.  Prints sustained FPS, latency percentiles, drop
accounting, and the per-frame detections vs. ground truth.

With `--sweep` the sliding-window host tiler is swapped for the
fully-convolutional frame sweep (`streaming/fcn_sweep.FcnSweep`): the conv
trunk runs ONCE per frame on device and every window is scored from the
pooled feature map — identical detections (word-exact on the fixed
substrates), finer stride, no host patch extraction.

With `--trace` the run records per-frame spans (`repro/obs`): after the
clip, the first few frames are printed as ASCII waterfalls — frame root,
tile/infer/aggregate stages, engine queue-wait and device-step — and the
whole flight-recorder ring is dumped to `stream_demo_trace.jsonl`.

    PYTHONPATH=src python examples/stream_demo.py [--backend fixed_pallas]
        [--frames 50] [--fps 10] [--no-train] [--sweep] [--trace]
"""
import argparse

import jax

from repro.core import backends, deploy, smallnet
from repro.obs import recorder as obs_recorder
from repro.obs import trace as obs_trace
from repro.serving.vision_engine import VisionEngine
from repro.streaming.fcn_sweep import FcnSweep
from repro.streaming.pipeline import StreamConfig, StreamingPipeline
from repro.streaming.sources import PacedPlayer, SyntheticVideoSource
from repro.streaming.tiler import Tiler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="fixed_pallas",
                    choices=backends.list_backends())
    ap.add_argument("--frames", type=int, default=50)
    ap.add_argument("--fps", type=float, default=10.0)
    ap.add_argument("--stride", type=int, default=None,
                    help="window stride (default: 14 for the host tiler, "
                         "8 for --sweep; sweep strides must be multiples "
                         "of 4)")
    ap.add_argument("--sweep", action="store_true",
                    help="score windows from one full-frame conv sweep on "
                         "device instead of host-extracted patches")
    ap.add_argument("--threshold", type=float, default=0.9)
    ap.add_argument("--min-mass", type=float, default=0.04,
                    help="foreground gate: skip windows whose mean pixel "
                         "intensity is below this (the net never trained "
                         "on empty background)")
    ap.add_argument("--no-train", action="store_true",
                    help="skip training (random weights; detections are "
                         "arbitrary but the pipeline mechanics are real)")
    ap.add_argument("--trace", action="store_true",
                    help="record per-frame spans; print waterfalls for the "
                         "first frames and dump stream_demo_trace.jsonl")
    ap.add_argument("--trace-dir", default=".",
                    help="directory for the --trace dump")
    args = ap.parse_args()

    tracer = None
    if args.trace:
        tracer = obs_trace.enable(capacity=1 << 17,
                                  dump_dir=args.trace_dir)

    if args.no_train:
        params = smallnet.init_params(jax.random.key(0))
    else:
        print("== train float smallNet (quick run) ==")
        res = deploy.train_smallnet(n_train=3000, n_test=500, epochs=8)
        print(f"   test_acc={res.test_acc:.4f}")
        params = res.params

    mode = "FCN sweep" if args.sweep else "host tiler"
    print(f"== stream {args.frames} frames at {args.fps:g} FPS "
          f"through backend={args.backend!r} ({mode}) ==")
    source = SyntheticVideoSource(n_frames=args.frames, seed=7)
    if args.sweep:
        tiler = FcnSweep(stride=args.stride or 8, threshold=args.threshold,
                         min_mass=args.min_mass)
    else:
        tiler = Tiler(stride=args.stride or 14, threshold=args.threshold,
                      min_mass=args.min_mass)
    # in sweep mode the engine only carries params/backend — skip compiling
    # the batched 28x28 step it would never run (the pipeline warms the
    # whole-frame sweep program itself)
    engine = VisionEngine(params, backend=args.backend, batch_size=64,
                          warmup=not args.sweep)
    pipe = StreamingPipeline(
        PacedPlayer(source, fps=args.fps), engine, tiler,
        config=StreamConfig(deadline_ms=3e3 / args.fps, queue_size=4))
    results = pipe.run()

    truth = {f.index: f.truth for f in source}
    for r in results[:10]:
        dets = ", ".join(f"{d.label}@({d.y},{d.x}) p={d.score:.2f}"
                         for d in r.detections) or "-"
        gt = ", ".join(f"{b.label}@({b.y},{b.x})" for b in truth[r.index])
        print(f"   frame {r.index:3d}  {r.latency_s*1e3:6.1f} ms  "
              f"det=[{dets}]  truth=[{gt}]")
    if len(results) > 10:
        print(f"   ... {len(results) - 10} more frames")

    s = pipe.stats()
    print("== stats ==")
    print(f"   sustained_fps={s['sustained_fps']:.1f} (target {args.fps:g})  "
          f"served={s['frames_served']}/{s['frames_in']}  "
          f"dropped={s['frames_dropped']} {s['drops_by_reason'] or ''}")
    print(f"   latency p50={s.get('latency_p50_ms', 0):.1f}ms "
          f"p99={s.get('latency_p99_ms', 0):.1f}ms  "
          f"batch_occupancy={s.get('batch_occupancy', 0):.2f}  "
          f"detections={s['detections_total']}")
    print(f"   accounted={'OK' if s['accounted'] else 'LOST FRAMES'} "
          f"(in == served + dropped)")

    if tracer is not None:
        import os
        spans = tracer.recorder.spans()
        print("== trace waterfalls (first 3 frames) ==")
        trace_ids = []
        for sp in spans:                      # keep first-seen frame order
            if sp.name == "frame" and sp.trace_id not in trace_ids:
                trace_ids.append(sp.trace_id)
        for tid in trace_ids[:3]:
            print(obs_recorder.waterfall(spans, tid, max_spans=24))
        path = tracer.recorder.dump_jsonl(
            os.path.join(args.trace_dir, "stream_demo_trace.jsonl"),
            reason="stream_demo",
            detail=f"frames={args.frames} backend={args.backend}")
        print(f"== trace dumped: {path} ({len(spans)} spans, "
              f"{tracer.recorder.evicted} evicted) ==")
        obs_trace.disable()


if __name__ == "__main__":
    main()
