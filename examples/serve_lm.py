"""Batched serving driver: continuous-batching engine over a reduced LM.

The TPU analogue of the paper's deployment loop (DMA-FIFO in, classify,
GPIO out): requests stream in, slots refill without draining the batch.

    PYTHONPATH=src python examples/serve_lm.py --arch granite-3-2b \
        --requests 10 --batch 4
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import model as M
from repro.serving.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    model = M.build(cfg)
    params, _ = model.init(jax.random.key(0))
    eng = Engine(cfg, params, batch_size=args.batch, max_len=64)

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(1, cfg.vocab, size=6).astype(np.int32),
                    max_new_tokens=args.max_new) for i in range(args.requests)]
    t0 = time.perf_counter()
    done = eng.submit_and_run(reqs)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out) for r in done)
    for r in done[:5]:
        print(f"req {r.uid}: prompt={list(r.prompt)} -> {r.out}")
    print(f"{len(done)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s on this host)")


if __name__ == "__main__":
    main()
