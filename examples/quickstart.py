"""Quickstart: the paper's entire pipeline in one script.

Trains smallNet in float (the Keras counterpart), extracts + converts the
weights to two's-complement fixed point, "bakes" them into the compiled
program, compares the accuracy ladder float -> PLAN -> fixed -> int8, then
demos the backend registry (one network graph, swappable substrates) and the
streaming vision serving engine.

    PYTHONPATH=src python examples/quickstart.py [--epochs 16] [--backend pallas]
"""
import argparse

import jax.numpy as jnp

from repro.core import backends, deploy, smallnet
from repro.data import synth_mnist
from repro.launch.mesh import make_serving_mesh
from repro.serving.router import ReplicaRouter
from repro.serving.vision_engine import VisionEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=12)
    ap.add_argument("--n-train", type=int, default=6000)
    ap.add_argument("--backend", default="pallas",
                    choices=backends.list_backends(),
                    help="inference substrate for the serving demo")
    args = ap.parse_args()

    print("== 1. train float smallNet (paper §III-A: Adam, batch 64) ==")
    res = deploy.train_smallnet(n_train=args.n_train, n_test=1500,
                                epochs=args.epochs)
    print(f"   params={smallnet.param_count(res.params)} "
          f"train_acc={res.train_acc:.4f} test_acc={res.test_acc:.4f}")

    print("== 2. extract -> 2's-complement fixed point -> bake (§III-B) ==")
    qfix = smallnet.quantize_params_fixed(res.params)
    baked = deploy.bake(lambda q, x: smallnet.forward_fixed(q, x), qfix)
    x, y = synth_mnist.make_dataset(512, seed=2)
    pred = smallnet.predict(baked(jnp.asarray(x)))
    print(f"   baked fixed-point accuracy: {float((pred == y).mean()):.4f}")

    print("== 3. accuracy ladder (paper §IV-C: 93.47 -> 88.03 -> 81) ==")
    for name, acc in deploy.evaluate_all_paths(res.params, n_test=1500).items():
        print(f"   {name:24s} {acc:.4f}")

    print("== 4. backend registry: one graph, every substrate ==")
    xb, yb = synth_mnist.make_dataset(256, seed=4)
    xb = jnp.asarray(xb)
    ref_pred = smallnet.predict(smallnet.apply(res.params, xb, backend="ref"))
    for name in backends.list_backends():
        scores = smallnet.apply(res.params, xb, backend=name)  # float params in
        agree = float((smallnet.predict(scores) == ref_pred).mean())
        acc = float((smallnet.predict(scores) == jnp.asarray(yb)).mean())
        print(f"   backend={name:12s} acc={acc:.4f} argmax-agreement-vs-ref={agree:.4f}")

    # the fused fixed-point Pallas pipeline is not merely close to the
    # emulated fixed path — its int32 score words are identical
    fix = smallnet.apply(res.params, xb, backend="fixed")
    fixp = smallnet.apply(res.params, xb, backend="fixed_pallas")
    n_drift = int((fix != fixp).sum())
    print(f"   fixed vs fixed_pallas: {n_drift} of {fix.size} int32 words "
          f"differ ({'bit-exact' if n_drift == 0 else 'DRIFT'})")

    print(f"== 5. streaming vision engine on backend={args.backend!r} ==")
    # one jitted step sharded over the serving mesh: the batch axis splits
    # across every local device (degenerate on 1 CPU device, batch-DP on a
    # pod slice — same code either way)
    mesh = make_serving_mesh()
    eng = VisionEngine(res.params, backend=args.backend, batch_size=32,
                       mesh=mesh)
    eng.serve(list(synth_mnist.make_dataset(128, seed=6)[0]))
    s = eng.stats()
    print(f"   served n={s['n']} in {s['batches']} batched steps "
          f"(batch={s['batch_size']}, padded_slots={s['padded_slots']}, "
          f"mesh_devices={s['mesh_devices']})")
    print(f"   latency mean={s['latency_mean_ms']:.2f}ms "
          f"p50={s['latency_p50_ms']:.2f}ms p95={s['latency_p95_ms']:.2f}ms "
          f"throughput={s['throughput_qps']:.0f} img/s")

    print("== 5b. replica router: engine -> replicas -> mesh ==")
    # fleet-level serving: a least-loaded router over two replicas (here two
    # backends of the same weights — the paper's CPU + fabric, side by side),
    # drained concurrently with failover and aggregated fleet stats
    router = ReplicaRouter.from_backends(res.params,
                                         [args.backend, "fixed_pallas"],
                                         batch_size=32, mesh=mesh)
    router.serve(list(synth_mnist.make_dataset(128, seed=7)[0]))
    fs = router.stats()
    print(f"   fleet served n={fs['n']} over {fs['replicas']} replicas "
          f"(healthy={fs['healthy']}, served_by={fs['served_by']})")
    print(f"   fleet latency p50={fs['latency_p50_ms']:.2f}ms "
          f"p95={fs['latency_p95_ms']:.2f}ms "
          f"throughput={fs['throughput_qps']:.0f} img/s")

    print("== 6. latency (paper §IV-B: 560 ms CPU -> 109 ms FPGA, 5.1x) ==")
    sw = deploy.measure_latency(smallnet.forward, res.params)
    print(f"   deployed-baked latency: {sw*1e3:.3f} ms/image on this host")


if __name__ == "__main__":
    main()
