"""End-to-end LM training driver: any assigned arch at reduced scale, with
deterministic data, cosine schedule, async checkpointing and auto-resume.

    PYTHONPATH=src python examples/train_lm.py --arch granite-3-2b --steps 200
    PYTHONPATH=src python examples/train_lm.py --arch granite-3-2b \
        --preset 100m --steps 300         # ~100M-param variant (slow on CPU)

Kill it mid-run and start again: it resumes from the last checkpoint.
"""
import argparse
import dataclasses

import jax.numpy as jnp

from repro.configs.base import get_config
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", choices=["smoke", "100m"], default="smoke")
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    if args.preset == "100m":
        cfg = dataclasses.replace(
            cfg, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            head_dim=64, d_ff=2048, vocab=32768, micro_batch=args.batch,
            dtype=jnp.float32, param_dtype=jnp.float32)
    t = Trainer(cfg, TrainerConfig(
        total_steps=args.steps, seq_len=args.seq_len, global_batch=args.batch,
        lr=3e-3, warmup_steps=max(5, args.steps // 20),
        ckpt_dir=args.ckpt_dir, ckpt_every=25, log_every=10))

    def log(step, m):
        extra = " STRAGGLER" if m.get("straggler") else ""
        print(f"step {step:5d}  loss {m['loss']:.4f}  gnorm {m['grad_norm']:.3f}{extra}",
              flush=True)

    state, history = t.run(on_metrics=log)
    print(f"final loss: {history[-1]:.4f} (first: {history[0]:.4f})")


if __name__ == "__main__":
    main()
